//! llm-coopt CLI: serve, generate, eval, or inspect artifacts.
//!
//! ```text
//! llm-coopt --mode serve   --model llama-13b-sim --config coopt --addr 127.0.0.1:8090
//! llm-coopt --mode generate --model llama-13b-sim --config coopt --prompt "Q: 2+3=? ..."
//! llm-coopt --mode eval    --model llama-13b-sim --set easy
//! llm-coopt --mode info
//! ```

use anyhow::{bail, Context, Result};
use llm_coopt::config::{
    artifacts_dir, opt_config, parse_replica_roles, EngineConfig, ReqClass, RouterPolicy,
    SpecMode, SpecPolicy, SwapPolicy,
};
use llm_coopt::coordinator::{Engine, GenRequest};
use llm_coopt::eval;
use llm_coopt::router::{start_autoscaler, RouterHandle};
use llm_coopt::runtime::Runtime;
use llm_coopt::sampling::SamplingParams;
use llm_coopt::server::Server;
use llm_coopt::util::cli::Cli;
use llm_coopt::workload::load_mcq_set;
use llm_coopt::log_info;

fn main() -> Result<()> {
    llm_coopt::util::logging::init();
    let mut cli = Cli::new("llm-coopt", "LLM-CoOpt serving coordinator");
    cli.flag("mode", "info", "serve | generate | eval | info")
        .flag("model", "llama-13b-sim", "model preset name")
        .flag("config", "coopt", "original|optkv|optgqa|optpa|coopt")
        .flag("artifacts", "", "artifacts dir (default ./artifacts)")
        .flag("addr", "127.0.0.1:8090", "serve: bind address")
        .flag("workers", "8", "serve: HTTP worker threads")
        .flag(
            "replicas",
            "1",
            "serve: engine replicas behind the router, each with its own \
             scheduler, KV cache, and tier manager (1 = the single-engine path)",
        )
        .flag(
            "router-policy",
            "least_loaded",
            "serve: request placement across replicas: round_robin, \
             least_loaded (live queue depth + free device/host KV blocks + \
             spec_regime/tokens_per_step gauges), prefix_affinity (route \
             shared leading prefixes to the replica already holding them, \
             falling back to least_loaded above the cost model's \
             load-imbalance threshold), or directory (cluster-wide prefix \
             directory keyed on full chain hashes; when affinity falls back, \
             the destination pulls the warm KV chain from its owner over the \
             host tier if the Z100 model prices the transfer under \
             re-prefilling)",
        )
        .flag(
            "replica-roles",
            "",
            "serve: comma-separated PD role per replica (prefill|decode|mixed), \
             e.g. prefill,decode,mixed.  Empty = all mixed.  Prefill-role \
             replicas hand each sequence's KV off through the host tier to a \
             decode-capable replica at prefill completion when the Z100 model \
             prices the PCIe transfer under re-prefilling",
        )
        .flag(
            "pd-autoscale",
            "false",
            "serve: run the queue-depth/occupancy-spread autoscaler, which \
             drains idle replicas, re-admits them on backlog, and re-roles \
             the idlest replica toward the saturated phase (true|false)",
        )
        .flag("prompt", "", "generate: the prompt")
        .flag("max-new-tokens", "32", "generate: tokens to produce")
        .flag("temperature", "0.0", "generate: sampling temperature")
        .flag(
            "prefill-chunk-tokens",
            "0",
            "chunked prefill (Opt-Pa step 1): per-chunk token budget, 0 = one-shot \
             (mid-prompt chunks need a backend with a chunked prefill graph)",
        )
        .flag(
            "host-pool-blocks",
            "0",
            "two-tier KV (Opt-KV tier manager): host-tier pool capacity in blocks, \
             0 = single tier.  Preemption then swaps a victim's KV over PCIe and \
             prefetches it back instead of recomputing its prefill; backends \
             without KV swap support fall back to drop-and-recompute",
        )
        .flag(
            "swap-policy",
            "auto",
            "swap-vs-recompute preemption policy with a host pool: auto = \
             cost-based (PCIe round trip vs prefill recompute on the Z100 model), \
             always, never",
        )
        .flag(
            "evict-watermark",
            "0",
            "two-tier KV: low watermark of free device blocks below which the \
             engine proactively swaps the preemption-order victim's KV to the \
             host tier ahead of demand (at most one victim per step; swap-only, \
             never recompute), 0 = off.  Needs --host-pool-blocks > 0",
        )
        .flag(
            "prefetch-depth",
            "1",
            "two-tier KV: decode batches' worth of swapped sequences the async \
             prefetch queue may stage ahead of the scheduler (deeper hides more \
             swap latency, holds more device blocks)",
        )
        .flag(
            "spec-tokens",
            "0",
            "speculative decoding: draft length k per decode round (a verify \
             pass scores k+1 positions and can commit k+1 tokens), 0 = off. \
             Backends without draft/verify support fall back to one-token decode",
        )
        .flag(
            "spec-mode",
            "fixed",
            "draft-length selection: fixed (--spec-tokens K every round) or \
             adaptive (an online controller picks k in 0..=spec-k-max each \
             round from the measured acceptance rate and the Z100 cost \
             model's regime detector; k=0 on GEMM-bound batches)",
        )
        .flag(
            "spec-k-max",
            "4",
            "adaptive speculation: upper bound of the per-round draft-length \
             search",
        )
        .flag(
            "spec-ewma-alpha",
            "0.25",
            "adaptive speculation: EWMA weight of the newest acceptance \
             measurement (higher adapts faster, lower is steadier)",
        )
        .flag(
            "spec-policy",
            "stochastic",
            "speculative acceptance rule for sampled requests: stochastic = \
             rejection sampling (distribution-preserving, incl. top-k/top-p; \
             greedy requests always verify by exact argmax match) or greedy = \
             deterministic argmax verification even under temperature sampling",
        )
        .flag(
            "spec-shrink",
            "0.125",
            "draft model size as a fraction of the target (drives the Z100 \
             model's draft-weight restream cost)",
        )
        .flag(
            "trace-depth",
            "64",
            "request-lifecycle tracing: finished-request timelines kept per \
             replica in the flight-recorder ring (GET /admin/trace), 0 = off. \
             Per-phase latency attribution stays on either way",
        )
        .flag(
            "trace-sample",
            "1.0",
            "request-lifecycle tracing: fraction of requests recording the \
             full event timeline (deterministic by request id).  Unsampled \
             requests keep their phase breakdown but carry no events",
        )
        .flag(
            "slo-admission",
            "false",
            "SLO overload control: router admission shedding on/off.  When \
             on, batch-class requests are shed with 429 + Retry-After when \
             the projected queue wait would blow the interactive TTFT \
             budget, the batch queue is bounded, and per-tenant accounting \
             caps any tenant's share of outstanding prefill tokens \
             (true|false)",
        )
        .flag(
            "slo-interactive-ttft-ms",
            "250",
            "SLO overload control: interactive TTFT budget in milliseconds; \
             the admission controller sheds or defers batch work when the \
             projected queue wait exceeds it",
        )
        .flag(
            "interactive-prefill-reserve",
            "0.0",
            "SLO overload control: fraction of the per-step prefill budget \
             reserved for interactive sequences while any interactive \
             prefill is pending (0.0..=0.9; 0 = no split)",
        )
        .flag(
            "forecast",
            "false",
            "predictive control: sample a per-replica signal ring at step \
             boundaries and run the self-scoring estimators (output-length \
             quantiles, arrival-burst detector, queue-wait forecaster).  \
             Controllers consume a forecast only while its calibration \
             coverage is in band; off keeps every reactive behaviour \
             bit-identical (true|false)",
        )
        .flag(
            "forecast-ring",
            "256",
            "predictive control: signal-ring capacity in step-boundary \
             samples (GET /admin/forecast dumps it)",
        )
        .flag(
            "forecast-warmup",
            "16",
            "predictive control: resolved predictions an estimator needs \
             before controllers may consume it",
        )
        .flag(
            "forecast-burst-ratio",
            "3.0",
            "predictive control: short-over-long-window arrival-rate ratio \
             that declares a burst (clamped to >= 1.0)",
        )
        .flag(
            "forecast-burst-tighten",
            "2.0",
            "predictive control: admission-wait multiplier while a scored \
             burst is active (clamped to >= 1.0; pre-tightens shedding \
             ahead of the queue growth)",
        )
        .flag(
            "log-level",
            "",
            "stderr log level: error|warn|info|debug|trace (overrides \
             LLM_COOPT_LOG; also gates the structured JSON events the \
             serving path emits on dropped replies)",
        )
        .flag("set", "easy", "eval: easy | challenge");
    let args = cli.parse_or_exit();

    if !args.get("log-level").is_empty() {
        llm_coopt::util::logging::set_level(llm_coopt::util::logging::Level::parse(
            args.get("log-level"),
        )?);
    }

    let engine_cfg = |model: &str, opt| -> Result<EngineConfig> {
        let mut cfg = EngineConfig::new(model, opt);
        let chunk = args.get_usize("prefill-chunk-tokens");
        if chunk > 0 {
            cfg = cfg.with_chunked_prefill(chunk);
        }
        let host = args.get_usize("host-pool-blocks");
        if host > 0 {
            cfg = cfg.with_host_pool(host);
        }
        cfg = cfg.with_swap_policy(SwapPolicy::parse(args.get("swap-policy"))?);
        let watermark = args.get_usize("evict-watermark");
        if watermark > 0 {
            cfg = cfg.with_evict_watermark(watermark);
        }
        cfg = cfg.with_prefetch_depth(args.get_usize("prefetch-depth"));
        let spec = args.get_usize("spec-tokens");
        if spec > 0 {
            cfg = cfg.with_speculation(spec);
        }
        if SpecMode::parse(args.get("spec-mode"))? == SpecMode::Adaptive {
            cfg = cfg.with_adaptive_speculation(args.get_usize("spec-k-max"));
        }
        cfg = cfg
            .with_spec_policy(SpecPolicy::parse(args.get("spec-policy"))?)
            .with_spec_shrink(args.get_f64("spec-shrink"))
            .with_spec_ewma_alpha(args.get_f64("spec-ewma-alpha"))
            .with_trace_depth(args.get_usize("trace-depth"))
            .with_trace_sample(args.get_f64("trace-sample"))
            .with_slo_admission(args.get_bool("slo-admission"))
            .with_interactive_ttft_ms(args.get_usize("slo-interactive-ttft-ms") as u64)
            .with_interactive_prefill_reserve(args.get_f64("interactive-prefill-reserve"))
            .with_forecast(args.get_bool("forecast"))
            .with_forecast_ring(args.get_usize("forecast-ring"))
            .with_forecast_warmup(args.get_usize("forecast-warmup") as u64)
            .with_forecast_burst_ratio(args.get_f64("forecast-burst-ratio"))
            .with_forecast_burst_tighten(args.get_f64("forecast-burst-tighten"));
        Ok(cfg)
    };

    let dir = if args.get("artifacts").is_empty() {
        artifacts_dir()
    } else {
        args.get("artifacts").into()
    };

    match args.get("mode") {
        "info" => {
            let rt = Runtime::new(&dir)?;
            println!("artifacts: {}", dir.display());
            println!(
                "geometry: block_size={} max_blocks={} pool={} max_batch={} max_seq={}",
                rt.manifest.geometry.block_size,
                rt.manifest.geometry.max_blocks,
                rt.manifest.geometry.num_pool_blocks,
                rt.manifest.geometry.max_batch,
                rt.manifest.geometry.max_seq
            );
            println!("{} models, {} graphs:", rt.manifest.models.len(), rt.manifest.graphs.len());
            for m in &rt.manifest.models {
                println!(
                    "  {:18} ({}) layers={} d={} Hq={} Hkv(gqa)={} params≈{}",
                    m.preset.name,
                    m.preset.stands_for,
                    m.preset.layers,
                    m.preset.d_model,
                    m.preset.n_heads,
                    m.preset.n_kv_heads_gqa,
                    m.preset.param_count()
                );
            }
            Ok(())
        }
        "serve" => {
            let opt = opt_config(args.get("config"))?;
            let model = args.get("model");
            let replicas = args.get_usize("replicas").max(1);
            let policy = RouterPolicy::parse(args.get("router-policy"))?;
            let roles = parse_replica_roles(args.get("replica-roles"))?;
            if !roles.is_empty() && roles.len() != replicas {
                bail!(
                    "--replica-roles names {} roles for {replicas} replicas",
                    roles.len()
                );
            }
            let rt = Runtime::new(&dir)?;
            let mut engines = Vec::with_capacity(replicas);
            let base = engine_cfg(model, opt)?;
            let (slo, forecast) = (base.slo, base.forecast);
            for i in 0..replicas {
                let mrt = rt.load_model(model, opt)?;
                if i == 0 {
                    log_info!("compiled {model}/{} in {:?}", opt.name, mrt.compile_time);
                }
                let mut cfg = engine_cfg(model, opt)?;
                if let Some(&role) = roles.get(i) {
                    cfg = cfg.with_role(role);
                }
                engines.push(Engine::new(mrt, cfg));
            }
            let router = RouterHandle::spawn(engines, policy)
                .with_slo(slo)
                .with_forecast(forecast);
            let server =
                Server::bind_router(args.get("addr"), router, args.get_usize("workers"))?;
            if args.get_bool("pd-autoscale") {
                start_autoscaler(&server.router(), std::time::Duration::from_millis(500));
                log_info!("pd autoscaler running (500ms tick)");
            }
            server.serve()
        }
        "generate" => {
            let opt = opt_config(args.get("config"))?;
            let model = args.get("model");
            let prompt = args.get("prompt");
            if prompt.is_empty() {
                bail!("--prompt required in generate mode");
            }
            let rt = Runtime::new(&dir)?;
            let mrt = rt.load_model(model, opt)?;
            let mut engine = Engine::new(mrt, engine_cfg(model, opt)?);
            let results = engine.generate(vec![GenRequest {
                prompt: prompt.to_string(),
                max_new_tokens: args.get_usize("max-new-tokens"),
                sampling: SamplingParams {
                    temperature: args.get_f64("temperature"),
                    ..Default::default()
                },
                ignore_eos: false,
                corr_id: None,
                class: ReqClass::default(),
            }])?;
            let r = &results[0];
            println!("prompt   : {}", r.prompt);
            println!("completion: {}", r.text);
            println!(
                "tokens={} finish={:?} latency={:.3}s sim_time={:.4}s",
                r.generated_tokens, r.finish, r.latency_s, r.sim_time_s
            );
            println!(
                "phases  : queue={:.4}s prefill={:.4}s decode={:.4}s \
                 swap_blocked={:.4}s migration={:.4}s",
                r.phases.queue_s,
                r.phases.prefill_s,
                r.phases.decode_s,
                r.phases.swap_blocked_s,
                r.phases.migration_s
            );
            Ok(())
        }
        "eval" => {
            let opt = opt_config(args.get("config"))?;
            let model = args.get("model");
            let split = args.get("set");
            let rt = Runtime::new(&dir)?;
            let set_file = rt
                .manifest
                .eval_sets
                .iter()
                .find(|(s, _)| s == split)
                .map(|(_, f)| f.clone())
                .context("eval set not in manifest")?;
            let set = load_mcq_set(dir.join(set_file))?;
            let mrt = rt.load_model(model, opt)?;
            let mut engine = Engine::new(mrt, EngineConfig::new(model, opt));
            let r = eval::evaluate(&mut engine, &set)?;
            println!(
                "{model} {} ARC-sim[{split}]: {}/{} = {:.2}%",
                opt.name,
                r.correct,
                r.total,
                r.accuracy_pct()
            );
            Ok(())
        }
        other => bail!("unknown mode '{other}'"),
    }
}
