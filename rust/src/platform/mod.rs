//! DCU Z100 platform model (paper §2 and §4.1).
//!
//! The paper evaluates on a DCU Z100: ~4 MB L2, wavefront 64, GDDR6 at
//! ~512 GB/s, ~15 TFLOPS FP16 peak, FP8 emulated via INT8, physically
//! separate CPU/GPU memory.  We do not have that hardware; this module is
//! the documented substitution (DESIGN.md): an analytical cost model of
//! exactly those parameters, driven by the *actual* per-step state of the
//! serving engine (context lengths, allocated blocks, written slots).
//!
//! The paper's equations appear as named methods:
//!
//! * Eq. 2  `used_cache`        — blocks touched x block size (baseline
//!   walks every allocated block, Opt-Pa only valid ones)
//! * Eq. 3  `effective_latency` — `H*T_cache + (1-H)*T_DRAM`
//! * Eq. 4  `kernel_load`       — `B * N_block * d^2` attention load
//!
//! The relative deltas between opt-configs come from first principles
//! (bytes moved, blocks touched, ops issued); the absolute scale is set by
//! the Z100 datasheet numbers above.  Benches report these simulated
//! times next to the real CPU wallclock of the sim-scale stack.

use crate::config::{ModelPreset, OptConfig};

/// Z100 datasheet + microarchitectural constants.
#[derive(Debug, Clone)]
pub struct PlatformSpec {
    pub name: &'static str,
    pub l2_bytes: f64,
    /// total device memory (weights + KV pool contend for it)
    pub device_memory_bytes: f64,
    pub wavefront: usize,
    /// DRAM (GDDR6) streaming bandwidth
    pub bandwidth_bytes_per_s: f64,
    pub fp16_flops: f64,
    /// cache/DRAM access latencies (cycles) for Eq. 3
    pub t_cache_cycles: f64,
    pub t_dram_cycles: f64,
    pub clock_hz: f64,
    /// allocator-mismatch penalty per block allocation on the baseline
    /// (§2: "allocator inefficiency and increased latency due to
    /// allocator mismatch"); the optimized write path amortizes it
    pub alloc_penalty_s: f64,
    /// fixed per-token-write overhead (cache-management instructions)
    pub write_op_s: f64,
    /// fixed per-kernel-pass launch/ramp overhead; chunked prefill pays
    /// it once per window (the monolithic prefill amortizes it)
    pub pass_launch_s: f64,
    /// host<->device interconnect bandwidth (PCIe gen3 x16 class — the
    /// Z100 sits on physically separate CPU/GPU memory, §2); the Opt-KV
    /// tier manager streams swapped KV blocks over this link
    pub pcie_bandwidth_bytes_per_s: f64,
    /// fixed DMA setup/launch latency per swap transfer batch
    pub swap_launch_s: f64,
    /// per-block softmax reduction/synchronization overhead: warp-level
    /// broadcast chain (baseline) vs shared-memory block_sum (Opt-Pa)
    pub sync_warp_s: f64,
    pub sync_blocksum_s: f64,
    /// achievable fractions of peak (GEMM vs memory-bound attention GEMV)
    pub gemm_eff: f64,
    pub attn_compute_eff: f64,
    /// INT8-emulated FP8 dequant cost per KV byte loaded (compute side)
    pub fp8_dequant_flops_per_byte: f64,
}

impl Default for PlatformSpec {
    fn default() -> Self {
        PlatformSpec {
            name: "DCU-Z100",
            l2_bytes: 4.0 * 1024.0 * 1024.0,
            device_memory_bytes: 16.0 * 1024.0 * 1024.0 * 1024.0,
            wavefront: 64,
            bandwidth_bytes_per_s: 512.0e9,
            fp16_flops: 15.0e12,
            t_cache_cycles: 80.0,
            t_dram_cycles: 400.0,
            clock_hz: 1.5e9,
            alloc_penalty_s: 4.0e-6,
            write_op_s: 30.0e-9,
            pass_launch_s: 25.0e-6,
            pcie_bandwidth_bytes_per_s: 16.0e9,
            swap_launch_s: 10.0e-6,
            sync_warp_s: 220.0e-9,
            sync_blocksum_s: 60.0e-9,
            gemm_eff: 0.70,
            attn_compute_eff: 0.30,
            fp8_dequant_flops_per_byte: 1.0,
        }
    }
}

/// Paper-scale geometry for the model being served (the sim preset's twin).
#[derive(Debug, Clone)]
pub struct PaperGeometry {
    pub layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    /// GQA group count when Opt-GQA restructures the checkpoint
    pub gqa_groups: usize,
    /// GPTQ weight width in bits
    pub weight_bits: f64,
}

impl PaperGeometry {
    pub fn from_preset(p: &ModelPreset) -> Self {
        PaperGeometry {
            layers: p.paper_layers,
            d_model: p.paper_d_model,
            n_heads: p.paper_heads,
            head_dim: p.paper_d_model / p.paper_heads,
            ffn: (p.paper_d_model as f64 * 2.6875) as usize, // llama ratio
            gqa_groups: p.groups(true),
            weight_bits: 4.0,
        }
    }

    pub fn kv_heads(&self, opt: &OptConfig) -> usize {
        if opt.gqa {
            (self.n_heads / self.gqa_groups).max(1)
        } else {
            self.n_heads
        }
    }

    /// total parameter count (weights traffic per decode step)
    pub fn param_count(&self) -> f64 {
        let d = self.d_model as f64;
        let per_layer = 4.0 * d * d + 3.0 * d * self.ffn as f64;
        self.layers as f64 * per_layer + 2.0 * 32000.0 * d
    }

    /// KV bytes per token per layer under `opt` (K + V [+ scales])
    pub fn kv_bytes_per_token_layer(&self, opt: &OptConfig) -> f64 {
        let hk = self.kv_heads(opt) as f64;
        let elt = if opt.fp8_kv { 1.0 } else { 2.0 };
        let scales = if opt.fp8_kv { hk * 4.0 * 2.0 } else { 0.0 };
        hk * self.head_dim as f64 * elt * 2.0 + scales
    }
}

/// Per-sequence engine state fed into the cost model each step.
#[derive(Debug, Clone, Copy)]
pub struct SeqCostInput {
    /// context length (tokens visible to attention)
    pub ctx_len: usize,
    /// blocks currently allocated to the sequence (>= ceil(ctx/B) on the
    /// padded baseline)
    pub allocated_blocks: usize,
}

/// Decomposed cost of one engine step.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepCost {
    pub weights_mem_s: f64,
    pub kv_mem_s: f64,
    pub compute_s: f64,
    pub overhead_s: f64,
    pub total_s: f64,
    pub bytes_moved: f64,
    pub flops: f64,
}

#[derive(Debug, Clone)]
pub struct CostModel {
    pub spec: PlatformSpec,
    pub geom: PaperGeometry,
    pub block_size: usize,
    /// sim-context -> paper-context scale: the sim engine's geometry caps
    /// contexts at 160 tokens while the paper's ShareGPT workload averages
    /// ~500; engine-reported lengths are multiplied by this factor before
    /// costing so KV-path traffic sits at the paper's operating point.
    pub ctx_scale: f64,
}

impl CostModel {
    pub fn new(spec: PlatformSpec, geom: PaperGeometry, block_size: usize) -> Self {
        CostModel {
            spec,
            geom,
            block_size,
            ctx_scale: 1.0,
        }
    }

    /// Scale applied to engine-reported (sim) context lengths; see field doc.
    pub fn with_ctx_scale(mut self, s: f64) -> Self {
        self.ctx_scale = s;
        self
    }

    pub fn for_preset(preset: &ModelPreset, block_size: usize) -> Self {
        Self::new(
            PlatformSpec::default(),
            PaperGeometry::from_preset(preset),
            block_size,
        )
    }

    // --- paper equations ---------------------------------------------------

    /// Eq. 2: cache actually traversed by the attention kernel.
    /// `R` = blocks touched, `S_block` = block size in tokens.
    pub fn used_cache_tokens(&self, blocks_touched: usize) -> usize {
        blocks_touched * self.block_size
    }

    /// Eq. 3: effective access latency in cycles given hit rate `h`.
    pub fn effective_latency_cycles(&self, h: f64) -> f64 {
        h * self.spec.t_cache_cycles + (1.0 - h) * self.spec.t_dram_cycles
    }

    /// Eq. 4: attention kernel load `B * N_block * d^2`.
    pub fn kernel_load(&self, batch: usize, n_blocks: usize) -> f64 {
        batch as f64 * n_blocks as f64 * (self.geom.head_dim as f64).powi(2)
    }

    /// L2 hit rate for a KV working set of `ws` bytes: the resident
    /// fraction, saturating at 0.95 (metadata always contends).
    pub fn kv_hit_rate(&self, ws: f64) -> f64 {
        if ws <= 0.0 {
            return 0.95;
        }
        (self.spec.l2_bytes / ws).min(0.95)
    }

    /// Effective KV-stream bandwidth once cache hits are accounted:
    /// `bw * T_dram / T_eff` (all-DRAM streaming is the baseline bw).
    pub fn effective_kv_bandwidth(&self, ws: f64) -> f64 {
        let h = self.kv_hit_rate(ws);
        let t_eff = self.effective_latency_cycles(h);
        self.spec.bandwidth_bytes_per_s * self.spec.t_dram_cycles / t_eff
    }

    // --- step costs ---------------------------------------------------------

    /// Cost of one batched decode step at paper scale.
    ///
    /// `new_blocks` = blocks allocated this step (allocator penalty),
    /// `tokens_written` = KV writes issued (baseline re-writes nothing at
    /// decode, but its prefill wrote padding — see [`Self::prefill`]).
    pub fn decode_step(
        &self,
        seqs: &[SeqCostInput],
        opt: &OptConfig,
        new_blocks: usize,
        tokens_written: usize,
    ) -> StepCost {
        self.attention_step(seqs, opt, new_blocks, tokens_written, 1)
    }

    /// Speculative decoding: cost of one verify pass scoring `k + 1`
    /// positions per lane in a single kernel invocation.  This is the
    /// amortization speculation buys — the weights stream once and the KV
    /// cache is read once for up to k+1 token commits (instead of once
    /// per token on the sequential path); only the GEMM compute and the
    /// KV writes scale with k+1.
    pub fn verify_batch(
        &self,
        seqs: &[SeqCostInput],
        opt: &OptConfig,
        k: usize,
        new_blocks: usize,
        tokens_written: usize,
    ) -> StepCost {
        self.attention_step(seqs, opt, new_blocks, tokens_written, k + 1)
    }

    /// One attention-phase step with `q_tokens` query positions per lane
    /// (1 = plain decode, k+1 = a speculative verify pass).
    fn attention_step(
        &self,
        seqs: &[SeqCostInput],
        opt: &OptConfig,
        new_blocks: usize,
        tokens_written: usize,
        q_tokens: usize,
    ) -> StepCost {
        let s = &self.spec;
        let g = &self.geom;
        let b = seqs.len() as f64;
        let q = q_tokens as f64;
        if seqs.is_empty() {
            return StepCost::default();
        }

        // 1. weights stream once per step (GPTQ 4-bit), GEMM compute per
        // lane and query token
        let weight_bytes = g.param_count() * g.weight_bits / 8.0;
        let weights_mem_s = weight_bytes / s.bandwidth_bytes_per_s;
        let gemm_flops = 2.0 * g.param_count() * b * q;
        let gemm_s = gemm_flops / (s.fp16_flops * s.gemm_eff);

        // 2. attention KV traffic (Eq. 2/4): blocks touched per sequence
        let kv_tok_bytes = g.kv_bytes_per_token_layer(opt) * g.layers as f64;
        let mut kv_bytes = 0.0;
        let mut blocks_touched = 0usize;
        for sq in seqs {
            let ctx = (sq.ctx_len as f64 * self.ctx_scale).round() as usize;
            let alloc = (sq.allocated_blocks as f64 * self.ctx_scale).round() as usize;
            let touched = if opt.valid_only {
                ctx.div_ceil(self.block_size)
            } else {
                alloc.max(ctx.div_ceil(self.block_size))
            };
            blocks_touched += touched;
            kv_bytes += self.used_cache_tokens(touched) as f64 * kv_tok_bytes;
        }
        // Eq. 3 cache behaviour on the KV stream
        let kv_mem_s = kv_bytes / self.effective_kv_bandwidth(kv_bytes);

        // attention compute: q.K^T + p.V over every touched token, per
        // layer and per query position (4*Hq*D flops per key token per
        // layer); FP8 dequant runs at full SIMD INT8 rate and is paid
        // once on the single KV read regardless of q
        let attn_flops = 4.0
            * g.n_heads as f64
            * g.head_dim as f64
            * g.layers as f64
            * self.used_cache_tokens(blocks_touched) as f64
            * q;
        let dequant_flops = if opt.fp8_kv {
            kv_bytes * s.fp8_dequant_flops_per_byte
        } else {
            0.0
        };
        let attn_s = attn_flops / (s.fp16_flops * s.attn_compute_eff)
            + dequant_flops / s.fp16_flops;

        // 3. overheads: softmax reductions per (seq x kv-head x block),
        //    allocator penalty on fresh blocks, per-write fixed cost
        let sync_unit = if opt.valid_only {
            s.sync_blocksum_s
        } else {
            s.sync_warp_s
        };
        let kv_heads = g.kv_heads(opt) as f64;
        let sync_s = blocks_touched as f64 * kv_heads * sync_unit / s.wavefront as f64;
        let alloc_s = new_blocks as f64
            * if opt.skip_filter {
                s.alloc_penalty_s * 0.25 // optimized write path amortizes
            } else {
                s.alloc_penalty_s
            };
        let write_bytes = tokens_written as f64 * kv_tok_bytes;
        let write_s = tokens_written as f64 * s.write_op_s + write_bytes / s.bandwidth_bytes_per_s;
        let overhead_s = sync_s + alloc_s + write_s;

        let compute_s = gemm_s + attn_s;
        // memory and compute overlap; overheads serialize
        let total_s = (weights_mem_s + kv_mem_s).max(compute_s) + overhead_s;
        StepCost {
            weights_mem_s,
            kv_mem_s,
            compute_s,
            overhead_s,
            total_s,
            bytes_moved: weight_bytes + kv_bytes + write_bytes,
            flops: gemm_flops + attn_flops + dequant_flops,
        }
    }

    /// Speculative decoding: cost of drafting `k` tokens per lane with a
    /// draft model shrunk to `shrink` of the target's parameters.  The
    /// draft chain is sequential — each of the k micro-steps restreams
    /// the (shrunk) draft weights and re-reads the draft's equally shrunk
    /// KV — which is exactly the overhead the verify pass's k-fold
    /// KV-read amortization has to beat.
    pub fn draft_step(
        &self,
        seqs: &[SeqCostInput],
        opt: &OptConfig,
        k: usize,
        shrink: f64,
    ) -> StepCost {
        let s = &self.spec;
        let g = &self.geom;
        if seqs.is_empty() || k == 0 {
            return StepCost::default();
        }
        let b = seqs.len() as f64;
        let shrink = shrink.clamp(0.01, 1.0);
        let kf = k as f64;

        let weight_bytes = g.param_count() * g.weight_bits / 8.0 * shrink;
        let weights_mem_s = kf * weight_bytes / s.bandwidth_bytes_per_s;
        let gemm_flops = 2.0 * g.param_count() * shrink * b * kf;
        let gemm_s = gemm_flops / (s.fp16_flops * s.gemm_eff);

        // draft KV stream: each micro-step re-reads the draft's context
        let kv_tok_bytes = g.kv_bytes_per_token_layer(opt) * g.layers as f64 * shrink;
        let mut kv_bytes = 0.0;
        for q in seqs {
            let ctx = (q.ctx_len as f64 * self.ctx_scale).round();
            kv_bytes += ctx * kv_tok_bytes * kf;
        }
        let kv_mem_s = kv_bytes / self.effective_kv_bandwidth(kv_bytes / kf);

        // k sequential kernel launches (the micro-steps cannot batch)
        let overhead_s = kf * s.pass_launch_s;
        let total_s = (weights_mem_s + kv_mem_s).max(gemm_s) + overhead_s;
        StepCost {
            weights_mem_s,
            kv_mem_s,
            compute_s: gemm_s,
            overhead_s,
            total_s,
            bytes_moved: kf * weight_bytes + kv_bytes,
            flops: gemm_flops,
        }
    }

    /// Acceptance rate at which speculative decoding breaks even with
    /// one-token decode on Eq. 12 throughput for this batch shape:
    /// solves `E[committed](α) = (t_draft + t_verify) / t_decode` with
    /// `E[committed](α) = Σ_{i=0..k} α^i` (the accepted geometric prefix
    /// plus the corrected/bonus token).  Returns `None` when even perfect
    /// acceptance (k+1 commits per round) cannot break even.
    pub fn spec_crossover_acceptance(
        &self,
        seqs: &[SeqCostInput],
        opt: &OptConfig,
        k: usize,
        shrink: f64,
    ) -> Option<f64> {
        if seqs.is_empty() || k == 0 {
            return None;
        }
        let t1 = self.decode_step(seqs, opt, 0, seqs.len()).total_s;
        if t1 <= 0.0 {
            return None;
        }
        let spec_s = self.spec_round_s(seqs, opt, k, shrink);
        let need = spec_s / t1; // tokens a round must commit to break even
        if expected_spec_commits(1.0, k) < need {
            return None;
        }
        // E[committed] is monotone in α: bisect
        let (mut lo, mut hi) = (0.0f64, 1.0f64);
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if expected_spec_commits(mid, k) < need {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Some(0.5 * (lo + hi))
    }

    /// Simulated seconds of one full speculative round (sequential draft
    /// chain + one batched verify pass) at draft length `k`.
    fn spec_round_s(&self, seqs: &[SeqCostInput], opt: &OptConfig, k: usize, shrink: f64) -> f64 {
        self.draft_step(seqs, opt, k, shrink).total_s
            + self.verify_batch(seqs, opt, k, 0, seqs.len() * (k + 1)).total_s
    }

    /// The adaptive-speculation regime detector: draft length that
    /// maximizes expected committed tokens per simulated second for this
    /// batch shape at the given (estimated) per-position acceptance rate,
    /// searched over `1..=k_max` against the one-token decode baseline.
    ///
    /// Returns 0 when no draft length beats plain decode — which happens
    /// both when acceptance is too low (the draft is not worth verifying)
    /// and when the batch has crossed into GEMM-bound territory, where
    /// the verify pass's k-fold weight/KV amortization has nothing left
    /// to amortize (compute, not the memory stream, is the bottleneck).
    /// Ties go to the smaller k, so the controller never drifts upward
    /// without a strict throughput reason.
    pub fn best_draft_len(
        &self,
        seqs: &[SeqCostInput],
        opt: &OptConfig,
        k_max: usize,
        acceptance: f64,
        shrink: f64,
    ) -> usize {
        if seqs.is_empty() || k_max == 0 {
            return 0;
        }
        let t1 = self.decode_step(seqs, opt, 0, seqs.len()).total_s;
        if t1 <= 0.0 {
            return 0;
        }
        let a = acceptance.clamp(0.0, 1.0);
        let mut best_k = 0usize;
        let mut best_rate = 1.0 / t1;
        for k in 1..=k_max {
            let spec_s = self.spec_round_s(seqs, opt, k, shrink);
            if spec_s <= 0.0 {
                continue;
            }
            let rate = expected_spec_commits(a, k) / spec_s;
            if rate > best_rate {
                best_rate = rate;
                best_k = k;
            }
        }
        best_k
    }

    /// Regime classification for a decode batch: `true` when the step is
    /// bound by the memory streams (weight restream + KV read — the
    /// regime speculation amortizes), `false` when the batched GEMM
    /// compute dominates (speculation is unwinnable there; Eq. 12 gains
    /// come from batching instead).
    pub fn decode_is_memory_bound(&self, seqs: &[SeqCostInput], opt: &OptConfig) -> bool {
        let c = self.decode_step(seqs, opt, 0, seqs.len());
        c.weights_mem_s + c.kv_mem_s >= c.compute_s
    }

    /// KV pool capacity in *blocks* once the GPTQ weights are resident
    /// (the memory-capacity coupling behind the paper's "13B gains more"
    /// pattern: bigger weights leave less pool, the baseline's FP16+MHA
    /// blocks are larger, so the baseline sustains fewer concurrent
    /// sequences — CoOpt's smaller blocks recover batch headroom).
    pub fn paper_pool_blocks(&self, opt: &OptConfig) -> usize {
        let weights = self.geom.param_count() * self.geom.weight_bits / 8.0;
        // runtime reserves activations/workspace (~15%)
        let free = (self.spec.device_memory_bytes - weights)
            .max(self.spec.device_memory_bytes * 0.05)
            * 0.85;
        let block_bytes =
            self.geom.kv_bytes_per_token_layer(opt) * self.geom.layers as f64
                * self.block_size as f64;
        (free / block_bytes) as usize
    }

    /// Scale the paper-scale pool down to the sim engine's geometry so the
    /// *engine itself* feels the capacity pressure.  `scale` is the fixed
    /// paper→sim divisor (DESIGN.md: 12), clamped to the sim pool bounds.
    pub fn sim_pool_blocks(&self, opt: &OptConfig, scale: f64, lo: usize, hi: usize) -> usize {
        ((self.paper_pool_blocks(opt) as f64 / scale) as usize).clamp(lo, hi)
    }

    /// Paper-scale bytes one swapped KV block carries over PCIe (FP8
    /// blocks move at half the bytes of FP16 — the Opt-KV read/write
    /// cost model applied to the interconnect).
    pub fn swap_block_bytes(&self, opt: &OptConfig) -> f64 {
        self.geom.kv_bytes_per_token_layer(opt)
            * self.geom.layers as f64
            * self.block_size as f64
            * self.ctx_scale
    }

    /// One-way host<->device transfer time for `blocks` KV blocks (the
    /// tier manager's swap-out or swap-in leg).
    pub fn swap_transfer(&self, blocks: usize, opt: &OptConfig) -> StepCost {
        if blocks == 0 {
            return StepCost::default();
        }
        let bytes = blocks as f64 * self.swap_block_bytes(opt);
        let total_s = bytes / self.spec.pcie_bandwidth_bytes_per_s + self.spec.swap_launch_s;
        StepCost {
            total_s,
            bytes_moved: bytes,
            overhead_s: self.spec.swap_launch_s,
            ..StepCost::default()
        }
    }

    /// The Opt-KV evict-vs-recompute decision: is a full swap round trip
    /// (out now + in later) of `blocks` cheaper than re-running the
    /// prefill of `tokens` committed tokens?  FP8 halves the transfer
    /// bytes, so the tiered path wins even more often under Opt-KV.
    pub fn swap_beats_recompute(&self, blocks: usize, tokens: usize, opt: &OptConfig) -> bool {
        if tokens == 0 {
            return false; // nothing to save
        }
        let round_trip = 2.0 * self.swap_transfer(blocks, opt).total_s;
        round_trip < self.prefill(tokens, opt).total_s
    }

    /// The cluster prefix directory's pull-vs-re-prefill decision: is
    /// moving `blocks` prefix blocks from another replica cheaper than
    /// re-prefilling their `tokens` tokens here?  Hierarchical by hit
    /// tier, like the Opt-KV ladder (device hit > host hit > miss): a
    /// *device*-resident prefix pays two PCIe legs (source export +
    /// destination import), a *host*-resident one only the import — its
    /// export already happened when the source swapped it out.  Priced
    /// per regime through the same transfer/prefill models as
    /// [`CostModel::swap_beats_recompute`].
    pub fn prefix_pull_pays(
        &self,
        blocks: usize,
        tokens: usize,
        host_tier: bool,
        opt: &OptConfig,
    ) -> bool {
        if blocks == 0 || tokens == 0 {
            return false; // nothing to move, nothing to save
        }
        let legs = if host_tier { 1.0 } else { 2.0 };
        let transfer = legs * self.swap_transfer(blocks, opt).total_s;
        transfer < self.prefill_chunk(tokens, 0, opt).total_s
    }

    /// Cost of one chunked-prefill window (Opt-Pa step 1): `chunk_len`
    /// tokens starting at `offset`, attending to all prior context.
    ///
    /// Each window streams the weights again — that is the overhead
    /// chunking trades for bounded decode stalls (a whole-prompt sum of
    /// window costs exceeds the one-shot cost, but no single window
    /// approaches it), and the prior-context KV is re-read through the
    /// Eq. 3 cache model.
    pub fn prefill_chunk(&self, chunk_len: usize, offset: usize, opt: &OptConfig) -> StepCost {
        let s = &self.spec;
        let g = &self.geom;
        let t = (chunk_len as f64 * self.ctx_scale).round().max(1.0);
        let prior = (offset as f64 * self.ctx_scale).round();

        let gemm_flops = 2.0 * g.param_count() * t;
        // window queries attend to the prior context plus the causal half
        // of the window itself
        let attn_flops =
            4.0 * g.n_heads as f64 * g.head_dim as f64 * (t * prior + t * t / 2.0);
        let compute_s = (gemm_flops + attn_flops) / (s.fp16_flops * s.gemm_eff);

        let weight_bytes = g.param_count() * g.weight_bits / 8.0;
        let weights_mem_s = weight_bytes / s.bandwidth_bytes_per_s;

        // chunked prefill writes exactly the window's tokens (the lazy
        // mapping never materializes padding ahead of the final window)
        let kv_tok_bytes = g.kv_bytes_per_token_layer(opt) * g.layers as f64;
        let write_bytes = t * kv_tok_bytes;
        let kv_read_bytes = prior * kv_tok_bytes;
        let kv_mem_s = kv_read_bytes / self.effective_kv_bandwidth(kv_read_bytes);
        let new_blocks = (t as usize).div_ceil(self.block_size);
        let alloc_s = new_blocks as f64
            * if opt.skip_filter {
                s.alloc_penalty_s * 0.25
            } else {
                s.alloc_penalty_s
            };
        let write_s = t * s.write_op_s + write_bytes / s.bandwidth_bytes_per_s;
        let overhead_s = alloc_s + write_s + s.pass_launch_s;

        let total_s = (weights_mem_s + kv_mem_s).max(compute_s) + overhead_s;
        StepCost {
            weights_mem_s,
            kv_mem_s,
            compute_s,
            overhead_s,
            total_s,
            bytes_moved: weight_bytes + write_bytes + kv_read_bytes,
            flops: gemm_flops + attn_flops,
        }
    }

    /// Cost of prefilling one sequence (`prompt_len` real tokens, padded
    /// to `padded_len` on the baseline write path).
    pub fn prefill(&self, prompt_len: usize, opt: &OptConfig) -> StepCost {
        let s = &self.spec;
        let g = &self.geom;
        let prompt_len = (prompt_len as f64 * self.ctx_scale).round() as usize;
        let t = prompt_len as f64;

        let gemm_flops = 2.0 * g.param_count() * t;
        let attn_flops = 4.0 * g.n_heads as f64 * g.head_dim as f64 * t * t / 2.0;
        let compute_s = (gemm_flops + attn_flops) / (s.fp16_flops * s.gemm_eff);

        let weight_bytes = g.param_count() * g.weight_bits / 8.0;
        let weights_mem_s = weight_bytes / s.bandwidth_bytes_per_s;

        // write path: baseline writes every padded position (Eq. 2
        // behaviour), Opt-KV writes exactly the prompt
        let padded = prompt_len.div_ceil(self.block_size) * self.block_size;
        let tokens_written = if opt.skip_filter {
            prompt_len
        } else {
            // pad to the serving max_seq analog: next pow2-ish chunk
            (padded.max(prompt_len)).next_power_of_two().min(4096)
        };
        let kv_tok_bytes = g.kv_bytes_per_token_layer(opt) * g.layers as f64;
        let write_bytes = tokens_written as f64 * kv_tok_bytes;
        let new_blocks = tokens_written.div_ceil(self.block_size);
        let alloc_s = new_blocks as f64
            * if opt.skip_filter {
                s.alloc_penalty_s * 0.25
            } else {
                s.alloc_penalty_s
            };
        let write_s =
            tokens_written as f64 * s.write_op_s + write_bytes / s.bandwidth_bytes_per_s;
        let overhead_s = alloc_s + write_s;

        let total_s = compute_s.max(weights_mem_s) + overhead_s;
        StepCost {
            weights_mem_s,
            kv_mem_s: 0.0,
            compute_s,
            overhead_s,
            total_s,
            bytes_moved: weight_bytes + write_bytes,
            flops: gemm_flops + attn_flops,
        }
    }

    /// How much cross-replica load imbalance ([`replica_imbalance`]) the
    /// router's prefix-affinity policy may cause before it abandons the
    /// cache-holding replica: the ratio of what one affinity hit saves (a
    /// one-block prefill window the prompt would otherwise recompute from
    /// scratch on a cold replica) to what skew costs (one extra decode
    /// round on the preferred replica before the cluster drains).  A
    /// cheap decode round relative to the saved prefill tolerates more
    /// skew; the clamp keeps degenerate geometries inside a sane band.
    pub fn affinity_imbalance_threshold(&self, opt: &OptConfig) -> f64 {
        let saved = self.prefill_chunk(self.block_size, 0, opt).total_s;
        let seq = SeqCostInput {
            ctx_len: self.block_size * 4,
            allocated_blocks: 4,
        };
        let round = self.decode_step(&[seq], opt, 1, 1).total_s;
        if round <= 0.0 || !saved.is_finite() {
            return 1.0;
        }
        (saved / round).clamp(0.25, 4.0)
    }
}

/// Normalized cross-replica load imbalance: `(max - min) / mean` of the
/// per-replica load scores; 0.0 for a single replica or an idle cluster.
/// The router's prefix-affinity fallback compares this (computed as if
/// the incoming request were placed on the prefix-holding replica)
/// against [`CostModel::affinity_imbalance_threshold`].
pub fn replica_imbalance(loads: &[f64]) -> f64 {
    if loads.len() <= 1 {
        return 0.0;
    }
    let mean = loads.iter().sum::<f64>() / loads.len() as f64;
    if mean <= 0.0 {
        return 0.0;
    }
    let max = loads.iter().cloned().fold(f64::MIN, f64::max);
    let min = loads.iter().cloned().fold(f64::MAX, f64::min);
    (max - min) / mean
}

/// Expected tokens a speculative round commits at per-position acceptance
/// `a` and draft length `k`: the geometric accepted prefix plus the
/// corrected/bonus token, `Σ_{i=0..k} a^i` (1 at k=0 — plain decode).
pub fn expected_spec_commits(acceptance: f64, k: usize) -> f64 {
    let a = acceptance.clamp(0.0, 1.0);
    (0..=k).map(|i| a.powi(i as i32)).sum()
}

/// Human-readable name of a decode regime (see
/// [`CostModel::decode_is_memory_bound`]); the `spec_regime` metrics
/// gauge and the bench rows use these strings.
pub fn regime_name(memory_bound: bool) -> &'static str {
    if memory_bound {
        "weight-stream-bound"
    } else {
        "gemm-bound"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{builtin_preset, ALL_CONFIGS, COOPT, OPTGQA, OPTKV, OPTPA, ORIGINAL};

    fn model() -> CostModel {
        CostModel::for_preset(&builtin_preset("llama-13b-sim").unwrap(), 16)
    }

    fn batch(ctx: usize, n: usize, padded_blocks: usize) -> Vec<SeqCostInput> {
        (0..n)
            .map(|_| SeqCostInput {
                ctx_len: ctx,
                allocated_blocks: padded_blocks,
            })
            .collect()
    }

    #[test]
    fn eq3_endpoints() {
        let m = model();
        assert_eq!(m.effective_latency_cycles(1.0), m.spec.t_cache_cycles);
        assert_eq!(m.effective_latency_cycles(0.0), m.spec.t_dram_cycles);
        let mid = m.effective_latency_cycles(0.5);
        assert!(mid > m.spec.t_cache_cycles && mid < m.spec.t_dram_cycles);
    }

    #[test]
    fn eq2_eq4_forms() {
        let m = model();
        assert_eq!(m.used_cache_tokens(5), 80);
        let load = m.kernel_load(8, 32);
        assert_eq!(load, 8.0 * 32.0 * 128.0 * 128.0);
    }

    #[test]
    fn coopt_beats_original_decode() {
        let m = model();
        // 8 seqs at ctx 512, baseline padded to 64 blocks (1024 tokens)
        let seqs = batch(512, 8, 64);
        let orig = m.decode_step(&seqs, &ORIGINAL, 1, 8);
        let coopt = m.decode_step(&seqs, &COOPT, 1, 8);
        assert!(coopt.total_s < orig.total_s);
        let gain = orig.total_s / coopt.total_s - 1.0;
        // the paper's end-to-end gains are 5-17%; per-step kernel gains
        // must be at least that (engine overheads dilute them)
        assert!(gain > 0.03, "gain {gain}");
    }

    #[test]
    fn each_opt_helps_individually() {
        let m = model();
        let seqs = batch(512, 8, 64);
        let orig = m.decode_step(&seqs, &ORIGINAL, 1, 8).total_s;
        for opt in [OPTKV, OPTGQA, OPTPA, COOPT] {
            let t = m.decode_step(&seqs, &opt, 1, 8).total_s;
            assert!(t < orig, "{} {t} vs {orig}", opt.name);
        }
    }

    #[test]
    fn capacity_coupling_favors_coopt_and_13b() {
        // the paper's headline ordering ("13B gains more") comes from
        // memory capacity: bigger weights -> smaller baseline KV pool,
        // and CoOpt's smaller blocks recover proportionally more batch
        let m7 = CostModel::for_preset(&builtin_preset("llama-7b-sim").unwrap(), 16);
        let m13 = model();
        let p7_orig = m7.paper_pool_blocks(&ORIGINAL);
        let p7_coopt = m7.paper_pool_blocks(&COOPT);
        let p13_orig = m13.paper_pool_blocks(&ORIGINAL);
        let p13_coopt = m13.paper_pool_blocks(&COOPT);
        assert!(p7_coopt > p7_orig && p13_coopt > p13_orig);
        assert!(p13_orig < p7_orig, "13B weights leave less pool");
        let r13 = p13_coopt as f64 / p13_orig as f64;
        let r7 = p7_coopt as f64 / p7_orig as f64;
        assert!(
            r13 > r7,
            "13B pool recovery {r13:.2} should exceed 7B {r7:.2}"
        );
        // and the sim-scale clamp keeps engines runnable
        let sim = m13.sim_pool_blocks(&ORIGINAL, 12.0, 16, 192);
        assert!((16..=192).contains(&sim));
    }

    #[test]
    fn optpa_gain_grows_with_padding_waste() {
        let m = model();
        // same ctx, increasing over-allocation: Opt-Pa's advantage grows
        let g = |alloc| {
            let seqs = batch(256, 8, alloc);
            let o = m.decode_step(&seqs, &ORIGINAL, 0, 8).total_s;
            let p = m.decode_step(&seqs, &OPTPA, 0, 8).total_s;
            o / p - 1.0
        };
        assert!(g(64) > g(20), "more padding => bigger Opt-Pa win");
    }

    #[test]
    fn chunked_prefill_bounds_stalls_but_costs_more_total() {
        let m = model();
        let one = m.prefill(512, &COOPT);
        let chunks: Vec<StepCost> = (0..4)
            .map(|i| m.prefill_chunk(128, i * 128, &COOPT))
            .collect();
        let sum: f64 = chunks.iter().map(|c| c.total_s).sum();
        // each window is far cheaper than the monolithic prefill (the
        // bounded decode stall)...
        for c in &chunks {
            assert!(c.total_s < one.total_s * 0.6, "{} vs {}", c.total_s, one.total_s);
        }
        // ...but the whole-prompt sum pays the per-chunk weight restream
        assert!(sum > one.total_s, "sum {sum} vs one-shot {}", one.total_s);
        // later windows re-read more prior KV
        assert!(chunks[3].kv_mem_s >= chunks[0].kv_mem_s);
        assert!(chunks[3].total_s >= chunks[0].total_s);
    }

    #[test]
    fn prefill_baseline_writes_more() {
        let m = model();
        let orig = m.prefill(200, &ORIGINAL);
        let opt = m.prefill(200, &OPTKV);
        assert!(opt.overhead_s < orig.overhead_s);
        assert!(opt.bytes_moved < orig.bytes_moved);
    }

    #[test]
    fn costs_monotone_in_context() {
        let m = model();
        for opt in ALL_CONFIGS {
            let t1 = m.decode_step(&batch(128, 4, 8), &opt, 0, 4).total_s;
            let t2 = m.decode_step(&batch(1024, 4, 64), &opt, 0, 4).total_s;
            assert!(t2 > t1, "{}", opt.name);
        }
    }

    #[test]
    fn empty_batch_is_free() {
        let m = model();
        assert_eq!(m.decode_step(&[], &ORIGINAL, 0, 0).total_s, 0.0);
    }

    #[test]
    fn verify_amortizes_the_kv_read_over_k_tokens() {
        let m = model();
        // small batch: the memory-bound regime where speculation matters
        let seqs = batch(512, 2, 32);
        let k = 4;
        let one = m.decode_step(&seqs, &COOPT, 0, 2);
        let verify = m.verify_batch(&seqs, &COOPT, k, 0, 2 * (k + 1));
        // the KV stream is read once either way...
        assert!((verify.kv_mem_s - one.kv_mem_s).abs() < 1e-12);
        // ...so a verify pass costs far less than k+1 sequential steps
        assert!(verify.total_s > one.total_s);
        assert!(
            verify.total_s < (k + 1) as f64 * one.total_s * 0.7,
            "verify {} vs {}x decode {}",
            verify.total_s,
            k + 1,
            one.total_s
        );
        // compute does scale with the extra query tokens
        assert!(verify.compute_s > one.compute_s * 2.0);
    }

    #[test]
    fn draft_cost_scales_with_k_and_shrink() {
        let m = model();
        let seqs = batch(256, 4, 16);
        let d2 = m.draft_step(&seqs, &COOPT, 2, 0.125);
        let d4 = m.draft_step(&seqs, &COOPT, 4, 0.125);
        assert!(d4.total_s > d2.total_s, "more drafts cost more");
        let big = m.draft_step(&seqs, &COOPT, 4, 0.5);
        assert!(big.total_s > d4.total_s, "a bigger draft model costs more");
        // a shrunk draft chain is cheaper than running the target k times
        let target_k = 4.0 * m.decode_step(&seqs, &COOPT, 0, 4).total_s;
        assert!(d4.total_s < target_k, "{} vs {}", d4.total_s, target_k);
        assert_eq!(m.draft_step(&[], &COOPT, 4, 0.125).total_s, 0.0);
        assert_eq!(m.draft_step(&seqs, &COOPT, 0, 0.125).total_s, 0.0);
    }

    #[test]
    fn spec_crossover_exists_and_speculation_wins_above_it() {
        let m = model().with_ctx_scale(8.0);
        // decode at small batch is weight-stream-bound on the Z100: the
        // regime where a verify pass amortizes the restream over k+1
        // commits (at large batch decode turns GEMM-bound and the
        // crossover rightly disappears)
        let seqs = batch(24, 2, 2);
        for k in [2usize, 4] {
            let a = m
                .spec_crossover_acceptance(&seqs, &COOPT, k, 0.125)
                .expect("a small draft model must be able to break even");
            assert!((0.0..1.0).contains(&a), "crossover {a} out of range");
            // throughput above the crossover beats one-token decode;
            // below it, loses
            let t1 = m.decode_step(&seqs, &COOPT, 0, 2).total_s;
            let spec = m.draft_step(&seqs, &COOPT, k, 0.125).total_s
                + m.verify_batch(&seqs, &COOPT, k, 0, 2 * (k + 1)).total_s;
            let committed = |alpha: f64| (0..=k).map(|i| alpha.powi(i as i32)).sum::<f64>();
            let hi = (a + 0.1).min(1.0);
            assert!(committed(hi) / spec >= 1.0 / t1 * 0.999);
            if a > 0.1 {
                assert!(committed(a - 0.1) / spec < 1.0 / t1);
            }
        }
        // an oversized draft model can make speculation unwinnable
        let heavy = m.spec_crossover_acceptance(&seqs, &COOPT, 1, 1.0);
        if let Some(a) = heavy {
            assert!(a > 0.5, "a full-size draft should need near-perfect acceptance");
        }
    }

    /// The engine's operating point (7B preset, ShareGPT ctx scale): the
    /// landscape the adaptive controller navigates.
    fn engine_model() -> CostModel {
        CostModel::for_preset(&builtin_preset("llama-7b-sim").unwrap(), 16).with_ctx_scale(8.0)
    }

    #[test]
    fn best_draft_len_tracks_acceptance_at_small_batch() {
        let m = engine_model();
        let seqs = batch(24, 1, 2);
        // the lone-lane decode is deep in the weight-stream-bound regime:
        // longer drafts amortize the restream harder as acceptance rises
        assert!(m.decode_is_memory_bound(&seqs, &COOPT));
        let k_lo = m.best_draft_len(&seqs, &COOPT, 4, 0.3, 0.125);
        let k_mid = m.best_draft_len(&seqs, &COOPT, 4, 0.5, 0.125);
        let k_hi = m.best_draft_len(&seqs, &COOPT, 4, 0.9, 0.125);
        assert_eq!(k_lo, 1, "low acceptance still pays at batch 1");
        assert_eq!(k_mid, 2);
        assert_eq!(k_hi, 4, "high acceptance saturates k_max");
        assert!(k_lo <= k_mid && k_mid <= k_hi, "monotone in acceptance");
        // hopeless drafts are not worth a verify pass
        assert_eq!(m.best_draft_len(&seqs, &COOPT, 4, 0.0, 0.125), 0);
        // degenerate inputs
        assert_eq!(m.best_draft_len(&[], &COOPT, 4, 0.9, 0.125), 0);
        assert_eq!(m.best_draft_len(&seqs, &COOPT, 0, 0.9, 0.125), 0);
    }

    #[test]
    fn best_draft_len_shrinks_with_batch_and_hits_zero_when_gemm_bound() {
        let m = engine_model();
        // growing the batch amortizes the weight stream across lanes, so
        // the optimal draft length falls: 4 -> 2 -> 1 -> 0
        let k1 = m.best_draft_len(&batch(24, 1, 2), &COOPT, 4, 0.9, 0.125);
        let k2 = m.best_draft_len(&batch(24, 2, 2), &COOPT, 4, 0.9, 0.125);
        let k3 = m.best_draft_len(&batch(24, 3, 2), &COOPT, 4, 0.9, 0.125);
        let k6 = m.best_draft_len(&batch(24, 6, 2), &COOPT, 4, 0.9, 0.125);
        assert_eq!((k1, k2, k3), (4, 2, 1));
        assert_eq!(k6, 0, "GEMM-bound batch: speculation unwinnable");
        // ...and the regime detector agrees with the boundary
        assert!(m.decode_is_memory_bound(&batch(24, 3, 2), &COOPT));
        assert!(!m.decode_is_memory_bound(&batch(24, 6, 2), &COOPT));
        assert!(!m.decode_is_memory_bound(&batch(24, 8, 2), &COOPT));
        // even perfect acceptance cannot save the GEMM-bound batch
        assert_eq!(m.best_draft_len(&batch(24, 8, 2), &COOPT, 4, 1.0, 0.125), 0);
        assert_eq!(regime_name(true), "weight-stream-bound");
        assert_eq!(regime_name(false), "gemm-bound");
    }

    #[test]
    fn best_draft_len_consistent_with_crossover() {
        let m = engine_model();
        let seqs = batch(24, 2, 2);
        for k in [1usize, 2, 4] {
            let cross = m
                .spec_crossover_acceptance(&seqs, &COOPT, k, 0.125)
                .expect("crossover exists at small batch");
            // above the crossover, *some* draft length must beat decode
            // (k itself breaks even there; the search can prefer another)
            assert!(
                m.best_draft_len(&seqs, &COOPT, 4, (cross + 0.05).min(1.0), 0.125) > 0,
                "k={k}"
            );
        }
        assert!((expected_spec_commits(0.0, 4) - 1.0).abs() < 1e-12);
        assert!((expected_spec_commits(1.0, 4) - 5.0).abs() < 1e-12);
        assert!((expected_spec_commits(0.5, 2) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn swap_transfer_scales_and_fp8_halves_bytes() {
        let m = model();
        let one = m.swap_transfer(4, &ORIGINAL);
        let two = m.swap_transfer(8, &ORIGINAL);
        assert!(two.total_s > one.total_s);
        assert!((two.bytes_moved - 2.0 * one.bytes_moved).abs() < 1.0);
        // FP8 blocks swap at roughly half the FP16 bytes (scales add a
        // little): the Opt-KV traffic saving extends to the PCIe link
        let fp16 = m.swap_block_bytes(&OPTGQA);
        let fp8 = m.swap_block_bytes(&COOPT);
        assert!(fp8 < 0.6 * fp16, "fp8 {fp8} vs fp16 {fp16}");
        assert_eq!(m.swap_transfer(0, &COOPT).total_s, 0.0);
    }

    #[test]
    fn swap_beats_recompute_for_realistic_victims() {
        // a preempted decode sequence: tens of committed tokens across a
        // handful of blocks — the PCIe round trip is orders of magnitude
        // cheaper than re-running the paper-scale prefill (the
        // arXiv:2504.06319 / 2604.05012 observation Opt-KV banks on)
        let m = model().with_ctx_scale(8.0);
        for opt in [ORIGINAL, COOPT] {
            assert!(m.swap_beats_recompute(4, 48, &opt), "{}", opt.name);
        }
        // nothing committed => nothing to save
        assert!(!m.swap_beats_recompute(0, 0, &COOPT));
    }

    #[test]
    fn replica_imbalance_measures_spread() {
        assert_eq!(replica_imbalance(&[]), 0.0);
        assert_eq!(replica_imbalance(&[7.0]), 0.0, "one replica is balanced");
        assert_eq!(replica_imbalance(&[0.0, 0.0, 0.0]), 0.0, "idle cluster");
        assert_eq!(replica_imbalance(&[5.0, 5.0, 5.0]), 0.0);
        // (max - min) / mean: 4 replicas at [3, 1, 1, 3] -> 2 / 2 = 1
        assert!((replica_imbalance(&[3.0, 1.0, 1.0, 3.0]) - 1.0).abs() < 1e-12);
        // one wedged replica dominates
        assert!(replica_imbalance(&[10.0, 0.0]) > 1.9);
    }

    #[test]
    fn affinity_threshold_is_finite_and_clamped() {
        for opt in ALL_CONFIGS {
            let t = model().with_ctx_scale(8.0).affinity_imbalance_threshold(&opt);
            assert!((0.25..=4.0).contains(&t), "{}: threshold {t}", opt.name);
        }
    }
}
