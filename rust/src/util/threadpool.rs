//! Fixed-size worker pool over std threads + channels (the async substrate;
//! tokio is unavailable offline).
//!
//! Used by the HTTP server (connection handling) and the benchmark harness
//! (parallel client load generation).  Jobs are boxed closures; `join`
//! blocks until the queue drains.  Panics in jobs are contained per-worker
//! and surfaced as a counter rather than poisoning the pool.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    pending: Mutex<usize>,
    all_done: Condvar,
    panics: AtomicUsize,
}

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            pending: Mutex::new(0),
            all_done: Condvar::new(),
            panics: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("coopt-worker-{i}"))
                    .spawn(move || worker_loop(rx, shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            shared,
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Queue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        {
            let mut p = self.shared.pending.lock().unwrap();
            *p += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Block until every queued job has finished.
    pub fn join(&self) {
        let mut p = self.shared.pending.lock().unwrap();
        while *p > 0 {
            p = self.shared.all_done.wait(p).unwrap();
        }
    }

    /// Jobs that panicked so far.
    pub fn panic_count(&self) -> usize {
        self.shared.panics.load(Ordering::Relaxed)
    }

    /// Run a closure over each item of an owned vec in parallel, returning
    /// results in input order (scoped scatter/gather convenience).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx.iter() {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("job completed")).collect()
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>, shared: Arc<Shared>) {
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match job {
            Ok(job) => {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    shared.panics.fetch_add(1, Ordering::Relaxed);
                }
                let mut p = shared.pending.lock().unwrap();
                *p -= 1;
                if *p == 0 {
                    shared.all_done.notify_all();
                }
            }
            Err(_) => return, // sender dropped: shutdown
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn contains_panics() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.execute(|| {});
        pool.join();
        assert_eq!(pool.panic_count(), 1);
        // pool still functional
        let c = Arc::new(AtomicU64::new(0));
        let c2 = Arc::clone(&c);
        pool.execute(move || {
            c2.fetch_add(1, Ordering::Relaxed);
        });
        pool.join();
        assert_eq!(c.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool);
        assert_eq!(c.load(Ordering::Relaxed), 10);
    }
}
