//! Declarative command-line flag parsing (clap replacement).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, defaults,
//! required flags, and auto-generated `--help`.

use anyhow::{bail, Result};

#[derive(Debug, Clone)]
struct Spec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_bool: bool,
    required: bool,
}

/// Builder + result of a parse.  Typical use:
///
/// ```ignore
/// let mut cli = Cli::new("arc_eval", "Reproduce Tables 1-2");
/// cli.flag("set", "easy", "eval split: easy|challenge");
/// cli.flag("models", "all", "comma-separated model list");
/// cli.bool_flag("verbose", "log per-question scores");
/// let args = cli.parse_or_exit();
/// let split = args.get("set");
/// ```
#[derive(Debug, Clone)]
pub struct Cli {
    prog: &'static str,
    about: &'static str,
    specs: Vec<Spec>,
    positionals: Vec<String>,
}

impl Cli {
    pub fn new(prog: &'static str, about: &'static str) -> Self {
        Cli {
            prog,
            about,
            specs: Vec::new(),
            positionals: Vec::new(),
        }
    }

    /// Optional flag with a default value.
    pub fn flag(&mut self, name: &'static str, default: &str, help: &'static str) -> &mut Self {
        self.specs.push(Spec {
            name,
            help,
            default: Some(default.to_string()),
            is_bool: false,
            required: false,
        });
        self
    }

    /// Required flag (no default).
    pub fn req_flag(&mut self, name: &'static str, help: &'static str) -> &mut Self {
        self.specs.push(Spec {
            name,
            help,
            default: None,
            is_bool: false,
            required: true,
        });
        self
    }

    /// Boolean switch (absent = false).
    pub fn bool_flag(&mut self, name: &'static str, help: &'static str) -> &mut Self {
        self.specs.push(Spec {
            name,
            help,
            default: Some("false".to_string()),
            is_bool: true,
            required: false,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} [FLAGS]\n\nFLAGS:\n", self.prog, self.about, self.prog);
        for spec in &self.specs {
            let d = match (&spec.default, spec.is_bool) {
                (_, true) => String::new(),
                (Some(d), _) => format!(" [default: {d}]"),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, d));
        }
        s.push_str("  --help               print this message\n");
        s
    }

    /// Parse an explicit argv (without the program name).
    pub fn parse_args(&mut self, argv: &[String]) -> Result<Args> {
        let mut values: Vec<(String, String)> = self
            .specs
            .iter()
            .filter_map(|s| s.default.clone().map(|d| (s.name.to_string(), d)))
            .collect();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown flag --{name}\n\n{}", self.usage()))?;
                let value = if spec.is_bool {
                    match inline_val {
                        Some(v) => v,
                        None => "true".to_string(),
                    }
                } else {
                    match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                                .clone()
                        }
                    }
                };
                values.retain(|(n, _)| n != &name);
                values.push((name, value));
            } else {
                self.positionals.push(arg.clone());
            }
            i += 1;
        }
        for spec in &self.specs {
            if spec.required && !values.iter().any(|(n, _)| n == spec.name) {
                bail!("missing required flag --{}\n\n{}", spec.name, self.usage());
            }
        }
        Ok(Args {
            values,
            positionals: std::mem::take(&mut self.positionals),
        })
    }

    /// Parse `std::env::args()`, printing help/errors and exiting on failure.
    pub fn parse_or_exit(&mut self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_args(&argv) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct Args {
    values: Vec<(String, String)>,
    pub positionals: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
            .unwrap_or_else(|| panic!("flag --{name} was never declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an unsigned integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.get(name) == "true"
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let mut cli = Cli::new("t", "test");
        cli.flag("a", "1", "").flag("b", "x", "").bool_flag("v", "");
        let args = cli.parse_args(&argv(&["--a", "5", "--v"])).unwrap();
        assert_eq!(args.get_usize("a"), 5);
        assert_eq!(args.get("b"), "x");
        assert!(args.get_bool("v"));
    }

    #[test]
    fn equals_syntax() {
        let mut cli = Cli::new("t", "test");
        cli.flag("n", "0", "");
        let args = cli.parse_args(&argv(&["--n=42"])).unwrap();
        assert_eq!(args.get_usize("n"), 42);
    }

    #[test]
    fn required_enforced() {
        let mut cli = Cli::new("t", "test");
        cli.req_flag("must", "");
        assert!(cli.parse_args(&argv(&[])).is_err());
        let mut cli2 = Cli::new("t", "test");
        cli2.req_flag("must", "");
        assert!(cli2.parse_args(&argv(&["--must", "y"])).is_ok());
    }

    #[test]
    fn unknown_flag_rejected() {
        let mut cli = Cli::new("t", "test");
        cli.flag("a", "1", "");
        assert!(cli.parse_args(&argv(&["--zzz", "1"])).is_err());
    }

    #[test]
    fn lists_and_positionals() {
        let mut cli = Cli::new("t", "test");
        cli.flag("models", "a,b", "");
        let args = cli.parse_args(&argv(&["pos1", "--models", "x,y,z"])).unwrap();
        assert_eq!(args.get_list("models"), ["x", "y", "z"]);
        assert_eq!(args.positionals, ["pos1"]);
    }
}
