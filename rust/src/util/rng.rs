//! Deterministic pseudo-random numbers + the distributions the workload
//! generator needs (rand/rand_distr replacement).
//!
//! Core generator is xoshiro256++ seeded via SplitMix64 — fast, good
//! statistical quality, trivially reproducible across runs and languages.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng {
            s,
            spare_normal: None,
        }
    }

    /// Independent child stream (for per-request/per-thread determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless bounded sampling.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (caching the pair's second value).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Log-normal with the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (inter-arrival times of a Poisson process).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Zipf-like rank sampling over [0, n) with exponent `s` (used for
    /// shared-prefix popularity in the workload generator).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-CDF on the harmonic weights; n is small in our use
        let mut total = 0.0;
        for k in 1..=n {
            total += (k as f64).powf(-s);
        }
        let mut target = self.f64() * total;
        for k in 1..=n {
            target -= (k as f64).powf(-s);
            if target <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            let v = r.range(-2, 2);
            assert!((-2..=2).contains(&v));
            lo_seen |= v == -2;
            hi_seen |= v == 2;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.lognormal(3.0, 1.0) > 0.0);
        }
    }

    #[test]
    fn zipf_skews_low_ranks() {
        let mut r = Rng::new(6);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[5]);
        assert!(counts[0] > counts[9]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(8);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}
