//! Leveled logging to stderr (log/env_logger replacement).
//!
//! Level from `LLM_COOPT_LOG` (error|warn|info|debug|trace), default info.
//! Timestamps are milliseconds since logger init — enough to correlate
//! with the metrics module without pulling in a clock-formatting crate.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("LLM_COOPT_LOG") {
        set_level(match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        });
    }
}

impl Level {
    /// Parse a `--log-level` value (error|warn|info|debug|trace).
    pub fn parse(s: &str) -> anyhow::Result<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Ok(Level::Error),
            "warn" => Ok(Level::Warn),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            "trace" => Ok(Level::Trace),
            other => Err(anyhow::anyhow!(
                "unknown log level '{other}' (expected error|warn|info|debug|trace)"
            )),
        }
    }
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Seconds since logger init (the timestamp base of every log line),
/// for structured events that want the same clock.
pub fn elapsed_s() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:>9.3}s {} {}] {}", t.as_secs_f64(), tag, module, msg);
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        init();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
