//! Summary statistics shared by the metrics module and the bench harness.

/// Online mean/variance (Welford) plus a reservoir of raw samples for
/// percentile queries.  For our workload sizes (<= a few hundred thousand
/// samples) we keep everything; `percentile` sorts lazily.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.sorted = false;
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let d = x - self.mean;
        self.mean += d / n;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            0.0
        } else {
            (self.m2 / (self.samples.len() - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let n = self.samples.len();
        let rank = (q / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi.min(n - 1)] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p90(&mut self) -> f64 {
        self.percentile(90.0)
    }
    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }
    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

/// Fixed-bucket histogram for cheap steady-state collection (latency in
/// microseconds by default).  Buckets are exponential: [0, base),
/// [base, base*growth), ...
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl Histogram {
    pub fn exponential(base: f64, growth: f64, buckets: usize) -> Self {
        assert!(base > 0.0 && growth > 1.0 && buckets >= 2);
        let mut bounds = Vec::with_capacity(buckets);
        let mut b = base;
        for _ in 0..buckets {
            bounds.push(b);
            b *= growth;
        }
        Histogram {
            counts: vec![0; buckets + 1],
            bounds,
            total: 0,
            sum: 0.0,
        }
    }

    pub fn record(&mut self, x: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| x < b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += x;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Upper-bound estimate of the q-th percentile (bucket boundary).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q / 100.0 * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    f64::INFINITY
                };
            }
        }
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.sum(), 40.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for i in 0..101 {
            s.add(i as f64);
        }
        assert!((s.p50() - 50.0).abs() < 1e-9);
        assert!((s.percentile(0.0) - 0.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.p90() - 90.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::exponential(1.0, 2.0, 10);
        for x in [0.5, 1.5, 3.0, 100.0, 2000.0] {
            h.record(x);
        }
        assert_eq!(h.count(), 5);
        assert!(h.percentile(10.0) <= h.percentile(90.0));
        assert!((h.mean() - 421.0).abs() < 1.0);
    }
}
