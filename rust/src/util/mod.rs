//! Offline-environment substrates.
//!
//! The build environment has no crates.io access beyond the `xla` crate's
//! closure, so the usual ecosystem pieces are implemented here as real,
//! tested modules (DESIGN.md "Offline-toolchain substitutions"):
//!
//! * [`json`] — serde_json replacement (parser + writer + accessors)
//! * [`rng`] — rand replacement (SplitMix64/xoshiro256++, distributions)
//! * [`fp8`] — E4M3FN codec, bit-compatible with the python/Pallas codec
//! * [`cli`] — clap replacement (declarative flag parser)
//! * [`logging`] — log/env_logger replacement
//! * [`threadpool`] — tokio replacement for our needs (pool + scoped jobs)
//! * [`bench`] — criterion replacement (warmup + stats harness)
//! * [`quickprop`] — proptest replacement (randomized properties + shrinking)
//! * [`stats`] — histograms/percentiles shared by metrics and bench

pub mod bench;
pub mod cli;
pub mod fp8;
pub mod json;
pub mod logging;
pub mod quickprop;
pub mod rng;
pub mod stats;
pub mod threadpool;
