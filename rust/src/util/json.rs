//! Minimal-but-complete JSON: parser, writer, and ergonomic accessors.
//!
//! Replaces serde_json in this offline environment.  Supports the full JSON
//! grammar (RFC 8259): objects, arrays, strings with escapes (incl. \uXXXX
//! and surrogate pairs), numbers, bools, null.  Object key order is
//! preserved (insertion order) so manifests round-trip stably.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON value.  Objects keep a side vector of keys to preserve order.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(Object),
}

/// Insertion-ordered string map.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Object {
    map: BTreeMap<String, Value>,
    order: Vec<String>,
}

impl Object {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, val: impl Into<Value>) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.order.push(key.clone());
        }
        self.map.insert(key, val.into());
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Value)> {
        self.order.iter().map(move |k| (k.as_str(), &self.map[k]))
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u32> for Value {
    fn from(n: u32) -> Self {
        Value::Num(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<Vec<Value>> for Value {
    fn from(v: Vec<Value>) -> Self {
        Value::Array(v)
    }
}
impl From<Object> for Value {
    fn from(o: Object) -> Self {
        Value::Object(o)
    }
}

impl Value {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(o) => o.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns an error naming the path.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing JSON key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&Object> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow!("JSON key '{key}' is not a string"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("JSON key '{key}' is not a non-negative integer"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow!("JSON key '{key}' is not a number"))
    }

    pub fn req_bool(&self, key: &str) -> Result<bool> {
        self.req(key)?
            .as_bool()
            .ok_or_else(|| anyhow!("JSON key '{key}' is not a bool"))
    }

    pub fn req_array(&self, key: &str) -> Result<&[Value]> {
        self.req(key)?
            .as_array()
            .ok_or_else(|| anyhow!("JSON key '{key}' is not an array"))
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(*n, out),
            Value::Str(s) => write_str(s, out),
            Value::Array(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Value::Object(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Value::Array(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 1 {
                        out.push(' ');
                    }
                    item.write_pretty(out, indent + 1);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Value::Object(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 1 {
                        out.push(' ');
                    }
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!(
                "expected '{}' at byte {}, got '{}'",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of JSON"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut obj = Object::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => break,
                c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.pos - 1, c as char),
            }
        }
        Ok(Value::Object(obj))
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => break,
                c => bail!("expected ',' or ']' at byte {}, got '{}'", self.pos - 1, c as char),
            }
        }
        Ok(Value::Array(items))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                bail!("invalid low surrogate");
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        s.push(
                            char::from_u32(cp)
                                .ok_or_else(|| anyhow!("invalid \\u escape"))?,
                        );
                    }
                    c => bail!("invalid escape '\\{}'", c as char),
                },
                _ => {
                    // copy raw UTF-8 bytes of this char
                    let start = self.pos - 1;
                    let width = utf8_width(b);
                    self.pos = start + width;
                    if self.pos > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| anyhow!("invalid UTF-8 in string"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump()?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| anyhow!("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow!("invalid number '{text}' at byte {start}"))?;
        Ok(Value::Num(n))
    }
}

fn utf8_width(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v, "{s}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.req("a").unwrap().as_array().unwrap()[2]
                .req_str("b")
                .unwrap(),
            "c"
        );
        assert_eq!(v.req("d").unwrap(), &Value::Null);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\n\t\"\\A😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\A😀");
        // writer round-trip
        let w = Value::Str("a\n\"x\\\u{1}".into()).to_string();
        assert_eq!(parse(&w).unwrap().as_str().unwrap(), "a\n\"x\\\u{1}");
    }

    #[test]
    fn object_order_preserved() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = v.as_object().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "\"abc", "{\"a\" 1}", "tru", "1 2", "{\"a\":}"] {
            assert!(parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(parse("-2.5e-2").unwrap().as_f64().unwrap(), -0.025);
        assert_eq!(parse("123456789").unwrap().as_usize().unwrap(), 123456789);
        assert_eq!(parse("1.5").unwrap().as_usize(), None);
        assert_eq!(parse("-1").unwrap().as_usize(), None);
    }

    #[test]
    fn pretty_round_trip() {
        let mut o = Object::new();
        o.insert("name", "x");
        o.insert("vals", Value::Array(vec![1i64.into(), 2i64.into()]));
        let v = Value::Object(o);
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }
}
