//! Benchmark harness (criterion replacement) for `cargo bench` targets
//! declared with `harness = false`.
//!
//! Each bench binary builds a [`BenchSuite`], registers closures, and the
//! harness handles warmup, adaptive iteration counts, and a stable report:
//!
//! ```text
//! bench                         iters      mean        p50        p99    thrpt
//! fig6/llama-13b-sim/coopt         20   41.2 ms    40.9 ms    44.0 ms   777/s
//! ```
//!
//! Results can also be dumped as JSON for EXPERIMENTS.md tooling.

use std::time::{Duration, Instant};

use super::json::{Object, Value};
use super::stats::Summary;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub std_s: f64,
    /// optional user-reported units/iteration (e.g. tokens) for throughput
    pub units_per_iter: f64,
    /// optional free-form extras for the JSON report
    pub extra: Object,
}

pub struct BenchSuite {
    pub name: &'static str,
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(name: &'static str) -> Self {
        BenchSuite {
            name,
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 10_000,
            results: Vec::new(),
        }
    }

    pub fn quick(name: &'static str) -> Self {
        let mut s = Self::new(name);
        s.warmup = Duration::from_millis(50);
        s.measure = Duration::from_millis(400);
        s
    }

    /// Benchmark `f`, timing each call.
    pub fn bench<F: FnMut()>(&mut self, name: impl Into<String>, mut f: F) -> &BenchResult {
        self.bench_units(name, 1.0, &mut f)
    }

    /// Benchmark with a units-per-iteration count for throughput reporting.
    pub fn bench_units<F: FnMut()>(
        &mut self,
        name: impl Into<String>,
        units_per_iter: f64,
        f: &mut F,
    ) -> &BenchResult {
        let name = name.into();
        // warmup
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup && warm_iters < self.max_iters {
            f();
            warm_iters += 1;
        }
        // measure
        let mut s = Summary::new();
        let m0 = Instant::now();
        let mut iters = 0usize;
        while (m0.elapsed() < self.measure || iters < self.min_iters)
            && iters < self.max_iters
        {
            let t0 = Instant::now();
            f();
            s.add(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        let r = BenchResult {
            name,
            iters,
            mean_s: s.mean(),
            p50_s: s.p50(),
            p99_s: s.p99(),
            std_s: s.std(),
            units_per_iter,
            extra: Object::new(),
        };
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Record an externally-measured result (for harnesses that manage
    /// their own loop, e.g. whole serving runs).
    pub fn record(&mut self, name: impl Into<String>, samples: &[f64], units_per_iter: f64) {
        let mut s = Summary::new();
        for &x in samples {
            s.add(x);
        }
        self.results.push(BenchResult {
            name: name.into(),
            iters: samples.len(),
            mean_s: s.mean(),
            p50_s: s.p50(),
            p99_s: s.p99(),
            std_s: s.std(),
            units_per_iter,
            extra: Object::new(),
        });
    }

    pub fn last_extra(&mut self) -> &mut Object {
        &mut self.results.last_mut().expect("a result").extra
    }

    pub fn report(&self) {
        println!("\n== {} ==", self.name);
        println!(
            "{:<44} {:>6} {:>12} {:>12} {:>12} {:>12}",
            "bench", "iters", "mean", "p50", "p99", "thrpt"
        );
        for r in &self.results {
            let thrpt = if r.units_per_iter > 0.0 && r.mean_s > 0.0 {
                format!("{:.1}/s", r.units_per_iter / r.mean_s)
            } else {
                "-".to_string()
            };
            println!(
                "{:<44} {:>6} {:>12} {:>12} {:>12} {:>12}",
                r.name,
                r.iters,
                fmt_dur(r.mean_s),
                fmt_dur(r.p50_s),
                fmt_dur(r.p99_s),
                thrpt
            );
        }
    }

    pub fn to_json(&self) -> Value {
        let mut arr = Vec::new();
        for r in &self.results {
            let mut o = Object::new();
            o.insert("name", r.name.as_str());
            o.insert("iters", r.iters);
            o.insert("mean_s", r.mean_s);
            o.insert("p50_s", r.p50_s);
            o.insert("p99_s", r.p99_s);
            o.insert("std_s", r.std_s);
            o.insert("units_per_iter", r.units_per_iter);
            o.insert("extra", Value::Object(r.extra.clone()));
            arr.push(Value::Object(o));
        }
        let mut top = Object::new();
        top.insert("suite", self.name);
        top.insert("results", Value::Array(arr));
        Value::Object(top)
    }

    /// Write the JSON report under target/bench-reports/.
    pub fn write_json(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("target/bench-reports");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string_pretty())?;
        Ok(path)
    }
}

pub fn fmt_dur(secs: f64) -> String {
    if !secs.is_finite() {
        "-".to_string()
    } else if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// `black_box` substitute: defeat the optimizer without unstable features.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut suite = BenchSuite::quick("selftest");
        suite.min_iters = 3;
        let mut acc = 0u64;
        suite.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(suite.results.len(), 1);
        let r = &suite.results[0];
        assert!(r.iters >= 3);
        assert!(r.mean_s >= 0.0);
        let j = suite.to_json();
        assert_eq!(j.req_str("suite").unwrap(), "selftest");
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_dur(2e-9).ends_with("ns"));
        assert!(fmt_dur(2e-6).ends_with("µs"));
        assert!(fmt_dur(2e-3).ends_with("ms"));
        assert!(fmt_dur(2.0).ends_with("s"));
    }
}
