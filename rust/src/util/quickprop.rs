//! Mini property-testing framework (proptest replacement).
//!
//! Generates random inputs from composable strategies, runs the property,
//! and on failure greedily shrinks the input before reporting.  Used for
//! the coordinator invariants (routing, batching, allocator state) in
//! rust/tests/prop_coordinator.rs and for module-level properties.
//!
//! ```ignore
//! quickprop::check(200, gens::vec(gens::usize_to(100), 0..=32), |xs| {
//!     let mut ys = xs.clone(); ys.sort(); ys.len() == xs.len()
//! });
//! ```

use super::rng::Rng;

/// A generator of values plus a shrinker.
pub struct Strategy<T> {
    pub gen: Box<dyn Fn(&mut Rng) -> T>,
    /// Produce strictly "smaller" candidates (possibly empty).
    pub shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

/// Run `prop` on `cases` random inputs; panic with the (shrunk) minimal
/// counterexample on failure.
pub fn check<T: Clone + std::fmt::Debug>(
    cases: usize,
    strat: Strategy<T>,
    prop: impl Fn(&T) -> bool,
) {
    check_seeded(0xC0FFEE, cases, strat, prop)
}

pub fn check_seeded<T: Clone + std::fmt::Debug>(
    seed: u64,
    cases: usize,
    strat: Strategy<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = (strat.gen)(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(input, &strat, &prop);
            panic!(
                "property failed (case {case}/{cases}, seed {seed:#x});\n\
                 minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<T: Clone + std::fmt::Debug>(
    mut failing: T,
    strat: &Strategy<T>,
    prop: &impl Fn(&T) -> bool,
) -> T {
    // greedy descent, bounded to avoid pathological shrinkers
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in (strat.shrink)(&failing) {
            if !prop(&cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

/// Ready-made strategies.
pub mod gens {
    use super::*;

    /// usize in [0, hi].
    pub fn usize_to(hi: usize) -> Strategy<usize> {
        Strategy {
            gen: Box::new(move |r| r.below(hi + 1)),
            shrink: Box::new(|&v| {
                let mut c = Vec::new();
                if v > 0 {
                    c.push(0);
                    c.push(v / 2);
                    c.push(v - 1);
                }
                c.dedup();
                c
            }),
        }
    }

    /// i64 in [lo, hi].
    pub fn i64_in(lo: i64, hi: i64) -> Strategy<i64> {
        Strategy {
            gen: Box::new(move |r| r.range(lo, hi)),
            shrink: Box::new(move |&v| {
                let mut c = Vec::new();
                let anchor = lo.max(0).min(hi);
                if v != anchor {
                    c.push(anchor);
                    c.push(anchor + (v - anchor) / 2);
                    c.push(v - (v - anchor).signum());
                }
                c.retain(|&x| (lo..=hi).contains(&x) && x != v);
                c.dedup();
                c
            }),
        }
    }

    /// Vec of T with length in `len`.
    pub fn vec<T: Clone + 'static>(
        elem: Strategy<T>,
        len: std::ops::RangeInclusive<usize>,
    ) -> Strategy<Vec<T>> {
        let (lo, hi) = (*len.start(), *len.end());
        let elem = std::rc::Rc::new(elem);
        let elem2 = std::rc::Rc::clone(&elem);
        Strategy {
            gen: Box::new(move |r| {
                let n = lo + r.below(hi - lo + 1);
                (0..n).map(|_| (elem.gen)(r)).collect()
            }),
            shrink: Box::new(move |v: &Vec<T>| {
                let mut out = Vec::new();
                // drop halves, drop one element, shrink one element
                if v.len() > lo {
                    out.push(v[..v.len() / 2.max(lo)].to_vec());
                    let mut one_less = v.clone();
                    one_less.pop();
                    out.push(one_less);
                }
                for i in 0..v.len().min(4) {
                    for cand in (elem2.shrink)(&v[i]) {
                        let mut w = v.clone();
                        w[i] = cand;
                        out.push(w);
                    }
                }
                out.retain(|w| w.len() >= lo);
                out
            }),
        }
    }

    /// Pair of independent strategies.
    pub fn pair<A: Clone + 'static, B: Clone + 'static>(
        a: Strategy<A>,
        b: Strategy<B>,
    ) -> Strategy<(A, B)> {
        let (ag, ash) = (std::rc::Rc::new(a.gen), std::rc::Rc::new(a.shrink));
        let (bg, bsh) = (std::rc::Rc::new(b.gen), std::rc::Rc::new(b.shrink));
        let (ag2, bg2) = (std::rc::Rc::clone(&ag), std::rc::Rc::clone(&bg));
        let _ = (ag2, bg2);
        Strategy {
            gen: Box::new(move |r| ((ag)(r), (bg)(r))),
            shrink: Box::new(move |(x, y)| {
                let mut out: Vec<(A, B)> = Vec::new();
                for c in (ash)(x) {
                    out.push((c, y.clone()));
                }
                for c in (bsh)(y) {
                    out.push((x.clone(), c));
                }
                out
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        check(100, gens::usize_to(1000), |&x| x <= 1000);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn fails_and_shrinks() {
        check(500, gens::usize_to(1000), |&x| x < 500);
    }

    #[test]
    fn shrinks_to_boundary() {
        // capture the panic message and check the shrunk value is minimal
        let result = std::panic::catch_unwind(|| {
            check(500, gens::usize_to(1000), |&x| x < 500);
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().expect("string panic"),
            Ok(_) => panic!("property should fail"),
        };
        assert!(msg.contains("500"), "shrunk to boundary: {msg}");
    }

    #[test]
    fn vec_strategy_respects_len() {
        check(200, gens::vec(gens::usize_to(10), 2..=5), |v| {
            (2..=5).contains(&v.len()) && v.iter().all(|&x| x <= 10)
        });
    }

    #[test]
    fn pair_strategy() {
        check(
            100,
            gens::pair(gens::usize_to(10), gens::i64_in(-5, 5)),
            |&(a, b)| a <= 10 && (-5..=5).contains(&b),
        );
    }
}
