//! FP8 E4M3FN codec — bit-compatible with `python/compile/kernels/fp8.py`.
//!
//! The rust side needs the codec for (a) initializing/inspecting FP8 KV
//! pools, (b) the platform model's traffic accounting, and (c) tests that
//! cross-check the python/Pallas implementation via the golden table in
//! `python/tests/test_fp8.py`.
//!
//! Layout: 1 sign | 4 exponent (bias 7) | 3 mantissa; no infinities;
//! 0x7F/0xFF are NaN; max finite 448; min subnormal 2^-9.  Encode is
//! round-to-nearest-even with saturation at ±448 (inputs are pre-scaled
//! by the dynamic quantizer, mirroring the kernel).

pub const E4M3_MAX: f32 = 448.0;

/// Decode one E4M3FN byte to f32.
#[inline]
pub fn decode(code: u8) -> f32 {
    let sign = if code & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let ef = (code >> 3) & 0xF;
    let m = (code & 0x7) as f32;
    if ef == 0 {
        sign * m * (1.0 / 512.0)
    } else if ef == 15 && (code & 0x7) == 7 {
        f32::NAN
    } else {
        sign * (1.0 + m / 8.0) * f32::powi(2.0, ef as i32 - 7)
    }
}

/// Encode one f32 to an E4M3FN byte (RNE, saturating at ±448).
pub fn encode(x: f32) -> u8 {
    if x.is_nan() {
        return 0x7F;
    }
    let sign: u8 = if x.is_sign_negative() { 0x80 } else { 0 };
    let a = x.abs().min(E4M3_MAX);
    if a == 0.0 {
        return sign;
    }
    // exponent of the value, clipped to the normal/subnormal split
    let mut e = a.log2().floor();
    e = e.clamp(-6.0, 8.0);
    let step = f32::powi(2.0, e as i32 - 3);
    // round-half-to-even in units of `step`
    let q = round_half_even((a / step) as f64) as f32 * step;
    if q == 0.0 {
        return sign;
    }
    let is_sub = q < f32::powi(2.0, -6);
    if is_sub {
        let m = (q * 512.0) as u32;
        sign | m as u8
    } else {
        let e2 = q.log2().floor().clamp(-6.0, 8.0);
        let m = (q / f32::powi(2.0, e2 as i32) * 8.0 - 8.0) as u32;
        let ef = (e2 as i32 + 7) as u32;
        sign | ((ef << 3) as u8) | m as u8
    }
}

#[inline]
fn round_half_even(x: f64) -> f64 {
    let r = x.round(); // half-away-from-zero
    if (x - x.trunc()).abs() == 0.5 {
        // tie: choose the even neighbour
        if r % 2.0 == 0.0 {
            r
        } else {
            r - (r - x).signum()
        }
    } else {
        r
    }
}

/// Dynamic symmetric quantization of a slice: returns (codes, scale) with
/// `scale = amax / 448` (mirrors `fp8.quantize(axis=-1)` per KV head).
pub fn quantize(xs: &[f32]) -> (Vec<u8>, f32) {
    let amax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let scale = (amax.max(1e-12)) / E4M3_MAX;
    let codes = xs.iter().map(|&x| encode(x / scale)).collect();
    (codes, scale)
}

/// Inverse of [`quantize`].
pub fn dequantize(codes: &[u8], scale: f32) -> Vec<f32> {
    codes.iter().map(|&c| decode(c) * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_spot_values() {
        assert_eq!(decode(0x00), 0.0);
        assert_eq!(decode(0x80), -0.0);
        assert_eq!(decode(0x38), 1.0); // ef=7 -> 2^0
        assert_eq!(decode(0xB8), -1.0);
        assert_eq!(decode(0x7E), 448.0); // max finite
        assert_eq!(decode(0x01), 1.0 / 512.0); // min subnormal
        assert!(decode(0x7F).is_nan());
        assert!(decode(0xFF).is_nan());
    }

    #[test]
    fn round_trip_all_codes() {
        // every finite code must encode back to itself
        for c in 0u16..256 {
            let c = c as u8;
            let v = decode(c);
            if v.is_nan() {
                continue;
            }
            let back = encode(v);
            // -0.0 encodes to 0x80 which decodes to -0.0: compare decoded
            assert_eq!(decode(back), v, "code {c:#x} -> {v} -> {back:#x}");
        }
    }

    #[test]
    fn saturates() {
        assert_eq!(encode(1e9), 0x7E);
        assert_eq!(encode(-1e9), 0xFE);
        assert_eq!(encode(449.0), 0x7E);
    }

    #[test]
    fn rne_ties() {
        // 1.0625 is exactly between 1.0 (m=0) and 1.125 (m=1): RNE -> 1.0
        assert_eq!(decode(encode(1.0625)), 1.0);
        // 1.1875 between 1.125 (m=1) and 1.25 (m=2): RNE -> 1.25 (even m)
        assert_eq!(decode(encode(1.1875)), 1.25);
    }

    #[test]
    fn quantize_bounds_error() {
        let xs: Vec<f32> = (0..64).map(|i| ((i * 37 % 101) as f32 - 50.0) * 0.3).collect();
        let (codes, scale) = quantize(&xs);
        let back = dequantize(&codes, scale);
        let amax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        for (a, b) in xs.iter().zip(&back) {
            // e4m3 relative error <= 2^-4 of the scale-normalized value
            assert!((a - b).abs() <= amax * 0.0715, "{a} vs {b}");
        }
    }

    /// Property: for random vectors, the quantize -> dequantize round
    /// trip stays inside the E4M3 error bound (relative to the per-vector
    /// amax the dynamic scale normalizes by).  The FP8 path carries
    /// swapped KV block payloads, so this bound is what the tier manager
    /// silently relies on.
    #[test]
    fn prop_quantize_roundtrip_error_bound() {
        use crate::util::quickprop::{check, gens};
        check(
            200,
            gens::vec(gens::i64_in(-1_000_000, 1_000_000), 1..=64),
            |xs: &Vec<i64>| {
                let v: Vec<f32> = xs.iter().map(|&i| i as f32 * 0.0137).collect();
                let (codes, scale) = quantize(&v);
                let back = dequantize(&codes, scale);
                if back.len() != v.len() {
                    return false;
                }
                let amax = v.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                // e4m3 worst-case quantization error after dynamic scaling
                // is amax/448 * 2^5 / 2 = amax * 0.0357; allow fp slack
                v.iter()
                    .zip(&back)
                    .all(|(a, b)| (a - b).abs() <= amax.max(1e-12) * 0.0715)
            },
        );
    }

    /// Property: the dynamic scale is exactly amax/448, and the
    /// max-magnitude element lands on ±E4M3_MAX after scaling (no
    /// headroom wasted, no saturation of in-range values).
    #[test]
    fn prop_quantize_scale_correctness() {
        use crate::util::quickprop::{check, gens};
        check(
            200,
            gens::vec(gens::i64_in(-100_000, 100_000), 1..=48),
            |xs: &Vec<i64>| {
                let v: Vec<f32> = xs.iter().map(|&i| i as f32 * 0.31).collect();
                let (codes, scale) = quantize(&v);
                let amax = v.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                if amax <= 1e-12 {
                    // all-zero vector: any positive scale decodes to zeros
                    return scale > 0.0 && dequantize(&codes, scale).iter().all(|&b| b == 0.0);
                }
                if (scale - amax / E4M3_MAX).abs() > scale * 1e-6 {
                    return false;
                }
                let (i, &m) = v
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                    .unwrap();
                let d = decode(codes[i]);
                (d.abs() - E4M3_MAX).abs() < 1e-3 && (d < 0.0) == (m < 0.0)
            },
        );
    }

    #[test]
    fn subnormal_region() {
        let v = 1.5 / 512.0; // between subnormal steps 1 and 2
        let d = decode(encode(v));
        assert!(d == 1.0 / 512.0 || d == 2.0 / 512.0);
        assert_eq!(decode(encode(3.0 / 512.0)), 3.0 / 512.0);
    }
}
