//! Workload generation: the ShareGPT-like serving trace (paper §4.2
//! throughput/latency experiments) and the ARC-sim eval-set loader.
//!
//! The throughput experiments consume only the *length distribution and
//! arrival pattern* of ShareGPT_V3 — prompts here are synthetic text with
//! the published length statistics (log-normal, multi-turn mixture),
//! which is exactly what the serving stack exercises.

pub mod harness;

use std::path::Path;

use anyhow::{Context, Result};

use crate::config::ReqClass;
use crate::sampling::SamplingParams;
use crate::util::json;
use crate::util::rng::Rng;

/// One serving request of the trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// offset from trace start (open-loop arrival)
    pub arrival_s: f64,
    pub prompt: String,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
}

/// ShareGPT-like trace generator parameters.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub num_requests: usize,
    /// mean arrival rate (req/s); 0 = all at t=0 (offered-load mode)
    pub arrival_rate: f64,
    /// log-normal prompt length (of the *underlying* normal)
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    /// log-normal response-length cap
    pub response_mu: f64,
    pub response_sigma: f64,
    /// clamp bounds (sim-scale contexts are short)
    pub min_prompt: usize,
    pub max_prompt: usize,
    pub min_new: usize,
    pub max_new: usize,
    /// fraction of requests that reuse a popular shared prefix
    /// (multi-turn/system-prompt behaviour; exercises prefix sharing)
    pub shared_prefix_frac: f64,
    pub seed: u64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        // ShareGPT_V3 published stats: mean prompt ~161 tok, mean response
        // ~338 tok (Kwon et al. 2023).  Scaled to the sim max_seq=128 /
        // max_ctx=160 geometry while keeping the log-normal shape and the
        // ~1:2 prompt:response ratio.
        TraceSpec {
            num_requests: 40,
            arrival_rate: 0.0,
            prompt_mu: 3.4,  // median ~30 tokens
            prompt_sigma: 0.55,
            response_mu: 3.1, // median ~22 tokens
            response_sigma: 0.6,
            min_prompt: 6,
            max_prompt: 100,
            min_new: 4,
            max_new: 48,
            shared_prefix_frac: 0.3,
            seed: 0xD1CE,
        }
    }
}

/// Generate a deterministic trace from the spec.
pub fn sharegpt_trace(spec: &TraceSpec) -> Vec<TraceRequest> {
    let mut rng = Rng::new(spec.seed);
    // a small pool of popular "conversation openers" (Zipf-selected)
    let openers: Vec<String> = (0..8)
        .map(|i| {
            let len = 16 + 4 * i;
            synth_text(&mut rng, len)
        })
        .collect();

    let mut t = 0.0f64;
    (0..spec.num_requests)
        .map(|_i| {
            if spec.arrival_rate > 0.0 {
                t += rng.exponential(spec.arrival_rate);
            }
            let plen = (rng.lognormal(spec.prompt_mu, spec.prompt_sigma) as usize)
                .clamp(spec.min_prompt, spec.max_prompt);
            let new = (rng.lognormal(spec.response_mu, spec.response_sigma) as usize)
                .clamp(spec.min_new, spec.max_new);
            let prompt = if rng.bool(spec.shared_prefix_frac) {
                let opener = &openers[rng.zipf(openers.len(), 1.1)];
                let tail_len = plen.saturating_sub(opener.len()).max(4);
                format!("{opener}{}", synth_text(&mut rng, tail_len))
            } else {
                synth_text(&mut rng, plen)
            };
            TraceRequest {
                arrival_s: t,
                prompt,
                max_new_tokens: new,
                sampling: SamplingParams::default(),
                // keep i unused but deterministic ordering documented
            }
        })
        .collect()
}

/// Multi-tenant skewed-prefix trace parameters — the workload
/// multi-replica routing policies differentiate on.  Each tenant owns a
/// shared system prompt (a block-aligned prefix every one of its
/// requests starts with, which prefix-affinity routing can colocate),
/// tenant popularity is Zipfian, and per-request tail/response lengths
/// are heavy-tailed log-normals (the skew load-aware routing exists to
/// absorb — round-robin stacks the whales).
#[derive(Debug, Clone)]
pub struct MultiTenantSpec {
    pub num_requests: usize,
    pub tenants: usize,
    /// Zipf exponent of tenant popularity (tenant 0 is the hottest)
    pub zipf_s: f64,
    /// per-tenant system prompt length band in bytes; the hottest
    /// tenants get the longest prompts (more sharable full blocks)
    pub system_prompt_min: usize,
    pub system_prompt_max: usize,
    /// log-normal user-turn tail appended after the system prompt
    pub tail_mu: f64,
    pub tail_sigma: f64,
    pub min_tail: usize,
    pub max_tail: usize,
    /// log-normal response-length cap
    pub response_mu: f64,
    pub response_sigma: f64,
    pub min_new: usize,
    pub max_new: usize,
    /// mean arrival rate (req/s); 0 = all at t=0 (offered-load mode)
    pub arrival_rate: f64,
    pub seed: u64,
}

impl Default for MultiTenantSpec {
    fn default() -> Self {
        // sized to the sim geometry: prompt ≤ 64 + 1 + 48 + BOS = 114
        // ≤ max_seq 128, prompt + response ≤ 154 ≤ max_context 160
        MultiTenantSpec {
            num_requests: 48,
            tenants: 12,
            zipf_s: 1.1,
            system_prompt_min: 31,
            system_prompt_max: 63,
            tail_mu: 3.0,
            tail_sigma: 0.8,
            min_tail: 4,
            max_tail: 48,
            response_mu: 2.9,
            response_sigma: 0.9,
            min_new: 4,
            max_new: 40,
            arrival_rate: 0.0,
            seed: 0xA117,
        }
    }
}

/// Generate a deterministic multi-tenant trace from the spec.
pub fn multi_tenant_trace(spec: &MultiTenantSpec) -> Vec<TraceRequest> {
    let mut rng = Rng::new(spec.seed);
    let denom = spec.tenants.saturating_sub(1).max(1);
    let sys_prompts: Vec<String> = (0..spec.tenants)
        .map(|t| {
            // hottest tenant (rank 0) gets the longest shared prefix;
            // the tenant marker keeps first blocks distinct across
            // tenants, so affinity keys never collide
            let len = spec.system_prompt_max
                - (spec.system_prompt_max - spec.system_prompt_min) * t / denom;
            let prefix = format!("tenant{t} ");
            let body = synth_text(&mut rng, len.saturating_sub(prefix.len()).max(1));
            format!("{prefix}{body}")
        })
        .collect();
    let mut t_arr = 0.0f64;
    (0..spec.num_requests)
        .map(|_| {
            if spec.arrival_rate > 0.0 {
                t_arr += rng.exponential(spec.arrival_rate);
            }
            let tenant = rng.zipf(spec.tenants, spec.zipf_s);
            let tail = (rng.lognormal(spec.tail_mu, spec.tail_sigma) as usize)
                .clamp(spec.min_tail, spec.max_tail);
            let new = (rng.lognormal(spec.response_mu, spec.response_sigma) as usize)
                .clamp(spec.min_new, spec.max_new);
            TraceRequest {
                arrival_s: t_arr,
                prompt: format!("{} {}", sys_prompts[tenant], synth_text(&mut rng, tail)),
                max_new_tokens: new,
                sampling: SamplingParams::default(),
            }
        })
        .collect()
}

/// SLO class mix layered over a trace (see [`slo_classes`]): which
/// positions are interactive, which carry deadlines, and how tenants
/// are attributed for per-tenant admission accounting.
#[derive(Debug, Clone)]
pub struct SloMix {
    /// every N-th request is interactive (4 => the 1:3
    /// interactive:batch mix of the overload bench); the rest are batch
    pub interactive_every: usize,
    /// interactive requests carry this deadline (generous — it exists
    /// to exercise the field end-to-end, not to cancel healthy traffic)
    pub interactive_deadline_ms: u64,
    /// the first N *batch* requests arrive with an already-expired
    /// deadline (client-side timeout shorter than any possible service):
    /// deadline enforcement must cancel them at a step boundary instead
    /// of burning capacity on answers nobody is waiting for
    pub expired_head: usize,
}

impl Default for SloMix {
    fn default() -> Self {
        SloMix {
            interactive_every: 4,
            interactive_deadline_ms: 60_000,
            expired_head: 3,
        }
    }
}

/// Assign an SLO request class to each position of a trace.  Classes
/// are a pure function of (index, prompt), so the same trace always
/// gets the same mix — the overload bench relies on this to compare
/// control-on vs control-off over identical offered work.  The tenant
/// is read back out of the multi-tenant prompt's leading `tenantN`
/// marker ([`multi_tenant_trace`] puts it there to keep first blocks
/// distinct); traces without the marker stay untenanted.
pub fn slo_classes(trace: &[TraceRequest], mix: &SloMix) -> Vec<ReqClass> {
    let every = mix.interactive_every.max(1);
    let mut batch_seen = 0usize;
    trace
        .iter()
        .enumerate()
        .map(|(i, req)| {
            let tenant = req
                .prompt
                .split_whitespace()
                .next()
                .filter(|t| t.starts_with("tenant"));
            let mut class = if i % every == 0 {
                ReqClass::interactive().with_deadline_ms(mix.interactive_deadline_ms)
            } else {
                batch_seen += 1;
                if batch_seen <= mix.expired_head {
                    ReqClass::batch().with_deadline_ms(0)
                } else {
                    ReqClass::batch()
                }
            };
            if let Some(t) = tenant {
                class = class.with_tenant(t);
            }
            class
        })
        .collect()
}

/// Disaggregated-PD stress trace parameters: a steady stream of
/// decode-heavy requests (short prompts, long responses) punctuated by
/// bursts of prefill-heavy ones (long prompts, short responses).  On a
/// mixed cluster every replica's decode batches stall behind the
/// bursts' prefill work; a PD-split cluster absorbs the bursts on its
/// prefill pool and hands the sequences off through the host tier, so
/// decode inter-token latency stays flat — exactly what the
/// `disaggregated_pd` bench section measures.
#[derive(Debug, Clone)]
pub struct PdTraceSpec {
    pub num_requests: usize,
    /// fraction of requests that are prefill-heavy burst members
    pub burst_frac: f64,
    /// burst arrivals come in clumps of this size
    pub burst_size: usize,
    /// long-prompt band of burst requests (bytes)
    pub burst_prompt_min: usize,
    pub burst_prompt_max: usize,
    /// decode budget of burst requests (short: they exist to prefill)
    pub burst_new: usize,
    /// the steady decode-heavy stream: short prompts, long responses
    pub steady_prompt_min: usize,
    pub steady_prompt_max: usize,
    pub steady_new_min: usize,
    pub steady_new_max: usize,
    /// mean arrival rate (req/s); 0 = all at t=0 (offered-load mode)
    pub arrival_rate: f64,
    pub seed: u64,
}

impl Default for PdTraceSpec {
    fn default() -> Self {
        // prompt + BOS ≤ max_seq 128, prompt + BOS + response ≤
        // max_context 160; bursts sit firmly past the 4x
        // prefill-dominance gate, the steady stream firmly under it
        PdTraceSpec {
            num_requests: 48,
            burst_frac: 0.4,
            burst_size: 4,
            burst_prompt_min: 80,
            burst_prompt_max: 110,
            burst_new: 4,
            steady_prompt_min: 8,
            steady_prompt_max: 24,
            steady_new_min: 24,
            steady_new_max: 40,
            arrival_rate: 0.0,
            seed: 0xBD2D,
        }
    }
}

/// Generate a deterministic PD stress trace from the spec.
pub fn pd_trace(spec: &PdTraceSpec) -> Vec<TraceRequest> {
    let mut rng = Rng::new(spec.seed);
    let mut t = 0.0f64;
    let mut burst_left = 0usize;
    (0..spec.num_requests)
        .map(|i| {
            let start_burst = burst_left == 0
                && rng.bool(spec.burst_frac / spec.burst_size.max(1) as f64);
            if start_burst {
                burst_left = spec.burst_size.max(1);
            }
            // burst members arrive together: only the steady stream and
            // each burst's head pay an inter-arrival gap
            if spec.arrival_rate > 0.0 && (burst_left == 0 || start_burst) {
                t += rng.exponential(spec.arrival_rate);
            }
            let (prompt, new) = if burst_left > 0 {
                burst_left -= 1;
                let span = spec.burst_prompt_max - spec.burst_prompt_min + 1;
                let len = spec.burst_prompt_min + rng.below(span);
                let marker = format!("burst{i} ");
                let body = synth_text(&mut rng, len.saturating_sub(marker.len()).max(1));
                (format!("{marker}{body}"), spec.burst_new)
            } else {
                let span = spec.steady_prompt_max - spec.steady_prompt_min + 1;
                let len = spec.steady_prompt_min + rng.below(span);
                let new_span = spec.steady_new_max - spec.steady_new_min + 1;
                let new = spec.steady_new_min + rng.below(new_span);
                let marker = format!("steady{i} ");
                let body = synth_text(&mut rng, len.saturating_sub(marker.len()).max(1));
                (format!("{marker}{body}"), new)
            };
            TraceRequest {
                arrival_s: t,
                prompt,
                max_new_tokens: new,
                sampling: SamplingParams::default(),
            }
        })
        .collect()
}

/// Deterministic pseudo-text of ~`len` bytes (byte-level tokens = bytes).
fn synth_text(rng: &mut Rng, len: usize) -> String {
    const WORDS: [&str; 16] = [
        "the", "model", "cache", "memory", "token", "answer", "question",
        "system", "user", "explain", "compute", "attention", "block",
        "value", "key", "query",
    ];
    let mut s = String::with_capacity(len + 8);
    while s.len() < len {
        if !s.is_empty() {
            s.push(' ');
        }
        s.push_str(WORDS[rng.below(WORDS.len())]);
    }
    s.truncate(len.max(1));
    s
}

// ---------------------------------------------------------------------------
// ARC-sim eval sets (written by python/compile/data.py at artifact time)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct McqQuestion {
    pub prompt: String,
    pub choices: Vec<String>,
    pub answer: usize,
}

#[derive(Debug, Clone)]
pub struct McqSet {
    pub split: String,
    pub letters: Vec<char>,
    pub questions: Vec<McqQuestion>,
}

pub fn load_mcq_set(path: impl AsRef<Path>) -> Result<McqSet> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading eval set {}", path.as_ref().display()))?;
    let v = json::parse(&text)?;
    let letters: Vec<char> = v.req_str("letters")?.chars().collect();
    let questions = v
        .req_array("questions")?
        .iter()
        .map(|q| {
            Ok(McqQuestion {
                prompt: q.req_str("prompt")?.to_string(),
                choices: q
                    .req_array("choices")?
                    .iter()
                    .map(|c| c.as_str().unwrap_or_default().to_string())
                    .collect(),
                answer: q.req_usize("answer")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(McqSet {
        split: v.req_str("split")?.to_string(),
        letters,
        questions,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic() {
        let spec = TraceSpec::default();
        let a = sharegpt_trace(&spec);
        let b = sharegpt_trace(&spec);
        assert_eq!(a.len(), spec.num_requests);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
    }

    #[test]
    fn lengths_respect_bounds() {
        let spec = TraceSpec {
            num_requests: 200,
            ..Default::default()
        };
        for r in sharegpt_trace(&spec) {
            assert!(r.prompt.len() >= spec.min_prompt.min(4));
            assert!(r.prompt.len() <= spec.max_prompt);
            assert!((spec.min_new..=spec.max_new).contains(&r.max_new_tokens));
        }
    }

    #[test]
    fn open_loop_arrivals_increase() {
        let spec = TraceSpec {
            num_requests: 50,
            arrival_rate: 10.0,
            ..Default::default()
        };
        let trace = sharegpt_trace(&spec);
        for w in trace.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        assert!(trace.last().unwrap().arrival_s > 0.0);
    }

    #[test]
    fn shared_prefixes_appear() {
        let spec = TraceSpec {
            num_requests: 100,
            shared_prefix_frac: 0.9,
            ..Default::default()
        };
        let trace = sharegpt_trace(&spec);
        // with 90% sharing over 8 openers some prompts must share a prefix
        let mut shared = 0;
        for i in 0..trace.len() {
            for j in 0..i {
                let a = &trace[i].prompt;
                let b = &trace[j].prompt;
                let common = a.bytes().zip(b.bytes()).take_while(|(x, y)| x == y).count();
                if common >= 16 {
                    shared += 1;
                    break;
                }
            }
        }
        assert!(shared > 10, "found {shared} shared-prefix prompts");
    }

    #[test]
    fn multi_tenant_trace_is_deterministic_and_bounded() {
        let spec = MultiTenantSpec::default();
        let a = multi_tenant_trace(&spec);
        let b = multi_tenant_trace(&spec);
        assert_eq!(a.len(), spec.num_requests);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        for r in &a {
            // fits the sim geometry with BOS and the full response
            assert!(r.prompt.len() + 1 <= 128, "prompt {} too long", r.prompt.len());
            assert!(r.prompt.len() + 1 + r.max_new_tokens <= 160);
            assert!((spec.min_new..=spec.max_new).contains(&r.max_new_tokens));
        }
    }

    #[test]
    fn multi_tenant_popularity_is_zipfian_with_shared_prefixes() {
        let spec = MultiTenantSpec {
            num_requests: 200,
            ..Default::default()
        };
        let trace = multi_tenant_trace(&spec);
        // count requests per tenant via the distinct tenant markers
        let mut counts = vec![0usize; spec.tenants];
        for r in &trace {
            let t: usize = r
                .prompt
                .strip_prefix("tenant")
                .and_then(|s| s.split(' ').next())
                .and_then(|s| s.parse().ok())
                .expect("tenant marker");
            counts[t] += 1;
        }
        assert!(counts[0] > counts[spec.tenants - 1], "head tenant hottest: {counts:?}");
        assert!(counts[0] > spec.num_requests / spec.tenants, "skewed, not uniform");
        // same-tenant requests share a multi-block prefix (>= 31 bytes of
        // system prompt), different tenants diverge inside block 0
        let same: Vec<&TraceRequest> = trace
            .iter()
            .filter(|r| r.prompt.starts_with("tenant0 "))
            .collect();
        assert!(same.len() >= 2);
        let common = same[0]
            .prompt
            .bytes()
            .zip(same[1].prompt.bytes())
            .take_while(|(a, b)| a == b)
            .count();
        assert!(common >= 31, "shared system prompt, got {common} bytes");
    }

    #[test]
    fn pd_trace_mixes_bursty_prefill_with_steady_decode() {
        let spec = PdTraceSpec::default();
        let a = pd_trace(&spec);
        let b = pd_trace(&spec);
        assert_eq!(a.len(), spec.num_requests);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        let bursts: Vec<&TraceRequest> =
            a.iter().filter(|r| r.prompt.starts_with("burst")).collect();
        let steady: Vec<&TraceRequest> =
            a.iter().filter(|r| r.prompt.starts_with("steady")).collect();
        assert_eq!(bursts.len() + steady.len(), a.len());
        assert!(!bursts.is_empty() && !steady.is_empty(), "both phases present");
        for r in &a {
            // fits the sim geometry with BOS and the full response
            assert!(r.prompt.len() + 1 <= 128);
            assert!(r.prompt.len() + 1 + r.max_new_tokens <= 160);
        }
        // burst members sit past the router's 4x prefill-dominance
        // gate, the steady stream sits under it: the trace exercises
        // both sides of handoff_pays
        for r in &bursts {
            assert!(r.prompt.len() >= 4 * r.max_new_tokens);
        }
        for r in &steady {
            assert!(r.prompt.len() < 4 * r.max_new_tokens);
        }
        // with open-loop arrivals, members of one burst arrive together
        let spec = PdTraceSpec {
            arrival_rate: 20.0,
            ..PdTraceSpec::default()
        };
        let t = pd_trace(&spec);
        let mut clumped = 0;
        for w in t.windows(2) {
            if w[0].prompt.starts_with("burst")
                && w[1].prompt.starts_with("burst")
                && w[1].arrival_s == w[0].arrival_s
            {
                clumped += 1;
            }
        }
        assert!(clumped > 0, "burst members share arrival stamps");
        assert!(t.last().unwrap().arrival_s > 0.0);
    }

    #[test]
    fn mcq_loader_parses() {
        let tmp = std::env::temp_dir().join(format!("coopt-mcq-{}.json", std::process::id()));
        std::fs::write(
            &tmp,
            r#"{"split":"easy","seed":1,"n":1,"letters":"ABCD",
                "questions":[{"question":"Q: 1+1=?","choices":["2","3","4","5"],
                              "answer":0,"prompt":"Q: 1+1=? A) 2 B) 3 C) 4 D) 5\nAnswer:",
                              "full":"..."}]}"#,
        )
        .unwrap();
        let set = load_mcq_set(&tmp).unwrap();
        assert_eq!(set.split, "easy");
        assert_eq!(set.letters, vec!['A', 'B', 'C', 'D']);
        assert_eq!(set.questions[0].answer, 0);
        std::fs::remove_file(&tmp).ok();
    }
}
