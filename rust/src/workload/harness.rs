//! Shared experiment harness used by the Fig. 6/7 bench targets and
//! EXPERIMENTS.md tooling: run one (model, config) pair over a ShareGPT-sim
//! trace through the full PJRT engine and collect the paper's metrics.

use anyhow::Result;

use crate::config::{EngineConfig, OptConfig, ReqClass};
use crate::coordinator::{Engine, GenRequest};
use crate::platform::CostModel;
use crate::runtime::{Backend, Runtime};
use crate::util::json::{Object, Value};
use crate::workload::{
    multi_tenant_trace, pd_trace, sharegpt_trace, slo_classes, MultiTenantSpec, PdTraceSpec,
    SloMix, TraceSpec,
};

/// One row of Fig. 6 / Fig. 7.
#[derive(Debug, Clone)]
pub struct RunRow {
    pub model: String,
    pub config: &'static str,
    pub requests: usize,
    pub tokens: u64,
    /// Eq. 11 totals
    pub latency_wall_s: f64,
    pub latency_sim_s: f64,
    /// Eq. 12
    pub throughput_wall: f64,
    pub throughput_sim: f64,
    pub p99_wall_s: f64,
    pub coordinator_overhead: f64,
    pub preemptions: u64,
    pub pool_blocks: usize,
}

impl RunRow {
    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("model", self.model.as_str());
        o.insert("config", self.config);
        o.insert("requests", self.requests);
        o.insert("tokens", self.tokens as usize);
        o.insert("latency_wall_s", self.latency_wall_s);
        o.insert("latency_sim_s", self.latency_sim_s);
        o.insert("throughput_wall", self.throughput_wall);
        o.insert("throughput_sim", self.throughput_sim);
        o.insert("p99_wall_s", self.p99_wall_s);
        o.insert("coordinator_overhead", self.coordinator_overhead);
        o.insert("preemptions", self.preemptions as usize);
        o.insert("pool_blocks", self.pool_blocks);
        Value::Object(o)
    }
}

/// Run `trace` through (model, cfg).  With `capacity_coupled`, the KV pool
/// is sized from the Z100 memory model for this config (the mechanism
/// behind the paper's "13B gains more" ordering, DESIGN.md).
pub fn run_trace(
    rt: &Runtime,
    model: &str,
    cfg: OptConfig,
    trace_spec: &TraceSpec,
    capacity_coupled: bool,
) -> Result<RunRow> {
    let mrt = rt.load_model(model, cfg)?;
    let mut geometry = *mrt.geometry();
    if capacity_coupled {
        let cm = CostModel::for_preset(mrt.preset(), geometry.block_size);
        geometry.num_pool_blocks =
            cm.sim_pool_blocks(&cfg, 12.0, 16, geometry.num_pool_blocks);
    }
    let pool_blocks = geometry.num_pool_blocks;
    // Engine reads geometry through the backend; shadow it via a wrapper.
    let backend = PoolSized { inner: mrt, geometry };
    let mut engine = Engine::new(backend, EngineConfig::new(model, cfg));

    for req in sharegpt_trace(trace_spec) {
        engine.submit(GenRequest {
            prompt: req.prompt,
            max_new_tokens: req.max_new_tokens,
            sampling: req.sampling,
            // fixed token counts across configs => clean Eq. 11/12 deltas
            ignore_eos: true,
            corr_id: None,
            class: ReqClass::default(),
        })?;
    }
    engine.run_to_completion()?;
    let m = &mut engine.metrics;
    Ok(RunRow {
        model: model.to_string(),
        config: cfg.name,
        requests: m.requests_finished as usize,
        tokens: m.tokens_generated,
        latency_wall_s: m.total_latency_wall_s(),
        latency_sim_s: m.total_latency_sim_s(),
        throughput_wall: m.throughput_wall(),
        throughput_sim: m.throughput_sim(),
        p99_wall_s: m.latency_wall.p99(),
        coordinator_overhead: m.coordinator_overhead_frac(),
        preemptions: m.preemptions,
        pool_blocks,
    })
}

/// Backend wrapper overriding the advertised cache geometry (pool size).
struct PoolSized<B: Backend> {
    inner: B,
    geometry: crate::config::CacheGeometry,
}

impl<B: Backend> Backend for PoolSized<B> {
    fn preset(&self) -> &crate::config::ModelPreset {
        self.inner.preset()
    }
    fn geometry(&self) -> &crate::config::CacheGeometry {
        &self.geometry
    }
    fn opt(&self) -> &OptConfig {
        self.inner.opt()
    }
    fn prefill(&mut self, t: &[i32], l: i32, s: &[i32]) -> Result<Vec<f32>> {
        self.inner.prefill(t, l, s)
    }
    // forward explicitly so the inner backend's chunk semantics (e.g. the
    // mock's) are not shadowed by the trait defaults
    fn prefill_chunk(&mut self, t: &[i32], o: i32, l: i32, s: &[i32]) -> Result<Vec<f32>> {
        self.inner.prefill_chunk(t, o, l, s)
    }
    fn supports_chunked_prefill(&self) -> bool {
        self.inner.supports_chunked_prefill()
    }
    fn swap_out(&mut self, device_block: u32, host_slot: u64) -> Result<()> {
        self.inner.swap_out(device_block, host_slot)
    }
    fn swap_in(&mut self, host_slot: u64, device_block: u32) -> Result<()> {
        self.inner.swap_in(host_slot, device_block)
    }
    fn swap_discard(&mut self, host_slot: u64) -> Result<()> {
        self.inner.swap_discard(host_slot)
    }
    fn supports_kv_swap(&self) -> bool {
        self.inner.supports_kv_swap()
    }
    fn export_block(&mut self, device_block: u32, host_slot: u64) -> Result<u64> {
        self.inner.export_block(device_block, host_slot)
    }
    fn import_block(&mut self, device_block: u32, payload: u64) -> Result<()> {
        self.inner.import_block(device_block, payload)
    }
    fn supports_kv_migration(&self) -> bool {
        self.inner.supports_kv_migration()
    }
    fn export_host_block(&mut self, host_slot: u64) -> Result<u64> {
        self.inner.export_host_block(host_slot)
    }
    fn draft(
        &mut self,
        t: &[i32],
        p: &[i32],
        c: &[i32],
        k: usize,
    ) -> Result<(Vec<i32>, Vec<f32>)> {
        self.inner.draft(t, p, c, k)
    }
    fn verify(
        &mut self,
        t: &[i32],
        p: &[i32],
        b: &[i32],
        c: &[i32],
        s: &[i32],
        k: usize,
    ) -> Result<Vec<f32>> {
        self.inner.verify(t, p, b, c, s, k)
    }
    fn supports_speculation(&self) -> bool {
        self.inner.supports_speculation()
    }
    fn decode(
        &mut self,
        t: &[i32],
        p: &[i32],
        b: &[i32],
        c: &[i32],
        s: &[i32],
    ) -> Result<Vec<f32>> {
        self.inner.decode(t, p, b, c, s)
    }
    fn reset_cache(&mut self) -> Result<()> {
        self.inner.reset_cache()
    }
    fn take_exec_time(&mut self) -> std::time::Duration {
        self.inner.take_exec_time()
    }
}

/// One row of the chunked-prefill comparison (both benches report it).
#[derive(Debug, Clone)]
pub struct ChunkCompareRow {
    pub mode: &'static str,
    /// decode inter-token latency percentiles on the simulated clock
    pub itl_sim_p50_s: f64,
    pub itl_sim_p95_s: f64,
    pub itl_sim_max_s: f64,
    /// Eq. 11 / Eq. 12 aggregates
    pub latency_sim_s: f64,
    pub throughput_sim: f64,
    pub prefill_chunks: u64,
    pub chunk_stall_sim_s: f64,
    pub tokens: u64,
}

impl ChunkCompareRow {
    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("mode", self.mode);
        o.insert("itl_sim_p50_s", self.itl_sim_p50_s);
        o.insert("itl_sim_p95_s", self.itl_sim_p95_s);
        o.insert("itl_sim_max_s", self.itl_sim_max_s);
        o.insert("latency_sim_s", self.latency_sim_s);
        o.insert("throughput_sim", self.throughput_sim);
        o.insert("prefill_chunks", self.prefill_chunks as usize);
        o.insert("chunk_stall_sim_s", self.chunk_stall_sim_s);
        o.insert("tokens", self.tokens as usize);
        Value::Object(o)
    }
}

/// Chunked-vs-one-shot prefill comparison over the deterministic mock
/// backend (runs without artifacts): `streams` short decode streams keep
/// generating while `long_prompts` long prompts (each ≥ 4x the chunk
/// size) arrive behind them.  One-shot mode runs each long prefill as a
/// monolithic step between decodes — its cost lands on every stream's
/// inter-token latency; chunked mode bounds that stall to one window.
/// Returns the `[one-shot, chunked]` rows.
pub fn run_chunk_compare(
    chunk_tokens: usize,
    long_prompts: usize,
    streams: usize,
    max_new: usize,
) -> Result<Vec<ChunkCompareRow>> {
    use crate::runtime::mock::MockBackend;
    use crate::sampling::SamplingParams;

    let long_len = 6 * chunk_tokens; // ≥ 4x the chunk budget by construction
    let mut rows = Vec::new();
    for (mode, chunked) in [("oneshot", false), ("chunked", true)] {
        let be = MockBackend::new().with_opt(crate::config::COOPT);
        let mut cfg = EngineConfig::new("llama-7b-sim", crate::config::COOPT);
        if chunked {
            // a tight step budget: decodes first, about one window of
            // prefill per step
            cfg = cfg
                .with_chunked_prefill(chunk_tokens)
                .with_step_budget(chunk_tokens + streams + 2);
        }
        let mut engine = Engine::new(be, cfg);
        for i in 0..streams {
            let toks: Vec<u32> = (0..8).map(|t| 33 + ((i * 17 + t) % 80) as u32).collect();
            engine.submit_tokens(toks, max_new, SamplingParams::default(), true)?;
        }
        for i in 0..long_prompts {
            let toks: Vec<u32> = (0..long_len)
                .map(|t| 33 + ((i * 31 + t * 7) % 80) as u32)
                .collect();
            engine.submit_tokens(toks, 4, SamplingParams::default(), true)?;
        }
        engine.run_to_completion()?;
        let m = &mut engine.metrics;
        rows.push(ChunkCompareRow {
            mode,
            itl_sim_p50_s: m.itl_sim.p50(),
            itl_sim_p95_s: m.itl_sim.p95(),
            itl_sim_max_s: m.itl_sim.max(),
            latency_sim_s: m.total_latency_sim_s(),
            throughput_sim: m.throughput_sim(),
            prefill_chunks: m.prefill_chunks,
            chunk_stall_sim_s: m.chunk_stall_s,
            tokens: m.tokens_generated,
        });
    }
    Ok(rows)
}

/// One row of the swap-vs-recompute comparison (Opt-KV tier manager).
#[derive(Debug, Clone)]
pub struct SwapCompareRow {
    pub mode: &'static str,
    pub throughput_sim: f64,
    pub latency_sim_s: f64,
    pub itl_sim_p50_s: f64,
    pub itl_sim_p95_s: f64,
    pub tokens: u64,
    pub preemptions: u64,
    pub swap_outs: u64,
    pub swap_ins: u64,
    pub prefetch_hits: u64,
    pub prefetch_misses: u64,
    pub tokens_recomputed: u64,
    pub recompute_avoided_tokens: u64,
}

impl SwapCompareRow {
    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("mode", self.mode);
        o.insert("throughput_sim", self.throughput_sim);
        o.insert("latency_sim_s", self.latency_sim_s);
        o.insert("itl_sim_p50_s", self.itl_sim_p50_s);
        o.insert("itl_sim_p95_s", self.itl_sim_p95_s);
        o.insert("tokens", self.tokens as usize);
        o.insert("preemptions", self.preemptions as usize);
        o.insert("swap_outs", self.swap_outs as usize);
        o.insert("swap_ins", self.swap_ins as usize);
        o.insert("prefetch_hits", self.prefetch_hits as usize);
        o.insert("prefetch_misses", self.prefetch_misses as usize);
        o.insert("tokens_recomputed", self.tokens_recomputed as usize);
        o.insert(
            "recompute_avoided_tokens",
            self.recompute_avoided_tokens as usize,
        );
        Value::Object(o)
    }
}

/// Swap-vs-recompute comparison over the deterministic mock backend (runs
/// without artifacts): a device pool sized to force preemption serves
/// `requests` growing decode streams, once with single-tier
/// drop-and-recompute preemption and once with the two-tier host pool
/// (swap + async prefetch).  Same workload, same generated tokens; the
/// tiered run should drive `tokens_recomputed` toward zero and win on
/// Eq. 12 throughput.  Returns the `[recompute, swap]` rows.
pub fn run_swap_compare(requests: usize, max_new: usize) -> Result<Vec<SwapCompareRow>> {
    use crate::config::{CacheGeometry, SwapPolicy, COOPT};
    use crate::runtime::mock::MockBackend;
    use crate::sampling::SamplingParams;

    let geometry = CacheGeometry {
        block_size: 4,
        max_blocks: 16,
        num_pool_blocks: 12, // deliberately undersized: preemption city
        max_batch: 4,
        max_seq: 48,
    };
    let mut rows = Vec::new();
    // host tier sized above the worst case (requests x blocks-per-seq) so
    // the swap path never degrades to recompute mid-comparison
    for (mode, host_blocks) in [("recompute", 0usize), ("swap", 128usize)] {
        let be = MockBackend::with_geometry(geometry).with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_host_pool(host_blocks)
            .with_swap_policy(SwapPolicy::Auto);
        let mut engine = Engine::new(be, cfg);
        for i in 0..requests {
            let toks: Vec<u32> = (0..16 + (i % 5) * 2)
                .map(|t| 33 + ((i * 13 + t * 3) % 80) as u32)
                .collect();
            engine.submit_tokens(toks, max_new, SamplingParams::default(), true)?;
        }
        engine.run_to_completion()?;
        let m = &mut engine.metrics;
        rows.push(SwapCompareRow {
            mode,
            throughput_sim: m.throughput_sim(),
            latency_sim_s: m.total_latency_sim_s(),
            itl_sim_p50_s: m.itl_sim.p50(),
            itl_sim_p95_s: m.itl_sim.p95(),
            tokens: m.tokens_generated,
            preemptions: m.preemptions,
            swap_outs: m.swap_outs,
            swap_ins: m.swap_ins,
            prefetch_hits: m.prefetch_hits,
            prefetch_misses: m.prefetch_misses,
            tokens_recomputed: m.tokens_recomputed,
            recompute_avoided_tokens: m.recompute_avoided_tokens,
        });
    }
    Ok(rows)
}

/// One row of the speculative-vs-baseline comparison (draft-and-verify).
#[derive(Debug, Clone)]
pub struct SpecCompareRow {
    pub mode: String,
    pub draft_tokens: usize,
    pub tokens: u64,
    /// decode + verify rounds (the denominator of tokens/step)
    pub decode_rounds: u64,
    pub tokens_per_step: f64,
    pub acceptance_rate: f64,
    pub throughput_sim: f64,
    pub latency_sim_s: f64,
    pub itl_sim_p50_s: f64,
    pub itl_sim_p95_s: f64,
}

impl SpecCompareRow {
    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("mode", self.mode.as_str());
        o.insert("draft_tokens", self.draft_tokens);
        o.insert("tokens", self.tokens as usize);
        o.insert("decode_rounds", self.decode_rounds as usize);
        o.insert("tokens_per_step", self.tokens_per_step);
        o.insert("acceptance_rate", self.acceptance_rate);
        o.insert("throughput_sim", self.throughput_sim);
        o.insert("latency_sim_s", self.latency_sim_s);
        o.insert("itl_sim_p50_s", self.itl_sim_p50_s);
        o.insert("itl_sim_p95_s", self.itl_sim_p95_s);
        Value::Object(o)
    }
}

/// Speculative-vs-baseline comparison over the deterministic mock + Z100
/// cost model (runs without artifacts): the same greedy workload decoded
/// one token at a time and with draft-and-verify at each `k` in `ks`.
/// Greedy speculation is exact, so the run *asserts* token-identical
/// outputs; the deltas are rounds, tokens/step, and Eq. 12 throughput.
/// A small concurrent batch keeps decode in the weight-stream-bound
/// regime where the k-fold KV/weight amortization pays (at large batch
/// decode turns GEMM-bound and speculation rightly stops winning).
pub fn run_spec_compare(
    requests: usize,
    max_new: usize,
    ks: &[usize],
) -> Result<Vec<SpecCompareRow>> {
    use crate::runtime::mock::MockBackend;
    use crate::sampling::SamplingParams;

    let mut rows = Vec::new();
    let mut base_tokens: Option<Vec<Vec<u32>>> = None;
    for &k in std::iter::once(&0usize).chain(ks.iter()) {
        let mut be = MockBackend::new().with_opt(crate::config::COOPT);
        // a fairly strong draft (~90% agreement): the high-acceptance
        // operating point the crossover analysis prices
        be.draft_divergence = 10;
        let mut cfg = EngineConfig::new("llama-7b-sim", crate::config::COOPT);
        if k > 0 {
            cfg = cfg.with_speculation(k);
        }
        let mut engine = Engine::new(be, cfg);
        for i in 0..requests {
            let toks: Vec<u32> = (0..8 + (i % 4) * 3)
                .map(|t| 33 + ((i * 11 + t * 5) % 80) as u32)
                .collect();
            engine.submit_tokens(toks, max_new, SamplingParams::default(), true)?;
        }
        let mut results = engine.run_to_completion()?;
        results.sort_by_key(|r| r.id);
        let outs: Vec<Vec<u32>> = results.iter().map(|r| r.tokens.clone()).collect();
        match &base_tokens {
            None => base_tokens = Some(outs),
            Some(base) => {
                if *base != outs {
                    anyhow::bail!("speculative outputs diverged from greedy baseline at k={k}");
                }
            }
        }
        let m = &mut engine.metrics;
        rows.push(SpecCompareRow {
            mode: if k == 0 {
                "baseline".to_string()
            } else {
                format!("spec-k{k}")
            },
            draft_tokens: k,
            tokens: m.tokens_generated,
            decode_rounds: m.decode_steps + m.spec_rounds,
            tokens_per_step: m.tokens_per_step(),
            acceptance_rate: m.acceptance_rate(),
            throughput_sim: m.throughput_sim(),
            latency_sim_s: m.total_latency_sim_s(),
            itl_sim_p50_s: m.itl_sim.p50(),
            itl_sim_p95_s: m.itl_sim.p95(),
        });
    }
    Ok(rows)
}

/// One (draft divergence, concurrent batch) operating point of the
/// adaptive-speculation sweep.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveSpecPoint {
    /// the mock draft disagrees with the target every `divergence`-th
    /// token (10 ≈ 90% per-position acceptance, 2 ≈ 50%)
    pub divergence: u64,
    /// concurrent greedy streams (the decode batch width — what moves
    /// the batch between the weight-stream-bound and GEMM-bound regimes)
    pub batch: usize,
}

/// Adaptive-vs-fixed-k speculation sweep over the deterministic mock +
/// Z100 cost model (runs without artifacts).  At each sweep point the
/// same greedy workload runs at every fixed k in `{0} ∪ fixed_ks` and
/// once with the adaptive controller (`k_max`); all runs are asserted
/// token-identical (greedy speculation is exact at any k, moving or
/// not).  The sweep is chosen so *no single fixed k wins everywhere* —
/// small batches reward long drafts, high divergence rewards short
/// ones, and the GEMM-bound large batch rewards k = 0 — which is
/// exactly the case for closing the loop: the adaptive rows should
/// match the best fixed k of each point (tokens/step within 2%) without
/// anyone retuning `--spec-tokens`.  Adaptive rows record the chosen-k
/// trace, final k, round histogram, and regime classification.
pub fn run_adaptive_spec_compare(
    points: &[AdaptiveSpecPoint],
    max_new: usize,
    fixed_ks: &[usize],
    k_max: usize,
) -> Result<Vec<Value>> {
    use crate::runtime::mock::MockBackend;
    use crate::sampling::SamplingParams;

    let mut rows = Vec::new();
    for &point in points {
        let mut base_tokens: Option<Vec<Vec<u32>>> = None;
        // modes: fixed k = 0 (baseline), each fixed k, then adaptive
        let fixed_modes: Vec<Option<usize>> = std::iter::once(Some(0))
            .chain(fixed_ks.iter().copied().map(Some))
            .chain(std::iter::once(None))
            .collect();
        for mode_k in fixed_modes {
            let mut be = MockBackend::new().with_opt(crate::config::COOPT);
            be.draft_divergence = point.divergence;
            // chunked prefill admits the whole batch in round one, so
            // the controller sees the true batch width from its first
            // decision instead of a one-lane warm-up
            let mut cfg = EngineConfig::new("llama-7b-sim", crate::config::COOPT)
                .with_chunked_prefill(32);
            cfg = match mode_k {
                Some(0) => cfg,
                Some(k) => cfg.with_speculation(k),
                None => cfg.with_adaptive_speculation(k_max),
            };
            let mut engine = Engine::new(be, cfg);
            for i in 0..point.batch {
                let toks: Vec<u32> = (0..8 + (i % 4) * 3)
                    .map(|t| 33 + ((i * 11 + t * 5) % 80) as u32)
                    .collect();
                engine.submit_tokens(toks, max_new, SamplingParams::default(), true)?;
            }
            let mut results = engine.run_to_completion()?;
            results.sort_by_key(|r| r.id);
            let outs: Vec<Vec<u32>> = results.iter().map(|r| r.tokens.clone()).collect();
            match &base_tokens {
                None => base_tokens = Some(outs),
                Some(base) => {
                    if *base != outs {
                        anyhow::bail!(
                            "outputs diverged from one-token decode at \
                             divergence={} batch={} mode={mode_k:?}",
                            point.divergence,
                            point.batch
                        );
                    }
                }
            }
            let m = &engine.metrics;
            let mut o = Object::new();
            o.insert("divergence", point.divergence as usize);
            o.insert("batch", point.batch);
            match mode_k {
                Some(k) => {
                    o.insert("mode", format!("fixed-k{k}"));
                    o.insert("draft_k", k);
                }
                None => {
                    o.insert("mode", "adaptive");
                    o.insert("draft_k", k_max);
                }
            }
            o.insert("tokens", m.tokens_generated as usize);
            o.insert("decode_rounds", (m.decode_steps + m.spec_rounds) as usize);
            o.insert("spec_rounds", m.spec_rounds as usize);
            o.insert("tokens_per_step", m.tokens_per_step());
            o.insert("acceptance_rate", m.acceptance_rate());
            o.insert("throughput_sim", m.throughput_sim());
            o.insert("latency_sim_s", m.total_latency_sim_s());
            if mode_k.is_none() {
                o.insert("k_last", m.spec_k_current);
                o.insert("regime", m.spec_regime);
                o.insert("ctrl_transitions", m.spec_ctrl_transitions as usize);
                o.insert("acceptance_ewma", m.spec_acceptance_ewma);
                let mut hist = Object::new();
                for (k, &n) in m.spec_k_hist.iter().enumerate() {
                    hist.insert(format!("{k}"), n as usize);
                }
                o.insert("k_hist", hist);
                let trace: Vec<Value> = engine
                    .spec_k_trace()
                    .iter()
                    .map(|&k| Value::from(k as usize))
                    .collect();
                o.insert("k_trace", Value::Array(trace));
            }
            rows.push(Value::Object(o));
        }
    }
    Ok(rows)
}

/// Multi-replica routing comparison over the deterministic mock backend
/// (runs without artifacts): the same multi-tenant skewed-prefix trace
/// is routed across N replicas (for each N in `replica_counts`) under
/// each [`crate::config::RouterPolicy`].  Every run is asserted
/// token-identical to the first (greedy + ignore_eos; engine outputs are
/// placement-invariant, so routing must never change what a request
/// gets back).  The deltas are:
///
/// * **cluster Eq. 12 throughput** — total generated tokens over the
///   busiest replica's simulated busy seconds (replicas run in
///   parallel, so the slowest one sets the cluster's finishing time);
/// * **per-replica spread** — [`crate::platform::replica_imbalance`] of
///   the busy seconds and of the decode-batch occupancy gauges;
/// * **cluster prefix-hit rate** — reused blocks over the total full
///   prompt blocks submitted (the same denominator for every policy, so
///   rates compare directly).
pub fn run_router_compare(
    replica_counts: &[usize],
    spec: &MultiTenantSpec,
) -> Result<Vec<Value>> {
    use crate::config::{RouterPolicy, COOPT};
    use crate::platform::replica_imbalance;
    use crate::router::Router;
    use crate::runtime::mock::MockBackend;
    use crate::tokenizer::Tokenizer;

    let trace = multi_tenant_trace(spec);
    // the hit-rate denominator is policy- and N-invariant: full prompt
    // blocks submitted, computed once over the trace
    let tokenizer = Tokenizer::new();
    let block_size = MockBackend::new().geometry().block_size;
    let opportunities: usize = trace
        .iter()
        .map(|req| tokenizer.encode(&req.prompt, true, false).len() / block_size)
        .sum();
    let mut baseline: Option<Vec<Vec<u32>>> = None;
    let mut rows = Vec::new();
    for &n in replica_counts {
        for policy in RouterPolicy::ALL {
            let engines: Vec<Engine<MockBackend>> = (0..n)
                .map(|_| {
                    Engine::new(
                        MockBackend::new().with_opt(COOPT),
                        EngineConfig::new("llama-7b-sim", COOPT),
                    )
                })
                .collect();
            let mut router = Router::new(engines, policy);
            for req in &trace {
                router.submit(GenRequest {
                    prompt: req.prompt.clone(),
                    max_new_tokens: req.max_new_tokens,
                    sampling: req.sampling,
                    // fixed token counts across policies => clean deltas
                    ignore_eos: true,
                    corr_id: None,
                    class: ReqClass::default(),
                })?;
            }
            let results = router.run_to_completion()?;
            let outs: Vec<Vec<u32>> = results.iter().map(|r| r.result.tokens.clone()).collect();
            match &baseline {
                None => baseline = Some(outs),
                Some(base) => {
                    if *base != outs {
                        anyhow::bail!(
                            "routing changed outputs at replicas={n} policy={}",
                            policy.name()
                        );
                    }
                }
            }
            let mut routed_counts = vec![0usize; n];
            for r in &results {
                routed_counts[r.replica] += 1;
            }
            let mut busy: Vec<f64> = Vec::with_capacity(n);
            let mut occupancy: Vec<f64> = Vec::with_capacity(n);
            let mut tokens = 0u64;
            let mut hits = 0u64;
            for e in router.replicas() {
                let m = &e.metrics;
                busy.push(m.sim_prefill_s + m.sim_decode_s + m.sim_swap_blocked_s);
                occupancy.push(m.decode_batch_occupancy());
                tokens += m.tokens_generated;
                hits += e.cache_stats().prefix_hits;
            }
            let busy_max = busy.iter().cloned().fold(0.0f64, f64::max);
            let mut o = Object::new();
            o.insert("policy", policy.name());
            o.insert("replicas", n);
            o.insert("requests", trace.len());
            o.insert("tokens", tokens as usize);
            o.insert(
                "cluster_throughput_sim",
                if busy_max > 0.0 {
                    tokens as f64 / busy_max
                } else {
                    0.0
                },
            );
            o.insert("busy_max_s", busy_max);
            o.insert("busy_spread", replica_imbalance(&busy));
            o.insert("occupancy_spread", replica_imbalance(&occupancy));
            o.insert("prefix_hits", hits as usize);
            o.insert("prefix_block_opportunities", opportunities);
            o.insert(
                "prefix_hit_rate",
                if opportunities > 0 {
                    hits as f64 / opportunities as f64
                } else {
                    0.0
                },
            );
            o.insert("token_identical", true);
            o.insert(
                "routed",
                Value::Array(routed_counts.into_iter().map(Value::from).collect()),
            );
            rows.push(Value::Object(o));
        }
    }
    Ok(rows)
}

/// Cluster-wide prefix reuse: the Zipfian multi-tenant trace driven
/// *open-loop* (one [`crate::router::Router::step_all`] per arrival, so
/// earlier requests' prefix blocks are still live when later ones
/// route) across an N-replica cluster under `prefix_affinity` (PR 5's
/// leading-block owner map) vs `directory` (the cluster
/// [`crate::router::directory::PrefixDirectory`] with cross-replica KV
/// pulls).  Both policies share the imbalance fallback; the difference
/// under test is what fallback *costs*: affinity re-prefills the shared
/// prefix on the spill replica, the directory pulls the warm chain over
/// PCIe first (priced by
/// [`crate::platform::CostModel::prefix_pull_pays`]), so those blocks
/// still land as prefix hits.  Rows report the cluster hit rate over a
/// policy-invariant denominator (full prompt blocks in the trace), the
/// Eq. 12 cluster throughput (pull transfer time is on the destination
/// critical path via `sim_swap_blocked_s`, so the win is net of the
/// PCIe bill), and the pull ledger; outputs are hard-asserted
/// token-identical to a single unconstrained engine.
pub fn run_global_prefix_reuse(
    replica_counts: &[usize],
    spec: &MultiTenantSpec,
) -> Result<Vec<Value>> {
    use crate::config::{RouterPolicy, COOPT};
    use crate::router::Router;
    use crate::runtime::mock::MockBackend;
    use crate::tokenizer::Tokenizer;

    let trace = multi_tenant_trace(spec);
    let reqs: Vec<GenRequest> = trace
        .iter()
        .map(|req| GenRequest {
            prompt: req.prompt.clone(),
            max_new_tokens: req.max_new_tokens,
            sampling: req.sampling,
            // fixed token counts across policies => clean Eq. 12 deltas
            ignore_eos: true,
            corr_id: None,
            class: ReqClass::default(),
        })
        .collect();
    let tokenizer = Tokenizer::new();
    let block_size = MockBackend::new().geometry().block_size;
    let opportunities: usize = reqs
        .iter()
        .map(|req| tokenizer.encode(&req.prompt, true, false).len() / block_size)
        .sum();
    // token-identity reference: one unconstrained engine
    let mut reference = Engine::new(
        MockBackend::new().with_opt(COOPT),
        EngineConfig::new("llama-7b-sim", COOPT),
    );
    let base: Vec<Vec<u32>> = reference
        .generate(reqs.clone())?
        .into_iter()
        .map(|r| r.tokens)
        .collect();

    let mut rows = Vec::new();
    for &n in replica_counts {
        for policy in [RouterPolicy::PrefixAffinity, RouterPolicy::Directory] {
            let engines: Vec<Engine<MockBackend>> = (0..n)
                .map(|_| {
                    Engine::new(
                        MockBackend::new().with_opt(COOPT),
                        // the host pool is the pull transport's staging
                        // tier; both policies get it so capacity is equal
                        EngineConfig::new("llama-7b-sim", COOPT).with_host_pool(64),
                    )
                })
                .collect();
            let mut router = Router::new(engines, policy);
            for req in &reqs {
                router.submit(req.clone())?;
                // open-loop arrival pacing: one cluster step per arrival
                // keeps tens of sequences in flight, so the hot tenant's
                // replica saturates (tripping the imbalance fallback)
                // while its prefix blocks are still resident to pull
                router.step_all()?;
            }
            let results = router.run_to_completion()?;
            let outs: Vec<Vec<u32>> = results.iter().map(|r| r.result.tokens.clone()).collect();
            if outs != base {
                anyhow::bail!(
                    "prefix reuse changed outputs at replicas={n} policy={}",
                    policy.name()
                );
            }
            let mut busy: Vec<f64> = Vec::with_capacity(n);
            let mut tokens = 0u64;
            let mut hits = 0u64;
            let (mut pulls, mut pull_blocks, mut pull_bytes) = (0u64, 0u64, 0u64);
            let (mut pull_blocks_out, mut pull_stale) = (0u64, 0u64);
            for e in router.replicas() {
                let m = &e.metrics;
                busy.push(m.sim_prefill_s + m.sim_decode_s + m.sim_swap_blocked_s);
                tokens += m.tokens_generated;
                hits += e.cache_stats().prefix_hits;
                pulls += m.prefix_pulls;
                pull_blocks += m.prefix_pull_blocks;
                pull_bytes += m.prefix_pull_bytes;
                pull_blocks_out += m.prefix_pull_blocks_out;
                pull_stale += m.prefix_pull_stale;
            }
            let busy_max = busy.iter().cloned().fold(0.0f64, f64::max);
            let dir = router.directory();
            let mut o = Object::new();
            o.insert("policy", policy.name());
            o.insert("replicas", n);
            o.insert("requests", reqs.len());
            o.insert("tokens", tokens as usize);
            o.insert(
                "cluster_throughput_sim",
                if busy_max > 0.0 {
                    tokens as f64 / busy_max
                } else {
                    0.0
                },
            );
            o.insert("busy_max_s", busy_max);
            o.insert("prefix_hits", hits as usize);
            o.insert("prefix_block_opportunities", opportunities);
            o.insert(
                "prefix_hit_rate",
                if opportunities > 0 {
                    hits as f64 / opportunities as f64
                } else {
                    0.0
                },
            );
            o.insert("prefix_pulls", pulls as usize);
            o.insert("prefix_pull_blocks", pull_blocks as usize);
            o.insert("prefix_pull_bytes", pull_bytes as usize);
            o.insert("prefix_pull_blocks_out", pull_blocks_out as usize);
            o.insert("prefix_pull_stale", pull_stale as usize);
            o.insert("directory_device_hits", dir.device_hits as usize);
            o.insert("directory_host_hits", dir.host_hits as usize);
            o.insert("directory_evictions", dir.evictions as usize);
            o.insert("token_identical", true);
            rows.push(Value::Object(o));
        }
    }
    Ok(rows)
}

/// Disaggregated prefill/decode comparison: the bursty long-prefill +
/// steady-decode trace ([`crate::workload::pd_trace`]) routed across a
/// 4-replica cluster twice — once with specialized roles (two prefill
/// replicas handing KV off through the host tier to two decode
/// replicas) and once all-mixed (PR 5's uniform cluster).  Hand-off is
/// unpriced so the split actually activates on every prefill-heavy
/// request; both runs are asserted token-identical to an unconstrained
/// single engine.  The headline delta is the cluster decode ITL p95:
/// mixed replicas stall their decode batches behind every burst's
/// one-shot prefill, while decode-role replicas only ever pay short
/// steady prefills and block imports.  Rows also report the migration
/// bill (blocks shipped, bytes over PCIe, tokens re-prefilled on the
/// fallback path) so the hand-off's cost side stays visible.
pub fn run_pd_compare(spec: &PdTraceSpec) -> Result<Vec<Value>> {
    use crate::config::{ReplicaRole, RouterPolicy, SwapPolicy, COOPT};
    use crate::platform::replica_imbalance;
    use crate::router::Router;
    use crate::runtime::mock::MockBackend;

    let trace = pd_trace(spec);
    let reqs: Vec<GenRequest> = trace
        .iter()
        .map(|req| GenRequest {
            prompt: req.prompt.clone(),
            max_new_tokens: req.max_new_tokens,
            sampling: req.sampling,
            // fixed token counts across modes => clean ITL deltas
            ignore_eos: true,
            corr_id: None,
            class: ReqClass::default(),
        })
        .collect();
    // token-identity reference: one unconstrained engine, no tiering
    let mut reference = Engine::new(
        MockBackend::new().with_opt(COOPT),
        EngineConfig::new("llama-7b-sim", COOPT),
    );
    let base: Vec<Vec<u32>> = reference
        .generate(reqs.clone())?
        .into_iter()
        .map(|r| r.tokens)
        .collect();

    let modes: [(&'static str, [ReplicaRole; 4]); 2] = [
        (
            "pd_split",
            [
                ReplicaRole::Prefill,
                ReplicaRole::Prefill,
                ReplicaRole::Decode,
                ReplicaRole::Decode,
            ],
        ),
        ("mixed", [ReplicaRole::Mixed; 4]),
    ];
    let mut rows = Vec::new();
    for (mode, roles) in modes {
        let engines: Vec<Engine<MockBackend>> = roles
            .iter()
            .map(|&role| {
                Engine::new(
                    MockBackend::new().with_opt(COOPT),
                    EngineConfig::new("llama-7b-sim", COOPT)
                        .with_host_pool(96)
                        .with_swap_policy(SwapPolicy::Always)
                        .with_role(role),
                )
            })
            .collect();
        let mut router = Router::new(engines, RouterPolicy::LeastLoaded).with_unpriced_handoff();
        for req in &reqs {
            router.submit(req.clone())?;
        }
        let results = router.run_to_completion()?;
        let outs: Vec<Vec<u32>> = results.iter().map(|r| r.result.tokens.clone()).collect();
        if outs != base {
            anyhow::bail!("disaggregation changed outputs in mode {mode}");
        }
        let mut busy: Vec<f64> = Vec::new();
        let mut tokens = 0u64;
        let (mut itl_p50, mut itl_p95) = (0.0f64, 0.0f64);
        let (mut mig_out, mut mig_in) = (0u64, 0u64);
        let (mut mig_blocks, mut mig_bytes) = (0u64, 0u64);
        let (mut fallbacks, mut recomputed) = (0u64, 0u64);
        for e in router.replicas_mut() {
            let m = &mut e.metrics;
            busy.push(m.sim_prefill_s + m.sim_decode_s + m.sim_swap_blocked_s);
            tokens += m.tokens_generated;
            // cluster decode tail = the worst replica's tail (role-pure
            // prefill replicas take no decode steps and drop out as NaN)
            itl_p50 = itl_p50.max(m.itl_sim.p50());
            itl_p95 = itl_p95.max(m.itl_sim.p95());
            mig_out += m.migrations_out;
            mig_in += m.migrations_in;
            mig_blocks += m.migrated_blocks_out;
            mig_bytes += m.migration_bytes;
            fallbacks += m.migrations_token_fallback;
            recomputed += m.tokens_recomputed;
        }
        let busy_max = busy.iter().cloned().fold(0.0f64, f64::max);
        let mut o = Object::new();
        o.insert("mode", mode);
        o.insert("replicas", roles.len());
        o.insert(
            "roles",
            Value::Array(roles.iter().map(|r| Value::from(r.name())).collect()),
        );
        o.insert("requests", trace.len());
        o.insert("tokens", tokens as usize);
        o.insert("decode_itl_sim_p50_s", itl_p50);
        o.insert("decode_itl_sim_p95_s", itl_p95);
        o.insert(
            "cluster_throughput_sim",
            if busy_max > 0.0 { tokens as f64 / busy_max } else { 0.0 },
        );
        o.insert("busy_max_s", busy_max);
        o.insert("busy_spread", replica_imbalance(&busy));
        o.insert("migrations_out", mig_out as usize);
        o.insert("migrations_in", mig_in as usize);
        o.insert("migrated_blocks", mig_blocks as usize);
        o.insert("migration_bytes", mig_bytes as usize);
        o.insert("migrations_token_fallback", fallbacks as usize);
        o.insert("tokens_recomputed", recomputed as usize);
        o.insert("token_identical", true);
        rows.push(Value::Object(o));
    }
    Ok(rows)
}

/// Tracing-overhead comparison: the same multi-tenant Zipfian trace
/// ([`crate::workload::multi_tenant_trace`]) driven through two
/// identically configured engines — one with the flight recorder and
/// full event sampling on (`trace_depth` 64, `trace_sample` 1.0), one
/// with tracing off (`trace_depth` 0, `trace_sample` 0.0).  Outputs
/// are asserted token-identical (tracing must never perturb
/// scheduling), and the headline number is the Eq. 12
/// simulated-throughput ratio traced / untraced: trace bookkeeping
/// runs on the wallclock only and adds zero simulated Z100 seconds,
/// so the ratio is exactly 1.0 by construction — CI gates it at
/// ≥ 0.97 as regression margin against anyone pricing tracing into
/// the sim clock.  Every row also reports the worst per-request
/// phase-reconciliation error (`|phase_sum − e2e|`; the wall-phase
/// partition must telescope with no gaps and no double counts), and
/// the traced run exports its flight recorder as a Chrome
/// `trace_event` file under `target/bench-reports/` for
/// `chrome://tracing` / Perfetto.
pub fn run_observability_compare(spec: &MultiTenantSpec) -> Result<Vec<Value>> {
    use crate::config::COOPT;
    use crate::runtime::mock::MockBackend;

    let trace = multi_tenant_trace(spec);
    let reqs: Vec<GenRequest> = trace
        .iter()
        .enumerate()
        .map(|(i, req)| GenRequest {
            prompt: req.prompt.clone(),
            max_new_tokens: req.max_new_tokens,
            sampling: req.sampling,
            // fixed token counts across modes => clean overhead deltas
            ignore_eos: true,
            // exercise correlation ids end-to-end in the traced run
            corr_id: Some(format!("mt/req-{i}")),
            class: ReqClass::default(),
        })
        .collect();

    let modes: [(&'static str, usize, f64); 2] = [("traced", 64, 1.0), ("untraced", 0, 0.0)];
    let mut baseline: Option<Vec<Vec<u32>>> = None;
    let mut throughput = [0.0f64; 2];
    let mut rows = Vec::new();
    for (mi, (mode, depth, sample)) in modes.into_iter().enumerate() {
        let mut engine = Engine::new(
            MockBackend::new().with_opt(COOPT),
            EngineConfig::new("llama-7b-sim", COOPT)
                .with_trace_depth(depth)
                .with_trace_sample(sample),
        );
        let results = engine.generate(reqs.clone())?;
        let outs: Vec<Vec<u32>> = results.iter().map(|r| r.tokens.clone()).collect();
        match &baseline {
            None => baseline = Some(outs),
            Some(base) => {
                if *base != outs {
                    anyhow::bail!("tracing changed outputs in mode {mode}");
                }
            }
        }
        let m = &engine.metrics;
        let busy = m.sim_prefill_s + m.sim_decode_s + m.sim_swap_blocked_s;
        let tput = if busy > 0.0 {
            m.tokens_generated as f64 / busy
        } else {
            0.0
        };
        throughput[mi] = tput;
        let max_err = results
            .iter()
            .map(|r| (r.phases.phase_sum_s() - r.latency_s).abs())
            .fold(0.0f64, f64::max);
        let mut o = Object::new();
        o.insert("mode", mode);
        o.insert("trace_depth", depth);
        o.insert("trace_sample", sample);
        o.insert("requests", trace.len());
        o.insert("tokens", m.tokens_generated as usize);
        o.insert("throughput_sim", tput);
        o.insert("busy_s", busy);
        o.insert("phase_reconcile_max_err_s", max_err);
        o.insert("token_identical", true);
        if depth > 0 {
            let dump = engine.trace_json(None, None);
            let per_req: Vec<(usize, Value)> = dump
                .as_array()
                .map(|a| a.iter().map(|t| (0usize, t.clone())).collect())
                .unwrap_or_default();
            o.insert("trace_requests", per_req.len());
            let chrome = crate::obs::chrome_trace(&per_req);
            let dir = std::path::Path::new("target/bench-reports");
            std::fs::create_dir_all(dir)?;
            let path = dir.join("trace_observability.json");
            std::fs::write(&path, chrome.to_string_pretty())?;
            o.insert("chrome_trace_path", path.to_string_lossy().to_string());
        }
        rows.push(Value::Object(o));
    }
    // traced over untraced; both runs generate identical token counts,
    // so this is purely a sim-clock accounting check
    let ratio = if throughput[1] > 0.0 {
        throughput[0] / throughput[1]
    } else {
        1.0
    };
    if let Value::Object(o) = &mut rows[0] {
        o.insert("sim_throughput_ratio", ratio);
    }
    Ok(rows)
}

/// Exact percentile over raw samples (sorted in place).  The SLO bench
/// gates strict on-vs-off inequalities, so it wants exact order
/// statistics rather than [`crate::metrics::LatencyHist`]'s log-bucket
/// approximation.
fn pctile(vals: &mut [f64], q: f64) -> f64 {
    if vals.is_empty() {
        return 0.0;
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((vals.len() as f64 - 1.0) * q).round() as usize;
    vals[idx.min(vals.len() - 1)]
}

/// SLO-aware overload control under ~2x-capacity traffic: the Zipfian
/// multi-tenant trace ([`crate::workload::multi_tenant_trace`]) with the
/// 1:3 interactive:batch class mix ([`crate::workload::slo_classes`])
/// driven open-loop (two cluster steps per arrival) into a single
/// replica whose KV pool and decode lanes are halved — offered work is
/// roughly twice what the replica drains, so a queue *must* build.  The
/// trace runs twice:
///
/// * **slo_on** — requests carry their classes, the router admission
///   controller sheds batch work (bounded batch queue + projected-wait
///   + per-tenant share), the scheduler serves interactive first and
///   picks batch lanes as preemption victims, and deadline enforcement
///   cancels the expired-head batch requests at a step boundary;
/// * **slo_off** — the same offered work untagged (every request
///   defaults to interactive, no deadlines): the exact pre-SLO
///   first-come-first-served behaviour.
///
/// Every served request is checked against an unconstrained
/// single-engine reference: normally-finished requests must be
/// token-identical, deadline-cancelled ones must be a strict prefix
/// (greedy decode is placement- and schedule-invariant, so overload
/// control may decide *whether/when* a request runs, never *what* it
/// generates).  Rows carry per-class wall TTFT/ITL/E2E order statistics
/// plus the shed/cancellation ledger; CI gates interactive tails
/// strictly better with control on, batch degradation bounded, and the
/// conservation law offered = completed + shed + expired per class.
pub fn run_slo_overload(spec: &MultiTenantSpec, mix: &SloMix) -> Result<Vec<Value>> {
    use crate::config::{CacheGeometry, RouterPolicy, SloConfig, COOPT};
    use crate::coordinator::FinishReason;
    use crate::router::{Router, SHED_MARKER};
    use crate::runtime::mock::MockBackend;

    let trace = multi_tenant_trace(spec);
    let classes = slo_classes(&trace, mix);
    let n = trace.len();
    let plain: Vec<GenRequest> = trace
        .iter()
        .enumerate()
        .map(|(i, req)| GenRequest {
            prompt: req.prompt.clone(),
            max_new_tokens: req.max_new_tokens,
            sampling: req.sampling,
            // fixed token counts across modes => clean tail deltas
            ignore_eos: true,
            // the index rides in the correlation id: shed requests never
            // produce a result, so positional alignment cannot work
            corr_id: Some(format!("slo/{i}")),
            class: ReqClass::default(),
        })
        .collect();
    // token-identity reference: one unconstrained engine, default
    // geometry, untagged
    let mut reference = Engine::new(
        MockBackend::new().with_opt(COOPT),
        EngineConfig::new("llama-7b-sim", COOPT),
    );
    let base: Vec<Vec<u32>> = reference
        .generate(plain.clone())?
        .into_iter()
        .map(|r| r.tokens)
        .collect();

    // undersized serving replica: half the KV pool, half the decode
    // lanes of the reference geometry — the paced arrivals offer about
    // twice what this replica can drain
    let tight = CacheGeometry {
        num_pool_blocks: 48,
        max_batch: 4,
        ..CacheGeometry::default()
    };
    const STEPS_PER_ARRIVAL: usize = 2;
    let slo = SloConfig {
        admission: true,
        interactive_ttft_ms: 2000,
        interactive_prefill_reserve: 0.5,
        tenant_share: 0.9,
        max_batch_queue: 8,
    };

    let mut rows = Vec::new();
    for control_on in [true, false] {
        let cfg = if control_on {
            EngineConfig::new("llama-7b-sim", COOPT)
                .with_slo_admission(true)
                .with_interactive_ttft_ms(slo.interactive_ttft_ms)
                .with_interactive_prefill_reserve(slo.interactive_prefill_reserve)
        } else {
            EngineConfig::new("llama-7b-sim", COOPT)
        };
        let engine = Engine::new(
            PoolSized {
                inner: MockBackend::new().with_opt(COOPT),
                geometry: tight,
            },
            cfg,
        );
        let mut router = Router::new(vec![engine], RouterPolicy::LeastLoaded);
        if control_on {
            router = router.with_slo(slo);
        }
        let mut shed_idx: Vec<usize> = Vec::new();
        for (i, req) in plain.iter().enumerate() {
            let mut req = req.clone();
            if control_on {
                req.class = classes[i].clone();
            }
            match router.submit(req) {
                Ok(_) => {}
                Err(e) if e.to_string().starts_with(SHED_MARKER) => shed_idx.push(i),
                Err(e) => return Err(e),
            }
            for _ in 0..STEPS_PER_ARRIVAL {
                router.step_all()?;
            }
        }
        let results = router.run_to_completion()?;
        let mut finished: Vec<Option<crate::coordinator::GenResult>> = vec![None; n];
        for r in results {
            let idx = r
                .result
                .corr_id
                .as_deref()
                .and_then(|c| c.strip_prefix("slo/"))
                .and_then(|s| s.parse::<usize>().ok())
                .ok_or_else(|| anyhow::anyhow!("result lost its slo/<i> correlation id"))?;
            match r.result.finish {
                FinishReason::DeadlineExceeded => {
                    if !base[idx].starts_with(&r.result.tokens) {
                        anyhow::bail!("cancelled request {idx} diverged from the reference");
                    }
                }
                _ => {
                    if r.result.tokens != base[idx] {
                        anyhow::bail!("overload control changed outputs at request {idx}");
                    }
                }
            }
            finished[idx] = Some(r.result);
        }

        let (mut int_offered, mut batch_offered) = (0usize, 0usize);
        let (mut int_completed, mut batch_completed) = (0usize, 0usize);
        let (mut int_shed, mut batch_shed) = (0usize, 0usize);
        let (mut int_expired, mut batch_expired) = (0usize, 0usize);
        let (mut ttft_i, mut itl_i, mut e2e_b) = (Vec::new(), Vec::new(), Vec::new());
        for (i, class) in classes.iter().enumerate() {
            let interactive = class.priority.is_interactive();
            if interactive {
                int_offered += 1;
            } else {
                batch_offered += 1;
            }
            if shed_idx.contains(&i) {
                if interactive {
                    int_shed += 1;
                } else {
                    batch_shed += 1;
                }
                continue;
            }
            let Some(r) = &finished[i] else {
                anyhow::bail!("request {i} neither shed nor finished (leaked)");
            };
            if r.finish == FinishReason::DeadlineExceeded {
                if interactive {
                    int_expired += 1;
                } else {
                    batch_expired += 1;
                }
                continue;
            }
            if interactive {
                int_completed += 1;
                ttft_i.push(r.ttft_s);
                if r.generated_tokens >= 2 {
                    itl_i.push((r.latency_s - r.ttft_s) / (r.generated_tokens - 1) as f64);
                }
            } else {
                batch_completed += 1;
                e2e_b.push(r.latency_s);
            }
        }
        // conservation per class: nothing vanishes, nothing double-counts
        if int_completed + int_shed + int_expired != int_offered
            || batch_completed + batch_shed + batch_expired != batch_offered
        {
            anyhow::bail!(
                "class conservation violated: interactive {int_completed}+{int_shed}+\
                 {int_expired} != {int_offered} or batch {batch_completed}+{batch_shed}+\
                 {batch_expired} != {batch_offered}"
            );
        }
        let (mut cancels, mut preemptions, mut tokens) = (0u64, 0u64, 0u64);
        for e in router.replicas() {
            cancels += e.metrics.deadline_cancellations;
            preemptions += e.metrics.preemptions;
            tokens += e.metrics.tokens_generated;
        }
        let mut o = Object::new();
        o.insert("mode", if control_on { "slo_on" } else { "slo_off" });
        o.insert("control", control_on);
        o.insert("replicas", 1usize);
        o.insert("steps_per_arrival", STEPS_PER_ARRIVAL);
        o.insert("offered", n);
        o.insert("shed_requests", router.shed_requests() as usize);
        o.insert("deadline_cancellations", cancels as usize);
        o.insert("preemptions", preemptions as usize);
        o.insert("tokens", tokens as usize);
        o.insert("interactive_offered", int_offered);
        o.insert("interactive_completed", int_completed);
        o.insert("interactive_shed", int_shed);
        o.insert("interactive_expired", int_expired);
        o.insert("interactive_ttft_wall_p99_s", pctile(&mut ttft_i, 0.99));
        o.insert("interactive_itl_wall_p95_s", pctile(&mut itl_i, 0.95));
        o.insert("batch_offered", batch_offered);
        o.insert("batch_completed", batch_completed);
        o.insert("batch_shed", batch_shed);
        o.insert("batch_expired", batch_expired);
        o.insert("batch_e2e_wall_p95_s", pctile(&mut e2e_b, 0.95));
        o.insert("token_identical", true);
        rows.push(Value::Object(o));
    }
    Ok(rows)
}

/// Forecast-driven control under bursty multi-tenant traffic: the
/// Zipfian trace with the 1:3 interactive:batch mix, paced as
/// alternating calm and burst phases of twelve arrivals each — calm
/// offers one request per six cluster steps (under capacity, the queue
/// drains), a burst offers two per step (far over capacity, the queue
/// *must* build) — into two undersized replicas behind the sync
/// least-loaded router.  Admission control is on in **both** modes with
/// the projected-wait rule parked out of reach (a budget no trace can
/// spend), so the bounded batch queue is the only live shed rule and
/// the schedule difference between modes is exactly the predictive
/// plane's doing:
///
/// * **forecast_on** — the router's signal ring scores each burst
///   onset against its post-horizon arrival rate; once the detector is
///   in band, [`crate::router::tightened_slo`] halves the batch-queue
///   bound for the *next* scored burst (batch sheds earlier into the
///   wave), per-tenant length quantiles cap the routing cost estimate,
///   and the engines' planes raise the eviction watermark and steer
///   victim choice;
/// * **forecast_off** — the identical offered work and admission knobs
///   with the plane disabled: the reactive status quo.
///
/// Output lengths cycle per tenant over a three-value set (tenant `t`
/// draws `8+6t`, `10+6t`, `12+6t` tokens), so the length estimator has
/// real per-tenant structure to learn and its window p90 — and hence
/// the pooled coverage the CI gates on — is deterministic run to run.
/// Every served request is checked token-identical against an
/// unconstrained single-engine reference (forecasting may decide
/// *whether/when* a request runs, never *what* it generates).  Rows
/// carry full-run and post-warm-up interactive tails (the post-warm-up
/// window starts at the run's midpoint, after the detector has scored
/// enough bursts to act), the shed ledger, Eq. 12 cluster throughput,
/// and the plane's calibration counters.
pub fn run_predictive_control(spec: &MultiTenantSpec) -> Result<Vec<Value>> {
    use crate::config::{CacheGeometry, ForecastConfig, RouterPolicy, SloConfig, COOPT};
    use crate::coordinator::FinishReason;
    use crate::router::{Router, SHED_MARKER};
    use crate::runtime::mock::MockBackend;

    let trace = multi_tenant_trace(spec);
    // no expired-head cancellations and a deadline far beyond any wall
    // runtime: every admitted request must finish normally, so token
    // identity is strict equality over the whole served set
    let mix = SloMix {
        interactive_every: 4,
        interactive_deadline_ms: 600_000,
        expired_head: 0,
    };
    let classes = slo_classes(&trace, &mix);
    let n = trace.len();
    let mut seen = vec![0usize; spec.tenants.max(1)];
    let plain: Vec<GenRequest> = trace
        .iter()
        .enumerate()
        .map(|(i, req)| {
            let t_idx = classes[i]
                .tenant
                .as_deref()
                .and_then(|t| t.strip_prefix("tenant"))
                .and_then(|s| s.parse::<usize>().ok())
                .unwrap_or(0)
                .min(seen.len() - 1);
            let k = seen[t_idx];
            seen[t_idx] += 1;
            GenRequest {
                prompt: req.prompt.clone(),
                // fixed token counts across modes => clean tail deltas
                max_new_tokens: (8 + 6 * t_idx + 2 * (k % 3)).min(spec.max_new.max(12)),
                sampling: req.sampling,
                ignore_eos: true,
                // the index rides in the correlation id: shed requests
                // never produce a result, so positional alignment
                // cannot work
                corr_id: Some(format!("pred/{i}")),
                class: ReqClass::default(),
            }
        })
        .collect();
    // token-identity reference: one unconstrained engine, default
    // geometry, untagged
    let mut reference = Engine::new(
        MockBackend::new().with_opt(COOPT),
        EngineConfig::new("llama-7b-sim", COOPT),
    );
    let base: Vec<Vec<u32>> = reference
        .generate(plain.clone())?
        .into_iter()
        .map(|r| r.tokens)
        .collect();

    let tight = CacheGeometry {
        num_pool_blocks: 48,
        max_batch: 4,
        ..CacheGeometry::default()
    };
    let slo = SloConfig {
        admission: true,
        // parked out of reach: the projected-wait rule must never fire,
        // so the on/off difference cannot ride on a wall-clock wait
        // projection — the same requests shed on any machine
        interactive_ttft_ms: 1_000_000,
        interactive_prefill_reserve: 0.5,
        tenant_share: 1.0,
        max_batch_queue: 6,
    };
    const REPLICAS: usize = 2;
    const ARRIVALS_PER_PHASE: usize = 12;
    const CALM_STEPS: usize = 6;
    let fc = ForecastConfig {
        enabled: true,
        warmup: 4,
        ..ForecastConfig::default()
    };

    let mut rows = Vec::new();
    for forecast_on in [true, false] {
        let mut cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_slo_admission(true)
            .with_interactive_ttft_ms(slo.interactive_ttft_ms)
            .with_interactive_prefill_reserve(slo.interactive_prefill_reserve);
        if forecast_on {
            cfg = cfg
                .with_forecast(true)
                .with_forecast_warmup(fc.warmup)
                .with_forecast_burst_ratio(fc.burst_ratio);
        }
        let engines: Vec<_> = (0..REPLICAS)
            .map(|_| {
                Engine::new(
                    PoolSized {
                        inner: MockBackend::new().with_opt(COOPT),
                        geometry: tight,
                    },
                    cfg.clone(),
                )
            })
            .collect();
        let mut router = Router::new(engines, RouterPolicy::LeastLoaded).with_slo(slo);
        if forecast_on {
            router = router.with_forecast(fc);
        }
        let mut shed_idx: Vec<usize> = Vec::new();
        for (i, req) in plain.iter().enumerate() {
            let mut req = req.clone();
            req.class = classes[i].clone();
            match router.submit(req) {
                Ok(_) => {}
                Err(e) if e.to_string().starts_with(SHED_MARKER) => shed_idx.push(i),
                Err(e) => return Err(e),
            }
            let in_burst = (i / ARRIVALS_PER_PHASE) % 2 == 1;
            let steps = if in_burst { i % 2 } else { CALM_STEPS };
            for _ in 0..steps {
                router.step_all()?;
            }
        }
        let results = router.run_to_completion()?;
        let mut finished: Vec<Option<crate::coordinator::GenResult>> = vec![None; n];
        for r in results {
            let idx = r
                .result
                .corr_id
                .as_deref()
                .and_then(|c| c.strip_prefix("pred/"))
                .and_then(|s| s.parse::<usize>().ok())
                .ok_or_else(|| anyhow::anyhow!("result lost its pred/<i> correlation id"))?;
            match r.result.finish {
                FinishReason::DeadlineExceeded => {
                    if !base[idx].starts_with(&r.result.tokens) {
                        anyhow::bail!("cancelled request {idx} diverged from the reference");
                    }
                }
                _ => {
                    if r.result.tokens != base[idx] {
                        anyhow::bail!("forecast-driven control changed outputs at request {idx}");
                    }
                }
            }
            finished[idx] = Some(r.result);
        }

        // the detector needs the first half of the run to score enough
        // bursts to act, so the post-warm-up tails (second half) are
        // where the two modes genuinely differ
        let warm = n / 2;
        let (mut int_offered, mut batch_offered) = (0usize, 0usize);
        let (mut int_completed, mut batch_completed) = (0usize, 0usize);
        let (mut int_shed, mut batch_shed) = (0usize, 0usize);
        let (mut int_expired, mut batch_expired) = (0usize, 0usize);
        let (mut q_i, mut ttft_i, mut e2e_b) = (Vec::new(), Vec::new(), Vec::new());
        let (mut q_i_pw, mut ttft_i_pw) = (Vec::new(), Vec::new());
        for (i, class) in classes.iter().enumerate() {
            let interactive = class.priority.is_interactive();
            if interactive {
                int_offered += 1;
            } else {
                batch_offered += 1;
            }
            if shed_idx.contains(&i) {
                if interactive {
                    int_shed += 1;
                } else {
                    batch_shed += 1;
                }
                continue;
            }
            let Some(r) = &finished[i] else {
                anyhow::bail!("request {i} neither shed nor finished (leaked)");
            };
            if r.finish == FinishReason::DeadlineExceeded {
                if interactive {
                    int_expired += 1;
                } else {
                    batch_expired += 1;
                }
                continue;
            }
            if interactive {
                int_completed += 1;
                q_i.push(r.phases.queue_s);
                ttft_i.push(r.ttft_s);
                if i >= warm {
                    q_i_pw.push(r.phases.queue_s);
                    ttft_i_pw.push(r.ttft_s);
                }
            } else {
                batch_completed += 1;
                e2e_b.push(r.latency_s);
            }
        }
        // conservation per class: nothing vanishes, nothing double-counts
        if int_completed + int_shed + int_expired != int_offered
            || batch_completed + batch_shed + batch_expired != batch_offered
        {
            anyhow::bail!(
                "class conservation violated: interactive {int_completed}+{int_shed}+\
                 {int_expired} != {int_offered} or batch {batch_completed}+{batch_shed}+\
                 {batch_expired} != {batch_offered}"
            );
        }
        let (mut preemptions, mut tokens) = (0u64, 0u64);
        let mut busy_max = 0.0f64;
        for e in router.replicas() {
            preemptions += e.metrics.preemptions;
            tokens += e.metrics.tokens_generated;
            let busy =
                e.metrics.sim_prefill_s + e.metrics.sim_decode_s + e.metrics.sim_swap_blocked_s;
            busy_max = busy_max.max(busy);
        }
        let mut o = Object::new();
        o.insert("mode", if forecast_on { "forecast_on" } else { "forecast_off" });
        o.insert("forecast", forecast_on);
        o.insert("replicas", REPLICAS);
        o.insert("offered", n);
        o.insert("postwarm_from", warm);
        o.insert("shed_requests", router.shed_requests() as usize);
        o.insert("preemptions", preemptions as usize);
        o.insert("tokens", tokens as usize);
        o.insert("interactive_offered", int_offered);
        o.insert("interactive_completed", int_completed);
        o.insert("interactive_shed", int_shed);
        o.insert("interactive_expired", int_expired);
        o.insert("interactive_queue_wall_p95_s", pctile(&mut q_i, 0.95));
        o.insert("interactive_ttft_wall_p99_s", pctile(&mut ttft_i, 0.99));
        o.insert(
            "interactive_queue_wall_p95_postwarm_s",
            pctile(&mut q_i_pw, 0.95),
        );
        o.insert(
            "interactive_ttft_wall_p99_postwarm_s",
            pctile(&mut ttft_i_pw, 0.99),
        );
        o.insert("batch_offered", batch_offered);
        o.insert("batch_completed", batch_completed);
        o.insert("batch_shed", batch_shed);
        o.insert("batch_expired", batch_expired);
        o.insert("batch_e2e_wall_p95_s", pctile(&mut e2e_b, 0.95));
        o.insert(
            "cluster_throughput_sim",
            if busy_max > 0.0 {
                tokens as f64 / busy_max
            } else {
                0.0
            },
        );
        o.insert("busy_max_s", busy_max);
        o.insert("token_identical", true);
        if forecast_on {
            let plane = router.forecast();
            if let Some(c) = plane.len_coverage_pooled() {
                o.insert("len_p90_coverage_pooled", c);
            }
            if let Some(c) = plane.wait_coverage() {
                o.insert("wait_coverage", c);
            }
            o.insert("wait_resolved", plane.wait_resolved() as usize);
            o.insert("bursts_detected", plane.bursts_detected() as usize);
            o.insert("bursts_resolved", plane.bursts_resolved() as usize);
            if let Some(h) = plane.burst_hit_rate() {
                o.insert("burst_hit_rate", h);
            }
            let mut eng_detected = 0u64;
            for e in router.replicas() {
                eng_detected += e.forecast_plane().bursts_detected();
            }
            o.insert("engine_bursts_detected", eng_detected as usize);
        }
        rows.push(Value::Object(o));
    }
    Ok(rows)
}

/// Short git commit of the working tree, for the BENCH_serve header
/// ("which code produced these rows").
fn git_commit_short() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Merge one named section into `target/bench-reports/BENCH_serve.json`,
/// the machine-readable serving-perf summary tracked across PRs
/// (throughput, tokens/step, ITL percentiles, swap/prefetch counters).
/// Each bench target owns its sections; existing ones from other targets
/// survive.  `config_desc` records the *actual* parameters this section
/// ran with; the header fingerprint hashes all sections' descriptors
/// (key-sorted), so rows are only compared across commits — or quick vs
/// full modes — when the harness knobs really match.  A copy lands at
/// the repo root (`BENCH_serve.json`) so the perf trajectory is tracked
/// in-repo, not only as a CI artifact.
pub fn write_bench_serve(
    section: &str,
    rows: &[Value],
    config_desc: &str,
) -> Result<std::path::PathBuf> {
    let dir = std::path::Path::new("target/bench-reports");
    std::fs::create_dir_all(dir)?;
    let path = dir.join("BENCH_serve.json");
    let mut sections = Object::new();
    let mut configs = Object::new();
    if let Ok(text) = std::fs::read_to_string(&path) {
        if let Ok(v) = crate::util::json::parse(&text) {
            if let Some(existing) = v.get("sections").and_then(|s| s.as_object()) {
                for (k, val) in existing.iter() {
                    sections.insert(k, val.clone());
                }
            }
            if let Some(existing) = v.get("section_configs").and_then(|s| s.as_object()) {
                for (k, val) in existing.iter() {
                    configs.insert(k, val.clone());
                }
            }
        }
    }
    sections.insert(section, Value::Array(rows.to_vec()));
    configs.insert(section, config_desc);
    let mut pairs: Vec<String> = configs
        .iter()
        .map(|(k, v)| format!("{k}={v}"))
        .collect();
    pairs.sort();
    let fingerprint = format!("{:016x}", fnv1a(pairs.join(";").as_bytes()));
    let mut top = Object::new();
    top.insert("bench", "serve");
    top.insert("git_commit", git_commit_short());
    top.insert("config_fingerprint", fingerprint);
    top.insert("section_configs", Value::Object(configs));
    top.insert("sections", Value::Object(sections));
    let text = Value::Object(top).to_string_pretty();
    std::fs::write(&path, &text)?;
    // best-effort root copy (benches run from the workspace root; a
    // read-only checkout must not fail the bench itself)
    let _ = std::fs::write("BENCH_serve.json", &text);
    Ok(path)
}

/// Percentage delta of `new` vs `base` where *lower is better*
/// (positive = improvement), e.g. Fig. 6 latency reductions.
pub fn reduction_pct(base: f64, new: f64) -> f64 {
    if base <= 0.0 {
        return 0.0;
    }
    (base - new) / base * 100.0
}

/// Percentage delta where *higher is better* (Fig. 7 throughput gains).
pub fn gain_pct(base: f64, new: f64) -> f64 {
    if base <= 0.0 {
        return 0.0;
    }
    (new - base) / base * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pct_helpers() {
        assert!((reduction_pct(100.0, 94.0) - 6.0).abs() < 1e-9);
        assert!((gain_pct(100.0, 112.0) - 12.0).abs() < 1e-9);
        assert_eq!(reduction_pct(0.0, 5.0), 0.0);
    }

    #[test]
    fn pd_compare_activates_handoff_and_stays_token_identical() {
        // the default spec's trace is pinned by the workload tests to
        // contain both burst and steady phases, so hand-offs must fire;
        // run_pd_compare bails internally on any token divergence, so a
        // clean return already proves identity vs the single engine
        let rows = run_pd_compare(&PdTraceSpec::default()).unwrap();
        assert_eq!(rows.len(), 2);
        let field = |row: &Value, key: &str| row.get(key).and_then(Value::as_f64).unwrap();
        let pd = &rows[0];
        let mixed = &rows[1];
        assert_eq!(pd.get("mode").and_then(Value::as_str), Some("pd_split"));
        assert_eq!(mixed.get("mode").and_then(Value::as_str), Some("mixed"));
        // the split must actually move KV: hand-offs happen and ship bytes
        assert!(field(pd, "migrations_out") > 0.0);
        assert!(field(pd, "migrations_in") > 0.0);
        assert!(field(pd, "migration_bytes") > 0.0);
        // the uniform cluster never migrates — the counters stay zero
        assert_eq!(field(mixed, "migrations_out"), 0.0);
        assert_eq!(field(mixed, "migration_bytes"), 0.0);
        for row in &rows {
            assert_eq!(row.get("token_identical").and_then(Value::as_bool), Some(true));
            assert!(field(row, "tokens") > 0.0);
            assert!(field(row, "decode_itl_sim_p95_s") > 0.0);
        }
    }
}
