//! Two-tier KV memory hierarchy — the **Opt-KV tier manager**.
//!
//! The paper's Opt-KV strategy treats the KV cache read/write paths as the
//! dominant memory-bandwidth bottleneck.  This module extends the paged
//! device pool with a **host tier**: when the device pool is exhausted,
//! the engine can *swap* a victim sequence's blocks to host memory over
//! PCIe instead of dropping them and recomputing the whole prefill (the
//! policy comparison of arXiv:2504.06319 / arXiv:2604.05012 — swap +
//! prefetch beats recompute-on-preempt for realistic traffic).
//!
//! Residency model (block granular):
//!
//! * a **sole-owner** device block (refcount 1) moves to a [`HostPool`]
//!   slot on swap-out; its device block returns to the free list and its
//!   prefix-hash entry is removed (a host-resident block can serve no
//!   device-side prefix match).  The hash is remembered so swap-in can
//!   re-index the block if the hash is still vacant.
//! * a **shared** device block (refcount > 1) never moves: the swapped
//!   sequence *keeps its reference*, so the block can neither be freed nor
//!   duplicated for the surviving readers — prefix sharing stays correct
//!   across tiers by construction, and swap-in reattaches the same
//!   physical block.
//!
//! The actual byte copies are executed by the backend
//! ([`crate::runtime::Backend::swap_out`]/[`swap_in`]); this module owns
//! the *metadata*: which block lives where, host-slot allocation, and the
//! accounting the engine's cost-based evict-vs-recompute policy and async
//! prefetch queue are built on (see [`crate::coordinator`]).

use crate::kvcache::BlockId;

/// Host-tier slot id (stable for the lifetime of one swapped block).
pub type HostSlotId = u64;

/// Where one logical block of a swapped sequence lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapEntry {
    /// Still resident on device: a prefix-shared block whose other readers
    /// keep it alive.  The swapped sequence retains its refcount.
    Device(BlockId),
    /// Copied to the host tier; `hash` restores the prefix index on
    /// swap-in when the block had been shareable.
    Host { slot: HostSlotId, hash: Option<u64> },
}

/// Per-sequence state while swapped out (mirrors the resident `SeqState`).
#[derive(Debug, Clone)]
pub struct SwappedSeq {
    /// logical block -> residency, same order as the block table
    pub entries: Vec<SwapEntry>,
    /// committed context length (tokens); the sequence resumes decoding
    /// at exactly this offset after swap-in
    pub len: usize,
    /// carried over for the resident state's accounting
    pub shared_prefix_blocks: usize,
    /// carried over: the block-table floor prefill materialized (see
    /// `CacheManager::truncate_seq` — speculative rollback must not free
    /// the padded baseline's prefill blocks)
    pub min_blocks: usize,
}

impl SwappedSeq {
    /// Device blocks needed to bring this sequence back.
    pub fn host_blocks(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| matches!(e, SwapEntry::Host { .. }))
            .count()
    }
}

/// Fixed-capacity host-side block pool.  Slot ids are never reused while
/// live, which lets the backend key its host buffers by slot.
///
/// Accounting is *slot-addressed*: the pool tracks exactly which slots
/// are live, so a double release of one slot is caught instead of
/// silently masking a leak of another while the backend's host buffer
/// for the leaked slot stays resident.  Migration turns these slots
/// into cross-replica transport, so the books must be airtight.
#[derive(Debug, Clone)]
pub struct HostPool {
    capacity: usize,
    live: std::collections::HashSet<HostSlotId>,
    next_slot: HostSlotId,
    used_peak: usize,
}

impl HostPool {
    pub fn new(capacity: usize) -> Self {
        HostPool {
            capacity,
            live: std::collections::HashSet::new(),
            next_slot: 0,
            used_peak: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn used(&self) -> usize {
        self.live.len()
    }

    /// High-water mark of live slots over the pool's lifetime — sizes the
    /// host tier for a re-run of the same trace (a pool that never fills
    /// is over-provisioned; a pool pinned at capacity forced recomputes).
    pub fn used_peak(&self) -> usize {
        self.used_peak
    }

    pub fn free(&self) -> usize {
        self.capacity - self.live.len()
    }

    /// Claim one host slot; `None` when the pool is full.
    pub fn alloc(&mut self) -> Option<HostSlotId> {
        if self.live.len() >= self.capacity {
            return None;
        }
        let slot = self.next_slot;
        self.next_slot += 1;
        self.live.insert(slot);
        self.used_peak = self.used_peak.max(self.live.len());
        Some(slot)
    }

    /// Release a live slot back to the pool.  Releasing a slot that is
    /// not live (double free, or a slot never allocated) is an
    /// accounting bug upstream; debug builds assert on it.
    pub fn release(&mut self, slot: HostSlotId) {
        let was_live = self.live.remove(&slot);
        debug_assert!(was_live, "host pool release of non-live slot {slot}");
    }
}

/// What a swap-out of one sequence would involve (drives the engine's
/// cost-based evict-vs-recompute decision before anything is mutated).
#[derive(Debug, Clone, Copy)]
pub struct SwapOutPlan {
    /// sole-owner blocks that would move to the host tier
    pub host_blocks: usize,
    /// shared blocks that stay device-resident (swap frees nothing here)
    pub shared_blocks: usize,
    /// committed tokens — the prefill a recompute would have to redo
    pub tokens: usize,
}

/// Committed swap-out: the backend must execute `copies` (device block ->
/// host slot) immediately, before any further allocation can recycle the
/// freed device blocks.
#[derive(Debug, Clone)]
pub struct SwapOutOps {
    pub copies: Vec<(BlockId, HostSlotId)>,
    /// device blocks returned to the free list
    pub freed_blocks: usize,
    /// committed tokens preserved (recompute avoided if swapped back in)
    pub tokens: usize,
}

/// Committed swap-in: the backend must execute `copies` (host slot ->
/// device block) before the sequence is stepped again.
#[derive(Debug, Clone)]
pub struct SwapInOps {
    pub copies: Vec<(HostSlotId, BlockId)>,
    /// context length the sequence resumes decoding at
    pub resume_len: usize,
}

/// Committed migrate-out of one sequence (cross-replica PD hand-off).
/// Unlike a swap-out, *every* block — shared or not — stages through a
/// host slot: the destination replica holds no references on this
/// device's blocks, so each payload must travel whole.  The caller must
/// execute `stages` (device block -> host slot exports) through the
/// backend before anything recycles the freed device blocks, then
/// release the staging slots once the payloads are in the hand-off
/// envelope.
#[derive(Debug, Clone)]
pub struct MigrateOutOps {
    /// (device block, staging host slot) per logical block, table order
    pub stages: Vec<(BlockId, HostSlotId)>,
    /// prefix-index hash per logical block (`None` = partial or
    /// unindexed); the destination re-indexes imported full blocks and
    /// reuses hash matches it already holds
    pub hashes: Vec<Option<u64>>,
    /// committed context length — the exact decode offset the sequence
    /// resumes at on the destination
    pub resume_len: usize,
    /// carried block-table floor (see [`SwappedSeq`]'s field of the
    /// same name)
    pub min_blocks: usize,
}

/// Committed migrate-in: the backend must import the payloads for
/// `imports` (logical block index -> freshly allocated device block)
/// before the sequence is stepped.  Hash-matched blocks already
/// resident on the destination are reused instead (prefix re-indexing
/// preserved) and do not appear here.
#[derive(Debug, Clone)]
pub struct MigrateInOps {
    pub imports: Vec<(usize, BlockId)>,
    pub reused_blocks: usize,
}

/// Host-tier occupancy snapshot (surfaced in `/metrics` and benches).
#[derive(Debug, Clone, Copy, Default)]
pub struct TierStats {
    pub host_capacity_blocks: usize,
    pub host_used_blocks: usize,
    /// high-water mark of host slots in use (see [`HostPool::used_peak`])
    pub host_used_peak_blocks: usize,
    pub swapped_seqs: usize,
    /// shared device blocks currently pinned by swapped sequences
    pub pinned_shared_blocks: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_pool_alloc_release() {
        let mut p = HostPool::new(2);
        assert_eq!(p.free(), 2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b, "slot ids are unique");
        assert!(p.alloc().is_none(), "capacity enforced");
        p.release(a);
        assert_eq!(p.free(), 1);
        assert_eq!(p.used_peak(), 2, "peak survives release");
        let c = p.alloc().unwrap();
        assert_ne!(c, b, "slot ids are never reused while the pool lives");
        assert_eq!(p.used(), 2);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "non-live slot")]
    fn host_pool_double_release_asserts() {
        let mut p = HostPool::new(2);
        let a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        p.release(a);
        // releasing `a` again must not silently mask a leak of `b`
        p.release(a);
    }

    #[test]
    fn swapped_seq_counts_host_blocks() {
        let s = SwappedSeq {
            entries: vec![
                SwapEntry::Device(3),
                SwapEntry::Host { slot: 0, hash: None },
                SwapEntry::Host { slot: 1, hash: Some(42) },
            ],
            len: 11,
            shared_prefix_blocks: 1,
            min_blocks: 0,
        };
        assert_eq!(s.host_blocks(), 2);
    }
}
