//! Paged KV-cache management — the L3 half of **Opt-KV** (paper §3.1).
//!
//! The coordinator owns the paged pool layout (the actual tensors live in
//! PJRT buffers, see [`crate::runtime`]); this module decides *which slots
//! get written*:
//!
//! * [`BlockAllocator`] — free-list pool allocator with refcounts
//!   (copy-on-write prefix sharing), O(1) alloc/free.
//! * [`CacheManager`] — per-sequence block tables, slot-mapping
//!   construction, and the **SkipSet** (Eq. 5): under `skip_filter`
//!   configs, padding positions and duplicate (prefix-shared) blocks map
//!   to slot −1, which the L1 `kv_write` kernel skips.  The `original`
//!   baseline reproduces the behaviour the paper criticizes: every padded
//!   prefill position is written ("all KVs ... regardless of whether they
//!   are actually useful, including padding and duplicate tokens").
//!   Prefill commits through [`CacheManager::prefill_chunk`] — Opt-Pa
//!   step 1 segments a prompt into windows and step 2 lazily maps blocks
//!   as each window lands; one-shot prefill is the single-window case.
//! * fragmentation accounting (allocated vs live slots — the Fig. 3
//!   motivation) and pool bytes per config (FP8 halves traffic;
//!   the platform model consumes these numbers).
//! * a **two-tier residency extension** ([`tier`]): an optional host-side
//!   block pool with block-granular `swap_out`/`swap_in`, so preemption
//!   can preserve a victim's KV over PCIe instead of recomputing it.
//!   Prefix-hash sharing stays correct across tiers — a shared block is
//!   never moved while another reader holds it (the swapped sequence just
//!   keeps its refcount), and a swapped-out sole-owner block leaves the
//!   prefix index until swap-in restores it.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::config::{CacheGeometry, OptConfig};

pub mod tier;

use self::tier::{
    HostPool, MigrateInOps, MigrateOutOps, SwapEntry, SwapInOps, SwapOutOps, SwapOutPlan,
    SwappedSeq, TierStats,
};

pub type BlockId = u32;
pub type SeqId = u64;

// ---------------------------------------------------------------------------
// prefix residency deltas (cluster directory feed)
// ---------------------------------------------------------------------------

/// What happened to one prefix-hash's residency on this replica.  The
/// cluster's prefix directory ([`crate::router::directory`]) applies
/// these to track which replica holds which prefix chain and in which
/// tier — the feed is *eventually consistent* (deltas ride the metrics
/// snapshot channel and the log is bounded), which is safe by
/// construction: a stale directory entry at worst routes a pull that
/// exports nothing and the destination re-prefills.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefixDeltaKind {
    /// the hash became device-resident (prefill commit, swap-in
    /// re-index, migrate-in import, or a pulled-block commit)
    CommitDevice,
    /// the hash's sole copy moved to this replica's host tier (swap-out)
    CommitHost,
    /// the hash left this replica entirely (block freed / swapped copy
    /// dropped)
    Evict,
}

/// One replica-published change to its resident prefix set, observed at
/// the [`CacheManager`]'s index/unindex seams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixDelta {
    pub hash: u64,
    pub kind: PrefixDeltaKind,
}

/// Bound on the undrained delta log: overflow drops the oldest deltas
/// (an engine serving under a non-directory policy is never drained, so
/// the log must not grow with uptime).  Lost deltas only leave stale
/// directory entries, which fall back to re-prefill.
const DELTA_LOG_CAP: usize = 8_192;

// ---------------------------------------------------------------------------
// block allocator
// ---------------------------------------------------------------------------

/// Free-list allocator with per-block reference counts (COW sharing).
#[derive(Debug, Clone)]
pub struct BlockAllocator {
    free: Vec<BlockId>,
    refcnt: Vec<u16>,
    num_blocks: usize,
    /// cumulative counters for metrics
    pub total_allocs: u64,
    pub total_frees: u64,
}

impl BlockAllocator {
    pub fn new(num_blocks: usize) -> Self {
        BlockAllocator {
            free: (0..num_blocks as BlockId).rev().collect(),
            refcnt: vec![0; num_blocks],
            num_blocks,
            total_allocs: 0,
            total_frees: 0,
        }
    }

    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        debug_assert_eq!(self.refcnt[id as usize], 0);
        self.refcnt[id as usize] = 1;
        self.total_allocs += 1;
        Some(id)
    }

    /// Increase the refcount of an already-allocated block (prefix share).
    pub fn incref(&mut self, id: BlockId) {
        debug_assert!(self.refcnt[id as usize] > 0, "incref of free block");
        self.refcnt[id as usize] += 1;
    }

    /// Drop one reference; the block returns to the free list at zero.
    /// Returns true if the block was actually freed.
    pub fn decref(&mut self, id: BlockId) -> bool {
        let rc = &mut self.refcnt[id as usize];
        assert!(*rc > 0, "decref of free block {id}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(id);
            self.total_frees += 1;
            true
        } else {
            false
        }
    }

    pub fn refcount(&self, id: BlockId) -> u16 {
        self.refcnt[id as usize]
    }

    pub fn num_free(&self) -> usize {
        self.free.len()
    }

    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }

    pub fn num_used(&self) -> usize {
        self.num_blocks - self.free.len()
    }
}

// ---------------------------------------------------------------------------
// cache manager
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Default)]
struct SeqState {
    /// logical block -> physical block
    table: Vec<BlockId>,
    /// tokens whose K/V occupy slots (context length)
    len: usize,
    /// physical blocks borrowed via prefix sharing (refcounted, read-only)
    shared_prefix_blocks: usize,
    /// block-table floor materialized by prefill — [`CacheManager::truncate_seq`]
    /// never frees below it, so the padded baseline's padding blocks
    /// survive speculative rollback exactly as one-shot prefill left them
    min_blocks: usize,
}

/// Outcome of planning a prefill write (drives the prefill graph inputs).
#[derive(Debug, Clone)]
pub struct PrefillPlan {
    /// slot per padded prompt position (len = max_seq); -1 = skip (Eq. 5)
    pub slot_mapping: Vec<i32>,
    /// positions actually written
    pub written: usize,
    /// positions skipped by the SkipSet (padding + shared-prefix)
    pub skipped: usize,
    /// whole blocks reused from the prefix cache
    pub reused_blocks: usize,
    /// of `reused_blocks`, the leading contiguous run at a block-aligned
    /// window start — prefill compute the engine can actually elide
    /// (the positions' KV is fully cached *and* precedes every computed
    /// position), which drives the Eq. 12 sim-cost discount.  Zero for
    /// unaligned windows.
    pub leading_reused: usize,
}

/// Aggregate fragmentation/pool statistics (Fig. 3 motivation).
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub blocks_total: usize,
    pub blocks_used: usize,
    pub slots_allocated: usize,
    pub slots_live: usize,
    /// 1 - live/allocated: internal fragmentation of the paged pool
    pub fragmentation: f64,
    pub prefix_hits: u64,
    pub skipped_writes: u64,
    pub total_writes: u64,
}

#[derive(Debug)]
pub struct CacheManager {
    pub geometry: CacheGeometry,
    alloc: BlockAllocator,
    seqs: HashMap<SeqId, SeqState>,
    /// full-block content hash -> physical block (prefix sharing index)
    prefix_index: HashMap<u64, BlockId>,
    /// inverse map for eviction when a block is freed
    block_hash: HashMap<BlockId, u64>,
    /// optional host tier (Opt-KV tier manager); `None` = single-tier
    host: Option<HostPool>,
    /// sequences whose KV currently lives (partly) in the host tier
    swapped: HashMap<SeqId, SwappedSeq>,
    /// undrained prefix residency changes (bounded; see [`PrefixDelta`])
    delta_log: std::collections::VecDeque<PrefixDelta>,
    /// cross-replica pulled blocks held at refcount 1 until a prefill
    /// consumes them: hash -> (block, age in ticks)
    pulled_pins: HashMap<u64, (BlockId, u32)>,
    prefix_hits: u64,
    skipped_writes: u64,
    total_writes: u64,
}

impl CacheManager {
    pub fn new(geometry: CacheGeometry) -> Self {
        CacheManager {
            alloc: BlockAllocator::new(geometry.num_pool_blocks),
            geometry,
            seqs: HashMap::new(),
            prefix_index: HashMap::new(),
            block_hash: HashMap::new(),
            host: None,
            swapped: HashMap::new(),
            delta_log: std::collections::VecDeque::new(),
            pulled_pins: HashMap::new(),
            prefix_hits: 0,
            skipped_writes: 0,
            total_writes: 0,
        }
    }

    /// Attach a host tier of `capacity_blocks` blocks (Opt-KV tier
    /// manager).  Zero capacity leaves the cache single-tier.
    pub fn enable_host_tier(&mut self, capacity_blocks: usize) {
        if capacity_blocks > 0 {
            self.host = Some(HostPool::new(capacity_blocks));
        }
    }

    pub fn has_host_tier(&self) -> bool {
        self.host.is_some()
    }

    pub fn num_free_blocks(&self) -> usize {
        self.alloc.num_free()
    }

    pub fn has_seq(&self, id: SeqId) -> bool {
        self.seqs.contains_key(&id)
    }

    pub fn seq_len(&self, id: SeqId) -> usize {
        self.seqs.get(&id).map(|s| s.len).unwrap_or(0)
    }

    /// Blocks a prefill of `prompt_len` tokens will need under `opt`
    /// (ignoring prefix reuse, i.e. the worst case).
    pub fn blocks_needed_prefill(&self, prompt_len: usize, opt: &OptConfig) -> usize {
        let bs = self.geometry.block_size;
        if opt.skip_filter {
            prompt_len.div_ceil(bs)
        } else {
            // baseline writes every padded position (Eq. 2 behaviour)
            self.geometry.max_seq.div_ceil(bs).max(prompt_len.div_ceil(bs))
        }
    }

    /// True if a new sequence with this prompt can be admitted right now.
    pub fn can_admit(&self, prompt_len: usize, opt: &OptConfig) -> bool {
        // +1 headroom so the first decode step cannot immediately stall
        self.alloc.num_free() >= self.blocks_needed_prefill(prompt_len, opt) + 1
    }

    /// Chunked-admission check: can a prefill window of `tokens` be
    /// committed right now?  Chunks write only real tokens, so this is a
    /// per-chunk bound regardless of `opt` (the baseline's padding blocks
    /// arrive with the final chunk; mid-prefill shortfalls are handled by
    /// the engine's preempt-and-retry path).
    pub fn can_admit_tokens(&self, tokens: usize, _opt: &OptConfig) -> bool {
        let bs = self.geometry.block_size;
        self.alloc.num_free() >= tokens.div_ceil(bs) + 1
    }

    /// Plan + commit the prefill of sequence `id` with `prompt` tokens.
    ///
    /// Allocates blocks (sharing full prefix blocks when `opt.skip_filter`
    /// allows the duplicate-token skip) and returns the slot mapping for
    /// the padded prefill graph.  Implemented as a single full-width
    /// chunk, so one-shot and chunked prefill share one code path.
    pub fn prefill(&mut self, id: SeqId, prompt: &[u32], opt: &OptConfig) -> Result<PrefillPlan> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        self.prefill_chunk(id, prompt, 0, prompt.len(), opt, true)
    }

    /// Opt-Pa step 1: commit the prefill window `[offset, offset+len)` of
    /// `prompt` for sequence `id`.
    ///
    /// `offset == 0` creates the sequence; later chunks append to it and
    /// must start exactly at the committed length (the lazy mapping of
    /// Opt-Pa step 2: blocks materialize as chunks arrive, never ahead of
    /// them).  Full blocks that fall entirely inside a window reuse the
    /// prefix-hash index exactly like one-shot prefill, so earlier chunks
    /// stay shareable across sequences.  The final chunk of a
    /// non-`skip_filter` config also writes the baseline's padding slots,
    /// which keeps chunked and one-shot prefill byte-identical in block
    /// counts and write totals for every opt config.  On pool exhaustion
    /// the window's allocations are rolled back and earlier chunks stay
    /// committed, so the caller can retry from the same offset.
    pub fn prefill_chunk(
        &mut self,
        id: SeqId,
        prompt: &[u32],
        offset: usize,
        len: usize,
        opt: &OptConfig,
        is_final: bool,
    ) -> Result<PrefillPlan> {
        let bs = self.geometry.block_size;
        let max_seq = self.geometry.max_seq;
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() > max_seq {
            bail!("prompt of {} tokens exceeds max_seq {max_seq}", prompt.len());
        }
        let end = offset + len;
        if len == 0 || end > prompt.len() {
            bail!(
                "invalid prefill chunk [{offset}, {end}) for a prompt of {} tokens",
                prompt.len()
            );
        }
        if is_final != (end == prompt.len()) {
            bail!("chunk finality mismatch: end {end} vs prompt len {}", prompt.len());
        }
        if offset == 0 {
            if self.seqs.contains_key(&id) {
                bail!("sequence {id} already exists");
            }
        } else {
            let committed = self
                .seqs
                .get(&id)
                .ok_or_else(|| anyhow::anyhow!("prefill chunk for unknown sequence {id}"))?
                .len;
            if committed != offset {
                bail!(
                    "chunk offset {offset} does not match committed length {committed} of sequence {id}"
                );
            }
        }

        let mut table: Vec<BlockId> = self
            .seqs
            .get(&id)
            .map(|st| st.table.clone())
            .unwrap_or_default();
        let prior_blocks = table.len();
        let mut new_blocks: Vec<BlockId> = Vec::new();
        let mut shared_now: Vec<BlockId> = Vec::new();
        let mut slot_mapping = vec![-1i32; max_seq];
        let mut reused_blocks = 0usize;
        // leading contiguous reuse run: stays live only while every
        // block since the (block-aligned) window start was a prefix hit
        let mut leading_reused = 0usize;
        let mut leading_run = offset % bs == 0;
        let mut fail: Option<&'static str> = None;

        // the final chunk of the padded baseline also writes every padding
        // position (Eq. 2 behaviour the paper criticizes)
        let write_upto = if is_final && !opt.skip_filter { max_seq } else { end };
        let mut pos = offset;
        while pos < write_upto {
            let b = pos / bs;
            let block_start = b * bs;
            // whole prompt block inside the window: prefix-share candidate
            if pos == block_start && block_start + bs <= end && b >= table.len() {
                let chunk_toks = &prompt[block_start..block_start + bs];
                let h = prefix_hash(&prompt[..block_start], chunk_toks);
                if opt.skip_filter {
                    if let Some(&phys) = self.prefix_index.get(&h) {
                        // duplicate tokens: reuse read-only, skip writes
                        // (prefix_hits counted after the window commits,
                        // so a rolled-back window doesn't inflate stats)
                        self.alloc.incref(phys);
                        table.push(phys);
                        shared_now.push(phys);
                        reused_blocks += 1;
                        if leading_run {
                            leading_reused += 1;
                        }
                        pos = block_start + bs;
                        continue; // slots stay -1  (Eq. 5 SkipSet)
                    }
                }
                match self.alloc.alloc() {
                    Some(phys) => {
                        if opt.skip_filter {
                            self.index_block(phys, h);
                        }
                        table.push(phys);
                        new_blocks.push(phys);
                        for o in 0..bs {
                            slot_mapping[block_start + o] = (phys as usize * bs + o) as i32;
                        }
                        leading_run = false;
                        pos = block_start + bs;
                        continue;
                    }
                    None => {
                        fail = Some("out of KV blocks during prefill");
                        break;
                    }
                }
            }
            // partial coverage: chunk tail, unaligned window, or padding
            if b >= table.len() {
                match self.alloc.alloc() {
                    Some(phys) => {
                        table.push(phys);
                        new_blocks.push(phys);
                    }
                    None => {
                        fail = Some("out of KV blocks during prefill");
                        break;
                    }
                }
            }
            leading_run = false;
            let phys = table[b];
            if self.alloc.refcount(phys) > 1 {
                // only *full* blocks are ever shared, and chunks never
                // revisit committed positions — guard anyway
                fail = Some("attempted write into shared block");
                break;
            }
            slot_mapping[pos] = (phys as usize * bs + pos % bs) as i32;
            pos += 1;
        }

        if let Some(msg) = fail {
            for phys in new_blocks {
                if self.alloc.decref(phys) {
                    self.unindex_block(phys);
                }
            }
            for phys in shared_now {
                if self.alloc.decref(phys) {
                    self.unindex_block(phys);
                }
            }
            bail!("{msg}");
        }

        // blocks whose last slot landed in this window became full and
        // shareable — including blocks filled across *split* windows,
        // which the full-block branch above never saw whole.  (Such a
        // block cannot be consumed shared by the sequence that wrote it —
        // part of it was committed before the content was known — but it
        // is now a provider for later sequences, matching one-shot
        // prefill's index contents.)
        if opt.skip_filter {
            for b in offset / bs..end / bs {
                let phys = table[b];
                if self.alloc.refcount(phys) == 1 && !self.block_hash.contains_key(&phys) {
                    let h = prefix_hash(&prompt[..b * bs], &prompt[b * bs..(b + 1) * bs]);
                    if !self.prefix_index.contains_key(&h) {
                        self.index_block(phys, h);
                    }
                }
            }
        }

        self.prefix_hits += shared_now.len() as u64;
        let written = slot_mapping.iter().filter(|&&s| s >= 0).count();
        // account the padded-graph skip total so chunk sums equal the
        // one-shot numbers: window skips now, padding skips on the final
        // chunk (for the baseline the padding is written, not skipped)
        let pad = if is_final { max_seq - prompt.len() } else { 0 };
        let skipped = (len + pad).saturating_sub(written);
        self.total_writes += written as u64;
        self.skipped_writes += skipped as u64;
        let shared_added = reused_blocks;
        let st = self.seqs.entry(id).or_default();
        debug_assert!(table.len() >= prior_blocks);
        // the padded baseline's padding blocks are a prefill artifact:
        // speculative rollback (truncate_seq) must leave them exactly as
        // one-shot prefill did.  SkipSet configs materialize no padding,
        // so their floor is the natural ceil(len / block_size).
        st.min_blocks = if opt.skip_filter { 0 } else { table.len() };
        st.table = table;
        st.len = end;
        st.shared_prefix_blocks += shared_added;
        Ok(PrefillPlan {
            slot_mapping,
            written,
            skipped,
            reused_blocks,
            leading_reused,
        })
    }

    /// Reserve the slot for the next decoded token of `id` and advance its
    /// length.  Returns (slot, position).  COW: if the target block is
    /// shared, it is copied (here: re-allocated; the runtime re-writes it).
    pub fn append_token(&mut self, id: SeqId) -> Result<(i32, usize)> {
        let bs = self.geometry.block_size;
        let max_ctx = self.geometry.max_context();
        let st = self
            .seqs
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {id}"))?;
        let pos = st.len;
        if pos >= max_ctx {
            bail!("sequence {id} hit max context {max_ctx}");
        }
        let b = pos / bs;
        if b >= st.table.len() {
            let phys = self
                .alloc
                .alloc()
                .ok_or_else(|| anyhow::anyhow!("out of KV blocks during decode"))?;
            st.table.push(phys);
        }
        // COW if the tail block is prefix-shared with another sequence
        let phys = st.table[b];
        if self.alloc.refcount(phys) > 1 && pos % bs != 0 {
            // a shared partial block cannot appear via our prefill scheme
            // (only *full* blocks are shared), but guard anyway
            bail!("attempted write into shared block {phys}");
        }
        if self.alloc.refcount(phys) > 1 {
            // take a private block, then release the shared copy — the
            // reverse order would leak our reference if the pool is
            // exhausted (the table would keep pointing at a block we no
            // longer own)
            let fresh = self
                .alloc
                .alloc()
                .ok_or_else(|| anyhow::anyhow!("out of KV blocks during COW"))?;
            self.alloc.decref(phys);
            st.table[b] = fresh;
        }
        let phys = st.table[b];
        st.len += 1;
        self.total_writes += 1;
        Ok(((phys as usize * bs + pos % bs) as i32, pos))
    }

    /// Speculative-decode rollback: un-write the tail of `id` back to
    /// `new_len` committed tokens.
    ///
    /// Whole blocks past the new boundary leave the sequence's table —
    /// a shared block is decref'd so its other readers are untouched, and
    /// a block that actually frees also leaves the prefix index — except
    /// blocks the prefill itself materialized (the padded baseline's
    /// padding span), which stay with the sequence exactly as one-shot
    /// prefill left them.  If the new boundary falls *inside* a
    /// prefix-shared block, the sequence gets a private copy
    /// (copy-on-write) so a resumed append can never write a sharer's
    /// slots; the engine's reservation path COWs before any KV write, so
    /// it never hits this case — it exists for API completeness, and on a
    /// real backend it would additionally need a partial-block copy.
    ///
    /// Returns the number of blocks released from the table.  Rolled-back
    /// slots need no backend call: they are unindexed metadata-side and
    /// simply re-written by whichever allocation claims them next.
    pub fn truncate_seq(&mut self, id: SeqId, new_len: usize) -> Result<usize> {
        let bs = self.geometry.block_size;
        let (dropped, cow_block) = {
            let alloc = &self.alloc;
            let Some(st) = self.seqs.get_mut(&id) else {
                bail!("truncate of unknown sequence {id}");
            };
            if new_len > st.len {
                bail!(
                    "cannot truncate sequence {id} to {new_len} beyond its {} committed tokens",
                    st.len
                );
            }
            if new_len == st.len {
                return Ok(0);
            }
            st.len = new_len;
            let keep = new_len
                .div_ceil(bs)
                .max(st.min_blocks)
                .min(st.table.len());
            let dropped = st.table.split_off(keep);
            let cow_block = if new_len % bs != 0 {
                let b = new_len / bs;
                (b < st.table.len() && alloc.refcount(st.table[b]) > 1).then_some(b)
            } else {
                None
            };
            (dropped, cow_block)
        };
        let released = dropped.len();
        let mut shared_released = 0usize;
        for phys in dropped {
            if self.alloc.refcount(phys) > 1 {
                shared_released += 1;
            }
            if self.alloc.decref(phys) {
                self.unindex_block(phys);
            }
        }
        if let Some(b) = cow_block {
            // boundary inside a shared block: take the private block
            // first, then release the shared reference (append_token's
            // ordering note — the reverse would leak on exhaustion)
            let fresh = self
                .alloc
                .alloc()
                .ok_or_else(|| anyhow::anyhow!("out of KV blocks during truncate COW"))?;
            shared_released += 1;
            let st = self.seqs.get_mut(&id).expect("present above");
            let old = st.table[b];
            st.table[b] = fresh;
            if self.alloc.decref(old) {
                self.unindex_block(old);
            }
        }
        if shared_released > 0 {
            let st = self.seqs.get_mut(&id).expect("present above");
            st.shared_prefix_blocks = st.shared_prefix_blocks.saturating_sub(shared_released);
        }
        Ok(released)
    }

    /// Padded block-table row for the decode graph.
    pub fn block_table_row(&self, id: SeqId) -> Vec<i32> {
        let max_blocks = self.geometry.max_blocks;
        let mut row = vec![0i32; max_blocks];
        if let Some(st) = self.seqs.get(&id) {
            for (i, &b) in st.table.iter().take(max_blocks).enumerate() {
                row[i] = b as i32;
            }
        }
        row
    }

    /// Free a sequence's blocks (end of generation or preemption).  Also
    /// covers sequences resident in the host tier: any freed host slots
    /// are returned so the caller can issue
    /// [`crate::runtime::Backend::swap_discard`] for them — slot ids are
    /// never reused, so an undiscarded slot is a permanent staging-buffer
    /// leak on a real backend.  Device-resident sequences return an empty
    /// list.
    pub fn free_seq(&mut self, id: SeqId) -> Vec<tier::HostSlotId> {
        if let Some(st) = self.seqs.remove(&id) {
            for b in st.table {
                if self.alloc.decref(b) {
                    self.unindex_block(b);
                }
            }
            Vec::new()
        } else if self.swapped.contains_key(&id) {
            self.drop_swapped(id)
        } else {
            Vec::new()
        }
    }

    // ---- two-tier residency (Opt-KV tier manager) -------------------------

    pub fn is_swapped(&self, id: SeqId) -> bool {
        self.swapped.contains_key(&id)
    }

    /// Committed context length of a swapped sequence (the exact decode
    /// offset it resumes at).
    pub fn swapped_len(&self, id: SeqId) -> usize {
        self.swapped.get(&id).map(|s| s.len).unwrap_or(0)
    }

    /// Device blocks a swap-in of `id` must allocate.
    pub fn swap_in_blocks_needed(&self, id: SeqId) -> usize {
        self.swapped.get(&id).map(|s| s.host_blocks()).unwrap_or(0)
    }

    /// What swapping `id` out would involve, or `None` when the host tier
    /// is absent, the sequence is not resident, or the host pool cannot
    /// take its sole-owner blocks.  Read-only: policy runs on this before
    /// anything is mutated.
    pub fn swap_out_plan(&self, id: SeqId) -> Option<SwapOutPlan> {
        let host = self.host.as_ref()?;
        let st = self.seqs.get(&id)?;
        let mut host_blocks = 0usize;
        let mut shared_blocks = 0usize;
        for &phys in &st.table {
            if self.alloc.refcount(phys) == 1 {
                host_blocks += 1;
            } else {
                shared_blocks += 1;
            }
        }
        if host_blocks > host.free() {
            return None;
        }
        Some(SwapOutPlan {
            host_blocks,
            shared_blocks,
            tokens: st.len,
        })
    }

    /// Move `id`'s sole-owner blocks to the host tier and release their
    /// device blocks.  Shared blocks stay device-resident with this
    /// sequence's reference intact, so prefix sharing survives the swap.
    ///
    /// The caller **must** execute the returned copies through the
    /// backend before anything else can allocate (and overwrite) the
    /// freed device blocks — the engine does both in one breath.
    pub fn swap_out(&mut self, id: SeqId) -> Result<SwapOutOps> {
        if self.swap_out_plan(id).is_none() {
            bail!("cannot swap out sequence {id} (no host tier, not resident, or host pool full)");
        }
        let st = self.seqs.remove(&id).expect("planned above");
        let mut entries = Vec::with_capacity(st.table.len());
        let mut copies = Vec::new();
        for &phys in &st.table {
            if self.alloc.refcount(phys) == 1 {
                let slot = self
                    .host
                    .as_mut()
                    .expect("planned above")
                    .alloc()
                    .expect("capacity checked by the plan");
                let hash = self.block_hash.get(&phys).copied();
                let freed = self.alloc.decref(phys);
                debug_assert!(freed);
                self.unindex_block(phys);
                if let Some(h) = hash {
                    // the sole copy now lives host-side on this replica
                    self.push_delta(h, PrefixDeltaKind::CommitHost);
                }
                copies.push((phys, slot));
                entries.push(SwapEntry::Host { slot, hash });
            } else {
                // shared: keep our reference; the block may only leave the
                // device once every reader has released it
                entries.push(SwapEntry::Device(phys));
            }
        }
        let freed_blocks = copies.len();
        let tokens = st.len;
        self.swapped.insert(
            id,
            SwappedSeq {
                entries,
                len: st.len,
                shared_prefix_blocks: st.shared_prefix_blocks,
                min_blocks: st.min_blocks,
            },
        );
        Ok(SwapOutOps {
            copies,
            freed_blocks,
            tokens,
        })
    }

    /// Bring a swapped sequence back to the device tier: allocate a device
    /// block per host entry and rebuild the block table (shared entries
    /// reattach the same physical block).  Fails without mutating when the
    /// device pool cannot take the host blocks.  The caller must execute
    /// the returned copies through the backend before stepping the
    /// sequence.
    pub fn swap_in(&mut self, id: SeqId) -> Result<SwapInOps> {
        let needed = match self.swapped.get(&id) {
            Some(s) => s.host_blocks(),
            None => bail!("sequence {id} is not swapped out"),
        };
        if self.alloc.num_free() < needed {
            bail!(
                "swap-in of sequence {id} needs {needed} device blocks, {} free",
                self.alloc.num_free()
            );
        }
        let sw = self.swapped.remove(&id).expect("checked above");
        let mut table = Vec::with_capacity(sw.entries.len());
        let mut copies = Vec::new();
        for entry in sw.entries {
            match entry {
                SwapEntry::Device(phys) => table.push(phys),
                SwapEntry::Host { slot, hash } => {
                    let phys = self.alloc.alloc().expect("free count checked above");
                    if let Some(h) = hash {
                        // restore shareability unless the hash was re-taken
                        // by a block created while we were swapped out
                        if !self.prefix_index.contains_key(&h) {
                            self.index_block(phys, h);
                        }
                    }
                    self.host
                        .as_mut()
                        .expect("swapped implies a host tier")
                        .release(slot);
                    copies.push((slot, phys));
                    table.push(phys);
                }
            }
        }
        self.seqs.insert(
            id,
            SeqState {
                table,
                len: sw.len,
                shared_prefix_blocks: sw.shared_prefix_blocks,
                min_blocks: sw.min_blocks,
            },
        );
        Ok(SwapInOps {
            copies,
            resume_len: sw.len,
        })
    }

    /// Abandon a swapped sequence: release its host slots and its
    /// references on shared device blocks (recompute fallback — the
    /// scheduler re-queues it as a fresh prefill).  Returns the freed
    /// host slots so the caller can tell the backend to discard their
    /// staging buffers (slot ids are never reused, so an undiscarded
    /// slot is a permanent leak on a real backend).
    pub fn drop_swapped(&mut self, id: SeqId) -> Vec<tier::HostSlotId> {
        let Some(sw) = self.swapped.remove(&id) else {
            return Vec::new();
        };
        let mut freed_slots = Vec::new();
        for entry in sw.entries {
            match entry {
                SwapEntry::Device(phys) => {
                    if self.alloc.decref(phys) {
                        self.unindex_block(phys);
                    }
                }
                SwapEntry::Host { slot, hash } => {
                    self.host
                        .as_mut()
                        .expect("swapped implies a host tier")
                        .release(slot);
                    if let Some(h) = hash {
                        // the host copy is gone; evict unless a device
                        // block independently serves the same hash
                        if !self.prefix_index.contains_key(&h) {
                            self.push_delta(h, PrefixDeltaKind::Evict);
                        }
                    }
                    freed_slots.push(slot);
                }
            }
        }
        freed_slots
    }

    // ---- cross-replica migration (disaggregated PD hand-off) --------------

    /// Physical blocks sequence `id` currently holds (0 if not
    /// resident).  The migration cost policy prices `seq_blocks` x PCIe
    /// transfer against re-prefilling `seq_len` tokens.
    pub fn seq_blocks(&self, id: SeqId) -> usize {
        self.seqs.get(&id).map(|s| s.table.len()).unwrap_or(0)
    }

    /// True when `id`'s blocks can stage through the host tier right
    /// now (host tier present with capacity for *every* block — shared
    /// blocks must travel too, the destination holds no references on
    /// this device's pool).
    pub fn can_migrate_out(&self, id: SeqId) -> bool {
        match (self.host.as_ref(), self.seqs.get(&id)) {
            (Some(host), Some(st)) => host.free() >= st.table.len(),
            _ => false,
        }
    }

    /// Export sequence `id` for a cross-replica hand-off: stage every
    /// block through a host slot, release this replica's references,
    /// and remove the sequence.  The caller **must** execute the
    /// returned stages through the backend before anything recycles the
    /// freed device blocks, then release the staging slots via
    /// [`CacheManager::release_host_slot`] once the payloads are in the
    /// hand-off envelope.  Fails without mutating.
    pub fn migrate_out(&mut self, id: SeqId) -> Result<MigrateOutOps> {
        if !self.can_migrate_out(id) {
            bail!(
                "cannot migrate out sequence {id} (no host tier, not resident, or host pool full)"
            );
        }
        let st = self.seqs.remove(&id).expect("resident per the check");
        let mut stages = Vec::with_capacity(st.table.len());
        let mut hashes = Vec::with_capacity(st.table.len());
        for &phys in &st.table {
            let slot = self
                .host
                .as_mut()
                .expect("host tier per the check")
                .alloc()
                .expect("capacity per the check");
            // capture the hash before the decref can free + unindex it;
            // a shared block keeps its index for the surviving readers
            hashes.push(self.block_hash.get(&phys).copied());
            stages.push((phys, slot));
            if self.alloc.decref(phys) {
                self.unindex_block(phys);
            }
        }
        Ok(MigrateOutOps {
            stages,
            hashes,
            resume_len: st.len,
            min_blocks: st.min_blocks,
        })
    }

    /// Release one transient migration staging slot after the backend
    /// has exported its payload.
    pub fn release_host_slot(&mut self, slot: tier::HostSlotId) {
        if let Some(host) = self.host.as_mut() {
            host.release(slot);
        }
    }

    /// Re-admit a migrated sequence on this replica at its exact decode
    /// offset.  Blocks whose content+position hash the destination
    /// already holds are reused through the prefix index (counted as
    /// prefix hits, skipped from the import list); the rest allocate
    /// fresh device blocks the backend must import the envelope
    /// payloads into.  Imported full blocks re-enter the prefix index,
    /// so shareability survives the hand-off.  Fails without mutating
    /// when the device pool cannot take the fresh blocks.
    pub fn migrate_in(
        &mut self,
        id: SeqId,
        hashes: &[Option<u64>],
        resume_len: usize,
        min_blocks: usize,
    ) -> Result<MigrateInOps> {
        if self.seqs.contains_key(&id) || self.swapped.contains_key(&id) {
            bail!("sequence {id} already exists");
        }
        // read-only pass: which incoming blocks this replica already holds
        let reuse: Vec<Option<BlockId>> = hashes
            .iter()
            .map(|h| h.and_then(|h| self.prefix_index.get(&h).copied()))
            .collect();
        let fresh = reuse.iter().filter(|r| r.is_none()).count();
        if self.alloc.num_free() < fresh {
            bail!(
                "migrate-in of sequence {id} needs {fresh} device blocks, {} free",
                self.alloc.num_free()
            );
        }
        let mut table = Vec::with_capacity(hashes.len());
        let mut imports = Vec::new();
        let mut reused_blocks = 0usize;
        for (i, r) in reuse.iter().enumerate() {
            match r {
                Some(phys) => {
                    self.alloc.incref(*phys);
                    table.push(*phys);
                    reused_blocks += 1;
                }
                None => {
                    let phys = self.alloc.alloc().expect("free count checked above");
                    if let Some(h) = hashes[i] {
                        if !self.prefix_index.contains_key(&h) {
                            self.index_block(phys, h);
                        }
                    }
                    imports.push((i, phys));
                    table.push(phys);
                }
            }
        }
        self.prefix_hits += reused_blocks as u64;
        self.seqs.insert(
            id,
            SeqState {
                table,
                len: resume_len,
                shared_prefix_blocks: reused_blocks,
                min_blocks,
            },
        );
        Ok(MigrateInOps {
            imports,
            reused_blocks,
        })
    }

    /// Host-tier occupancy snapshot.
    pub fn tier_stats(&self) -> TierStats {
        let (cap, used, peak) = self
            .host
            .as_ref()
            .map(|h| (h.capacity(), h.used(), h.used_peak()))
            .unwrap_or((0, 0, 0));
        let pinned = self
            .swapped
            .values()
            .flat_map(|s| s.entries.iter())
            .filter(|e| matches!(e, SwapEntry::Device(_)))
            .count();
        TierStats {
            host_capacity_blocks: cap,
            host_used_blocks: used,
            host_used_peak_blocks: peak,
            swapped_seqs: self.swapped.len(),
            pinned_shared_blocks: pinned,
        }
    }

    pub fn stats(&self) -> CacheStats {
        let bs = self.geometry.block_size;
        let slots_alloc = self.alloc.num_used() * bs;
        let slots_live: usize = self.seqs.values().map(|s| s.len).sum();
        CacheStats {
            blocks_total: self.alloc.num_blocks(),
            blocks_used: self.alloc.num_used(),
            slots_allocated: slots_alloc,
            slots_live,
            fragmentation: if slots_alloc == 0 {
                0.0
            } else {
                1.0 - slots_live as f64 / slots_alloc as f64
            },
            prefix_hits: self.prefix_hits,
            skipped_writes: self.skipped_writes,
            total_writes: self.total_writes,
        }
    }

    /// KV pool bytes per block per layer under `opt` at sim scale
    /// (f32 tensors stand in for the Z100's FP16; FP8 is byte-real).
    pub fn bytes_per_block(&self, kv_heads: usize, head_dim: usize, opt: &OptConfig) -> usize {
        let bs = self.geometry.block_size;
        let elt = if opt.fp8_kv { 1 } else { 2 }; // traffic dtype (paper: FP16)
        let scales = if opt.fp8_kv { bs * kv_heads * 4 * 2 } else { 0 };
        bs * kv_heads * head_dim * elt * 2 + scales
    }

    // ---- internals --------------------------------------------------------

    fn index_block(&mut self, phys: BlockId, hash: u64) {
        self.prefix_index.insert(hash, phys);
        self.block_hash.insert(phys, hash);
        self.push_delta(hash, PrefixDeltaKind::CommitDevice);
    }

    fn unindex_block(&mut self, phys: BlockId) {
        if let Some(h) = self.block_hash.remove(&phys) {
            // only remove if the index still points at this block
            if self.prefix_index.get(&h) == Some(&phys) {
                self.prefix_index.remove(&h);
                self.push_delta(h, PrefixDeltaKind::Evict);
            }
        }
    }

    fn push_delta(&mut self, hash: u64, kind: PrefixDeltaKind) {
        if self.delta_log.len() >= DELTA_LOG_CAP {
            self.delta_log.pop_front();
        }
        self.delta_log.push_back(PrefixDelta { hash, kind });
    }

    // ---- cross-replica prefix pulls ---------------------------------------

    /// Drain the undrained prefix residency deltas (the directory feed).
    pub fn take_prefix_deltas(&mut self) -> Vec<PrefixDelta> {
        self.delta_log.drain(..).collect()
    }

    /// Is this full-block hash device-resident right now?
    pub fn has_prefix_block(&self, hash: u64) -> bool {
        self.prefix_index.contains_key(&hash)
    }

    /// Device block currently serving `hash` through the prefix index.
    pub fn device_block_for_hash(&self, hash: u64) -> Option<BlockId> {
        self.prefix_index.get(&hash).copied()
    }

    /// Host slot holding a swapped-out copy of `hash`, if any.  A linear
    /// scan of the swapped set — bounded by concurrently swapped
    /// sequences, not pool size.
    pub fn host_slot_for_hash(&self, hash: u64) -> Option<tier::HostSlotId> {
        self.swapped
            .values()
            .flat_map(|s| s.entries.iter())
            .find_map(|e| match e {
                SwapEntry::Host { slot, hash: Some(h) } if *h == hash => Some(*slot),
                _ => None,
            })
    }

    /// Claim one transient host staging slot (prefix export path); the
    /// caller must release it via [`CacheManager::release_host_slot`].
    pub fn alloc_host_slot(&mut self) -> Option<tier::HostSlotId> {
        self.host.as_mut().and_then(|h| h.alloc())
    }

    /// Commit one pulled prefix block: allocate a device block, index it
    /// under `hash`, and *pin* it (a refcount this manager holds) so it
    /// survives until a prefill consumes it through the ordinary reuse
    /// path.  `None` when the hash is already resident/pinned or the
    /// pool has no free block — the caller simply pulls less.
    pub fn commit_pulled_block(&mut self, hash: u64) -> Option<BlockId> {
        if self.prefix_index.contains_key(&hash) || self.pulled_pins.contains_key(&hash) {
            return None;
        }
        let phys = self.alloc.alloc()?;
        self.index_block(phys, hash);
        self.pulled_pins.insert(hash, (phys, 0));
        Some(phys)
    }

    pub fn num_pulled_pins(&self) -> usize {
        self.pulled_pins.len()
    }

    /// Age the pulled-block pins one engine step.  A pin whose block
    /// gained another reader was consumed by a prefill: the pin drops
    /// and the block lives on with its reader.  A pin that reaches
    /// `ttl` unconsumed releases its block (and index entry) so pulled
    /// KV can never strand pool capacity.  Returns blocks released.
    pub fn tick_pulled_pins(&mut self, ttl: u32) -> usize {
        let hashes: Vec<u64> = self.pulled_pins.keys().copied().collect();
        let mut released = 0usize;
        for h in hashes {
            let (phys, age) = self.pulled_pins[&h];
            if self.alloc.refcount(phys) > 1 {
                self.pulled_pins.remove(&h);
                self.alloc.decref(phys);
            } else if age + 1 >= ttl {
                self.pulled_pins.remove(&h);
                if self.alloc.decref(phys) {
                    self.unindex_block(phys);
                }
                released += 1;
            } else {
                self.pulled_pins.get_mut(&h).expect("present above").1 = age + 1;
            }
        }
        released
    }

    /// Release every unconsumed pulled pin immediately (admission
    /// pressure, or end of a run): frees the pinned blocks so waiting
    /// prefills can proceed — the uncovered prefix is simply
    /// re-prefilled, exact by construction.  Returns blocks released.
    pub fn release_pulled_pins(&mut self) -> usize {
        let pins: Vec<(u64, BlockId)> = self
            .pulled_pins
            .drain()
            .map(|(h, (b, _))| (h, b))
            .collect();
        let mut released = 0usize;
        for (_h, phys) in pins {
            if self.alloc.decref(phys) {
                self.unindex_block(phys);
                released += 1;
            }
        }
        released
    }
}

/// FNV-1a over (prefix tokens, block tokens) — identifies a full block by
/// its content *and* position context, like vLLM's prefix-cache key.
fn prefix_hash(prefix: &[u32], chunk: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |x: u32| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    };
    eat(prefix.len() as u32);
    for &t in prefix {
        eat(t);
    }
    eat(0xFFFF_FFFF);
    for &t in chunk {
        eat(t);
    }
    h
}

/// Routing-affinity key of a prompt: the prefix-cache hash of its
/// *first* full KV block (`block_size` tokens at position 0), computed
/// with the same content+position hash the sharing index uses.  The
/// multi-replica router keys on the first block only — requests that
/// share a system prompt share it, while their divergent tails would
/// make any longer block-aligned key unique and useless for affinity.
/// `None` when the prompt doesn't fill one block (nothing sharable to
/// route on).
pub fn leading_prefix_hash(tokens: &[u32], block_size: usize) -> Option<u64> {
    if block_size == 0 || tokens.len() < block_size {
        return None;
    }
    Some(prefix_hash(&[], &tokens[..block_size]))
}

/// The prompt's full prefix-hash *chain*: one content+position hash per
/// complete leading KV block — exactly the hashes prefill commits to
/// the sharing index — capped at `max_blocks` (the directory's key
/// budget per request).  Chain hash `k` commits to every token before
/// block `k`, so a directory hit at depth `k` identifies the entire
/// `k+1`-block prefix, not just one block.  `chain[0]` equals
/// [`leading_prefix_hash`].
pub fn prefix_chain_hashes(tokens: &[u32], block_size: usize, max_blocks: usize) -> Vec<u64> {
    if block_size == 0 {
        return Vec::new();
    }
    let full = (tokens.len() / block_size).min(max_blocks);
    (0..full)
        .map(|b| {
            prefix_hash(
                &tokens[..b * block_size],
                &tokens[b * block_size..(b + 1) * block_size],
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{COOPT, ORIGINAL};

    fn geom() -> CacheGeometry {
        CacheGeometry {
            block_size: 4,
            max_blocks: 8,
            num_pool_blocks: 16,
            max_batch: 4,
            max_seq: 16,
        }
    }

    #[test]
    fn leading_prefix_hash_keys_on_first_block_only() {
        // same first block, different tails -> same affinity key
        let a = [1u32, 2, 3, 4, 50, 51];
        let b = [1u32, 2, 3, 4, 90];
        assert_eq!(leading_prefix_hash(&a, 4), leading_prefix_hash(&b, 4));
        assert!(leading_prefix_hash(&a, 4).is_some());
        // a different first block -> a different key
        let c = [9u32, 2, 3, 4, 50, 51];
        assert_ne!(leading_prefix_hash(&a, 4), leading_prefix_hash(&c, 4));
        // too short to fill a block (or degenerate geometry) -> no key
        assert_eq!(leading_prefix_hash(&[1, 2, 3], 4), None);
        assert_eq!(leading_prefix_hash(&a, 0), None);
        // matches the sharing index's hash for the same block
        assert_eq!(
            leading_prefix_hash(&a, 4),
            Some(prefix_hash(&[], &[1, 2, 3, 4]))
        );
    }

    #[test]
    fn allocator_basics() {
        let mut a = BlockAllocator::new(4);
        let b1 = a.alloc().unwrap();
        let b2 = a.alloc().unwrap();
        assert_ne!(b1, b2);
        assert_eq!(a.num_free(), 2);
        a.incref(b1);
        assert!(!a.decref(b1));
        assert!(a.decref(b1));
        assert_eq!(a.num_free(), 3);
        assert!(a.decref(b2));
        assert_eq!(a.num_free(), 4);
        assert!(a.alloc().is_some());
    }

    #[test]
    #[should_panic]
    fn allocator_double_free_panics() {
        let mut a = BlockAllocator::new(2);
        let b = a.alloc().unwrap();
        a.decref(b);
        a.decref(b);
    }

    #[test]
    fn prefill_coopt_skips_padding() {
        let mut cm = CacheManager::new(geom());
        let plan = cm.prefill(1, &[10, 11, 12, 13, 14, 15], &COOPT).unwrap();
        // 6 tokens, block 4: 2 blocks; slots 0..5 set, rest -1
        assert_eq!(plan.written, 6);
        assert_eq!(plan.skipped, 10);
        assert!(plan.slot_mapping[..6].iter().all(|&s| s >= 0));
        assert!(plan.slot_mapping[6..].iter().all(|&s| s == -1));
        assert_eq!(cm.stats().blocks_used, 2);
    }

    #[test]
    fn prefill_original_writes_padding() {
        let mut cm = CacheManager::new(geom());
        let plan = cm.prefill(1, &[10, 11, 12, 13, 14, 15], &ORIGINAL).unwrap();
        // baseline writes every padded position: 16 slots, 4 blocks
        assert_eq!(plan.written, 16);
        assert_eq!(plan.skipped, 0);
        assert_eq!(cm.stats().blocks_used, 4);
        // and fragmentation is visible: 16 slots allocated, 6 live
        let st = cm.stats();
        assert_eq!(st.slots_allocated, 16);
        assert_eq!(st.slots_live, 6);
        assert!(st.fragmentation > 0.6);
    }

    #[test]
    fn decode_appends_and_grows() {
        let mut cm = CacheManager::new(geom());
        cm.prefill(1, &[1, 2, 3], &COOPT).unwrap();
        let (slot, pos) = cm.append_token(1).unwrap();
        assert_eq!(pos, 3);
        assert!(slot >= 0);
        assert_eq!(cm.seq_len(1), 4);
        // crossing a block boundary allocates
        let used_before = cm.stats().blocks_used;
        let (_, pos) = cm.append_token(1).unwrap();
        assert_eq!(pos, 4);
        assert_eq!(cm.stats().blocks_used, used_before + 1);
    }

    #[test]
    fn prefix_sharing_reuses_blocks() {
        let mut cm = CacheManager::new(geom());
        let prompt = [7u32, 8, 9, 10, 20, 21, 22, 23, 5];
        let p1 = cm.prefill(1, &prompt, &COOPT).unwrap();
        assert_eq!(p1.reused_blocks, 0);
        let p2 = cm.prefill(2, &prompt, &COOPT).unwrap();
        // both full blocks shared; only the tail written
        assert_eq!(p2.reused_blocks, 2);
        assert_eq!(p2.written, 1);
        // physical tables overlap on the shared prefix
        assert_eq!(cm.block_table_row(1)[..2], cm.block_table_row(2)[..2]);
        // COW: appending to seq 2 must not touch seq 1's blocks
        cm.free_seq(1);
        cm.free_seq(2);
        assert_eq!(cm.stats().blocks_used, 0);
    }

    #[test]
    fn original_never_shares() {
        let mut cm = CacheManager::new(geom());
        let prompt = [7u32, 8, 9, 10, 20, 21, 22, 23];
        cm.prefill(1, &prompt, &ORIGINAL).unwrap();
        let p2 = cm.prefill(2, &prompt, &ORIGINAL).unwrap();
        assert_eq!(p2.reused_blocks, 0);
        assert_eq!(cm.stats().prefix_hits, 0);
    }

    #[test]
    fn free_recycles_everything() {
        let mut cm = CacheManager::new(geom());
        for id in 0..3u64 {
            cm.prefill(id, &[1, 2, 3, 4, 5], &COOPT).unwrap();
        }
        assert!(cm.stats().blocks_used > 0);
        for id in 0..3u64 {
            cm.free_seq(id);
        }
        assert_eq!(cm.stats().blocks_used, 0);
        assert_eq!(cm.num_free_blocks(), 16);
    }

    #[test]
    fn admission_control() {
        let mut cm = CacheManager::new(geom());
        assert!(cm.can_admit(8, &COOPT));
        // fill the pool
        let mut id = 0u64;
        while cm.can_admit(16, &COOPT) {
            cm.prefill(id, &(0..16).map(|x| id as u32 * 100 + x).collect::<Vec<_>>(), &COOPT)
                .unwrap();
            id += 1;
        }
        assert!(!cm.can_admit(16, &COOPT));
        cm.free_seq(0);
        assert!(cm.can_admit(8, &COOPT));
    }

    #[test]
    fn out_of_blocks_rolls_back() {
        let mut small = CacheManager::new(CacheGeometry {
            block_size: 4,
            max_blocks: 8,
            num_pool_blocks: 2,
            max_batch: 4,
            max_seq: 16,
        });
        // needs 4 blocks for baseline padded write, only 2 exist
        let err = small.prefill(1, &[1, 2, 3], &ORIGINAL);
        assert!(err.is_err());
        assert_eq!(small.stats().blocks_used, 0); // rolled back
        assert!(!small.has_seq(1));
    }

    #[test]
    fn max_context_enforced() {
        let g = CacheGeometry {
            block_size: 2,
            max_blocks: 2,
            num_pool_blocks: 8,
            max_batch: 1,
            max_seq: 4,
        };
        let mut cm = CacheManager::new(g);
        cm.prefill(1, &[1, 2, 3], &COOPT).unwrap();
        cm.append_token(1).unwrap(); // pos 3 (ctx 4 = max)
        assert!(cm.append_token(1).is_err());
    }

    #[test]
    fn chunked_prefill_matches_oneshot_coopt() {
        let prompt: Vec<u32> = (0..13).map(|i| 40 + i).collect();
        let mut one = CacheManager::new(geom());
        let p = one.prefill(1, &prompt, &COOPT).unwrap();
        let mut chunked = CacheManager::new(geom());
        // windows 5 + 3 + 5 (unaligned on purpose)
        let a = chunked.prefill_chunk(1, &prompt, 0, 5, &COOPT, false).unwrap();
        let b = chunked.prefill_chunk(1, &prompt, 5, 3, &COOPT, false).unwrap();
        let c = chunked.prefill_chunk(1, &prompt, 8, 5, &COOPT, true).unwrap();
        assert_eq!(a.written + b.written + c.written, p.written);
        assert_eq!(a.skipped + b.skipped + c.skipped, p.skipped);
        assert_eq!(chunked.seq_len(1), one.seq_len(1));
        assert_eq!(chunked.stats().blocks_used, one.stats().blocks_used);
        assert_eq!(chunked.stats().total_writes, one.stats().total_writes);
        assert_eq!(chunked.block_table_row(1).len(), one.block_table_row(1).len());
    }

    #[test]
    fn chunked_prefill_matches_oneshot_baseline_padding() {
        let prompt: Vec<u32> = (0..6).map(|i| 10 + i).collect();
        let mut one = CacheManager::new(geom());
        let p = one.prefill(1, &prompt, &ORIGINAL).unwrap();
        let mut chunked = CacheManager::new(geom());
        let a = chunked.prefill_chunk(1, &prompt, 0, 4, &ORIGINAL, false).unwrap();
        let b = chunked.prefill_chunk(1, &prompt, 4, 2, &ORIGINAL, true).unwrap();
        // the final chunk writes the baseline padding, like one-shot
        assert_eq!(a.written + b.written, p.written);
        assert_eq!(p.written, 16);
        assert_eq!(a.skipped + b.skipped, p.skipped);
        assert_eq!(chunked.stats().blocks_used, one.stats().blocks_used);
    }

    #[test]
    fn chunked_prefill_shares_prefix_blocks_across_sequences() {
        let prompt = [7u32, 8, 9, 10, 20, 21, 22, 23, 5];
        let mut cm = CacheManager::new(geom());
        cm.prefill(1, &prompt, &COOPT).unwrap();
        // second sequence arrives in block-aligned chunks: both full
        // blocks are shared exactly as in one-shot prefill
        let a = cm.prefill_chunk(2, &prompt, 0, 4, &COOPT, false).unwrap();
        let b = cm.prefill_chunk(2, &prompt, 4, 5, &COOPT, true).unwrap();
        assert_eq!(a.reused_blocks, 1);
        assert_eq!(b.reused_blocks, 1);
        assert_eq!(a.written + b.written, 1, "only the tail token is written");
        assert_eq!(cm.block_table_row(1)[..2], cm.block_table_row(2)[..2]);
        cm.free_seq(1);
        cm.free_seq(2);
        assert_eq!(cm.stats().blocks_used, 0);
    }

    #[test]
    fn blocks_split_across_windows_still_become_shareable() {
        // windows smaller than a block: every block is filled piecewise,
        // yet once full it must enter the prefix index so a later
        // sequence can share it exactly as after one-shot prefill
        let prompt: Vec<u32> = (0..9).map(|i| 60 + i).collect();
        let mut cm = CacheManager::new(geom()); // block_size 4
        let mut off = 0;
        while off < prompt.len() {
            let take = 3.min(prompt.len() - off);
            let fin = off + take == prompt.len();
            cm.prefill_chunk(1, &prompt, off, take, &COOPT, fin).unwrap();
            off += take;
        }
        let p2 = cm.prefill(2, &prompt, &COOPT).unwrap();
        assert_eq!(p2.reused_blocks, 2, "both full blocks shared despite split windows");
        assert_eq!(cm.block_table_row(1)[..2], cm.block_table_row(2)[..2]);
        cm.free_seq(1);
        cm.free_seq(2);
        assert_eq!(cm.stats().blocks_used, 0);
    }

    #[test]
    fn chunk_offset_must_match_committed_length() {
        let prompt: Vec<u32> = (0..12).collect();
        let mut cm = CacheManager::new(geom());
        cm.prefill_chunk(1, &prompt, 0, 4, &COOPT, false).unwrap();
        // gap and overlap both rejected; retry from the committed offset works
        assert!(cm.prefill_chunk(1, &prompt, 8, 4, &COOPT, false).is_err());
        assert!(cm.prefill_chunk(1, &prompt, 0, 4, &COOPT, false).is_err());
        assert!(cm.prefill_chunk(1, &prompt, 4, 8, &COOPT, true).is_ok());
        assert_eq!(cm.seq_len(1), 12);
        // finality must agree with the window
        let mut cm2 = CacheManager::new(geom());
        assert!(cm2.prefill_chunk(2, &prompt, 0, 4, &COOPT, true).is_err());
    }

    #[test]
    fn failed_chunk_keeps_earlier_chunks_committed() {
        let mut cm = CacheManager::new(CacheGeometry {
            block_size: 4,
            max_blocks: 8,
            num_pool_blocks: 2,
            max_batch: 4,
            max_seq: 32,
        });
        let prompt: Vec<u32> = (0..20).collect();
        cm.prefill_chunk(1, &prompt, 0, 8, &COOPT, false).unwrap();
        assert_eq!(cm.stats().blocks_used, 2);
        // pool exhausted: the window rolls back, the prefix survives
        assert!(cm.prefill_chunk(1, &prompt, 8, 8, &COOPT, false).is_err());
        assert_eq!(cm.seq_len(1), 8, "committed prefix intact");
        assert_eq!(cm.stats().blocks_used, 2, "window allocations rolled back");
    }

    #[test]
    fn failed_window_does_not_count_prefix_hits() {
        let mut cm = CacheManager::new(CacheGeometry {
            block_size: 4,
            max_blocks: 8,
            num_pool_blocks: 3,
            max_batch: 4,
            max_seq: 16,
        });
        let a: Vec<u32> = (0..12).collect();
        cm.prefill(1, &a, &COOPT).unwrap(); // 3 blocks, pool exhausted
        let mut b = a[..8].to_vec();
        b.extend([90, 91, 92, 93]);
        // shares two blocks, then fails allocating the third
        assert!(cm.prefill(2, &b, &COOPT).is_err());
        assert_eq!(cm.stats().prefix_hits, 0, "rolled-back window counts no hits");
        assert_eq!(cm.stats().blocks_used, 3);
        assert!(!cm.has_seq(2));
    }

    #[test]
    fn chunked_admission_bound() {
        let cm = CacheManager::new(geom()); // 16 blocks of 4
        assert!(cm.can_admit_tokens(4, &COOPT));
        assert!(cm.can_admit_tokens(56, &ORIGINAL)); // 14 blocks + headroom
        assert!(!cm.can_admit_tokens(64, &COOPT)); // 16 blocks + headroom > pool
    }

    #[test]
    fn bytes_per_block_fp8_smaller() {
        let cm = CacheManager::new(geom());
        let fp16 = cm.bytes_per_block(4, 32, &ORIGINAL);
        let fp8 = cm.bytes_per_block(4, 32, &COOPT);
        assert!(fp8 < fp16, "{fp8} vs {fp16}");
    }

    // ---- allocator refcount edge cases (the tier manager relies on these)

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "incref of free block")]
    fn allocator_incref_on_freed_block_panics() {
        let mut a = BlockAllocator::new(2);
        let b = a.alloc().unwrap();
        a.decref(b);
        a.incref(b);
    }

    #[test]
    fn allocator_exhaustion_and_reuse_ordering() {
        let mut a = BlockAllocator::new(3);
        let mut got = Vec::new();
        while let Some(b) = a.alloc() {
            got.push(b);
        }
        assert_eq!(got.len(), 3);
        assert_eq!(a.num_free(), 0);
        assert!(a.alloc().is_none(), "exhausted pool refuses");
        // free in a known order: the free list is LIFO, so the most
        // recently freed block is handed out first
        a.decref(got[0]);
        a.decref(got[2]);
        assert_eq!(a.alloc(), Some(got[2]));
        assert_eq!(a.alloc(), Some(got[0]));
        assert!(a.alloc().is_none());
        assert_eq!(a.total_frees, 2);
        assert_eq!(a.total_allocs, 5);
    }

    #[test]
    fn allocator_refcount_lifecycle_across_shares() {
        let mut a = BlockAllocator::new(2);
        let b = a.alloc().unwrap();
        a.incref(b);
        a.incref(b);
        assert_eq!(a.refcount(b), 3);
        assert!(!a.decref(b));
        assert!(!a.decref(b));
        assert_eq!(a.refcount(b), 1);
        assert_eq!(a.num_used(), 1, "still allocated until the last ref drops");
        assert!(a.decref(b));
        assert_eq!(a.num_used(), 0);
    }

    // ---- speculative rollback (truncate_seq) ------------------------------

    #[test]
    fn truncate_across_block_boundary_frees_whole_blocks() {
        let mut cm = CacheManager::new(geom()); // block_size 4
        cm.prefill(1, &[1, 2, 3, 4, 5, 6], &COOPT).unwrap();
        // grow to 11 tokens: blocks [0..4)(prefill) [4..8) [8..11)
        for _ in 0..5 {
            cm.append_token(1).unwrap();
        }
        assert_eq!(cm.seq_len(1), 11);
        assert_eq!(cm.stats().blocks_used, 3);
        // roll back across a boundary: 11 -> 6 drops the third block
        // entirely and the second block's tail positions
        let released = cm.truncate_seq(1, 6).unwrap();
        assert_eq!(released, 1);
        assert_eq!(cm.seq_len(1), 6);
        assert_eq!(cm.stats().blocks_used, 2);
        // rolling back to exactly a block boundary keeps the whole
        // boundary block and frees everything past it
        for _ in 0..3 {
            cm.append_token(1).unwrap(); // len 9, 3 blocks again
        }
        assert_eq!(cm.truncate_seq(1, 8).unwrap(), 1);
        assert_eq!(cm.seq_len(1), 8);
        assert_eq!(cm.stats().blocks_used, 2);
        // degenerate calls
        assert!(cm.truncate_seq(1, 10).is_err(), "beyond committed length");
        assert!(cm.truncate_seq(9, 1).is_err(), "unknown sequence");
        assert_eq!(cm.truncate_seq(1, 8).unwrap(), 0, "no-op truncate");
        cm.free_seq(1);
        assert_eq!(cm.stats().blocks_used, 0);
    }

    #[test]
    fn truncate_into_prefix_shared_block_cows_and_keeps_sharer_intact() {
        let mut cm = CacheManager::new(geom());
        let prompt = [7u32, 8, 9, 10, 20, 21, 22, 23];
        cm.prefill(1, &prompt, &COOPT).unwrap();
        let p2 = cm.prefill(2, &prompt, &COOPT).unwrap();
        assert_eq!(p2.reused_blocks, 2);
        let shared: Vec<i32> = cm.block_table_row(1)[..2].to_vec();

        // truncate seq 2 into the middle of its second (shared) block:
        // it must get a private copy, never a write path into the
        // sharer's slots
        cm.truncate_seq(2, 6).unwrap();
        assert_eq!(cm.seq_len(2), 6);
        assert_ne!(
            cm.block_table_row(2)[1],
            shared[1],
            "boundary block copied-on-write"
        );
        assert_eq!(cm.block_table_row(1)[..2], shared[..], "sharer untouched");
        // resuming appends lands in the private copy and allocates as usual
        let (slot, pos) = cm.append_token(2).unwrap();
        assert_eq!(pos, 6);
        assert_eq!(slot as usize / 4, cm.block_table_row(2)[1] as usize);
        // the sharer keeps decoding on the original physical blocks
        cm.append_token(1).unwrap();
        assert_eq!(cm.block_table_row(1)[..2], shared[..]);
        cm.free_seq(1);
        cm.free_seq(2);
        assert_eq!(cm.stats().blocks_used, 0);
    }

    #[test]
    fn truncate_fully_dropping_shared_block_only_drops_one_reference() {
        let mut cm = CacheManager::new(geom());
        let prompt = [7u32, 8, 9, 10, 20, 21, 22, 23];
        cm.prefill(1, &prompt, &COOPT).unwrap();
        cm.prefill(2, &prompt, &COOPT).unwrap();
        let shared = cm.block_table_row(1)[1];
        // block-aligned truncate that drops seq 2's whole second block
        // (shared): the sharer's data must survive
        let released = cm.truncate_seq(2, 4).unwrap();
        assert_eq!(released, 1);
        assert_eq!(cm.block_table_row(1)[1], shared);
        cm.append_token(1).unwrap();
        cm.free_seq(1);
        cm.free_seq(2);
        assert_eq!(cm.stats().blocks_used, 0);
    }

    #[test]
    fn truncate_then_resume_matches_never_speculated() {
        // speculative round shape: reserve, roll back, re-append — the
        // final table/len must match a run that never speculated
        let prompt: Vec<u32> = (0..6).map(|i| 50 + i).collect();
        let mut plain = CacheManager::new(geom());
        plain.prefill(1, &prompt, &COOPT).unwrap();
        for _ in 0..3 {
            plain.append_token(1).unwrap();
        }
        let mut spec = CacheManager::new(geom());
        spec.prefill(1, &prompt, &COOPT).unwrap();
        // reserve 4 speculative positions, reject 3 of them
        for _ in 0..4 {
            spec.append_token(1).unwrap();
        }
        spec.truncate_seq(1, 7).unwrap();
        for _ in 0..2 {
            spec.append_token(1).unwrap();
        }
        assert_eq!(spec.seq_len(1), plain.seq_len(1));
        assert_eq!(
            spec.block_table_row(1).len(),
            plain.block_table_row(1).len()
        );
        assert_eq!(spec.stats().blocks_used, plain.stats().blocks_used);
        spec.free_seq(1);
        assert_eq!(spec.stats().blocks_used, 0);
    }

    #[test]
    fn truncate_respects_baseline_padding_floor() {
        // the padded baseline materialized its padding blocks at prefill;
        // speculative rollback must not free them
        let mut cm = CacheManager::new(geom()); // max_seq 16, bs 4
        cm.prefill(1, &[1, 2, 3, 4, 5, 6], &ORIGINAL).unwrap();
        assert_eq!(cm.stats().blocks_used, 4, "padded span allocated");
        cm.append_token(1).unwrap();
        cm.append_token(1).unwrap();
        let released = cm.truncate_seq(1, 7).unwrap();
        assert_eq!(released, 0, "padding blocks stay with the sequence");
        assert_eq!(cm.stats().blocks_used, 4);
        assert_eq!(cm.seq_len(1), 7);
        // and the sequence keeps appending into the retained span
        let (_, pos) = cm.append_token(1).unwrap();
        assert_eq!(pos, 7);
        cm.free_seq(1);
        assert_eq!(cm.stats().blocks_used, 0);
    }

    #[test]
    fn truncate_survives_swap_roundtrip() {
        // min_blocks and rollback behaviour are preserved across the host
        // tier: swap out, swap in, then roll back
        let mut cm = tiered(8);
        cm.prefill(1, &[1, 2, 3, 4, 5, 6], &COOPT).unwrap();
        for _ in 0..4 {
            cm.append_token(1).unwrap();
        }
        cm.swap_out(1).unwrap();
        cm.swap_in(1).unwrap();
        assert_eq!(cm.seq_len(1), 10);
        let released = cm.truncate_seq(1, 7).unwrap();
        assert_eq!(released, 1);
        assert_eq!(cm.seq_len(1), 7);
        cm.append_token(1).unwrap();
        cm.free_seq(1);
        assert_eq!(cm.stats().blocks_used, 0);
        assert_eq!(cm.tier_stats().host_used_blocks, 0);
    }

    // ---- two-tier residency (Opt-KV tier manager) -------------------------

    fn tiered(host_blocks: usize) -> CacheManager {
        let mut cm = CacheManager::new(geom());
        cm.enable_host_tier(host_blocks);
        cm
    }

    #[test]
    fn swap_out_in_roundtrip_preserves_table_and_len() {
        let mut cm = tiered(8);
        let prompt: Vec<u32> = (0..10).map(|i| 50 + i).collect();
        cm.prefill(1, &prompt, &COOPT).unwrap();
        cm.append_token(1).unwrap();
        let len_before = cm.seq_len(1);
        let used_before = cm.stats().blocks_used;

        let ops = cm.swap_out(1).unwrap();
        assert_eq!(ops.copies.len(), 3, "3 sole-owner blocks move to host");
        assert_eq!(ops.freed_blocks, 3);
        assert_eq!(ops.tokens, len_before);
        assert!(cm.is_swapped(1));
        assert!(!cm.has_seq(1));
        assert_eq!(cm.swapped_len(1), len_before);
        assert_eq!(cm.stats().blocks_used, used_before - 3);
        assert_eq!(cm.tier_stats().host_used_blocks, 3);

        let back = cm.swap_in(1).unwrap();
        assert_eq!(back.copies.len(), 3);
        assert_eq!(back.resume_len, len_before);
        assert!(cm.has_seq(1));
        assert_eq!(cm.seq_len(1), len_before, "resumes at the exact offset");
        assert_eq!(cm.stats().blocks_used, used_before);
        assert_eq!(cm.tier_stats().host_used_blocks, 0);
        // decoding continues as if nothing happened
        cm.append_token(1).unwrap();
        cm.free_seq(1);
        assert_eq!(cm.stats().blocks_used, 0);
    }

    #[test]
    fn swap_refused_without_host_tier_or_capacity() {
        let mut cm = CacheManager::new(geom());
        cm.prefill(1, &[1, 2, 3, 4, 5], &COOPT).unwrap();
        assert!(cm.swap_out_plan(1).is_none(), "no host tier");
        assert!(cm.swap_out(1).is_err());
        assert!(cm.has_seq(1), "refused swap leaves the sequence resident");

        let mut cm = tiered(1); // 5 tokens need 2 host blocks
        cm.prefill(1, &[1, 2, 3, 4, 5], &COOPT).unwrap();
        assert!(cm.swap_out_plan(1).is_none(), "host pool too small");
        assert!(cm.swap_out(1).is_err());
        assert_eq!(cm.stats().blocks_used, 2, "nothing mutated");
    }

    #[test]
    fn shared_prefix_block_survives_one_readers_swap() {
        let mut cm = tiered(8);
        let prompt = [7u32, 8, 9, 10, 20, 21, 22, 23, 5];
        cm.prefill(1, &prompt, &COOPT).unwrap();
        let p2 = cm.prefill(2, &prompt, &COOPT).unwrap();
        assert_eq!(p2.reused_blocks, 2);
        let shared: Vec<i32> = cm.block_table_row(1)[..2].to_vec();

        // swapping seq 2 moves only its private tail; the shared blocks
        // stay on device, pinned by seq 2's retained references
        let ops = cm.swap_out(2).unwrap();
        assert_eq!(ops.copies.len(), 1, "only the sole-owner tail block moves");
        assert_eq!(cm.tier_stats().pinned_shared_blocks, 2);

        // the surviving reader keeps decoding on the same physical blocks
        assert_eq!(cm.block_table_row(1)[..2], shared[..]);
        cm.append_token(1).unwrap();

        // even freeing the surviving reader must not free the shared
        // blocks — the swapped sequence still holds them
        cm.free_seq(1);
        let back = cm.swap_in(2).unwrap();
        assert_eq!(back.copies.len(), 1);
        assert_eq!(
            cm.block_table_row(2)[..2],
            shared[..],
            "swap-in reattaches the identical shared blocks"
        );
        cm.free_seq(2);
        assert_eq!(cm.stats().blocks_used, 0);
        assert_eq!(cm.tier_stats().host_used_blocks, 0);
    }

    #[test]
    fn swap_out_unindexes_and_swap_in_reindexes_prefix_blocks() {
        let mut cm = tiered(8);
        let prompt = [7u32, 8, 9, 10, 20, 21, 22, 23];
        cm.prefill(1, &prompt, &COOPT).unwrap();
        cm.swap_out(1).unwrap();
        // while seq 1 is on the host, its blocks are unshareable: a new
        // identical prompt allocates fresh blocks
        let p2 = cm.prefill(2, &prompt, &COOPT).unwrap();
        assert_eq!(p2.reused_blocks, 0, "host-resident blocks serve no prefix match");
        cm.free_seq(2);
        // back on device, the blocks are shareable again
        cm.swap_in(1).unwrap();
        let p3 = cm.prefill(3, &prompt, &COOPT).unwrap();
        assert_eq!(p3.reused_blocks, 2, "swap-in restored the prefix index");
        cm.free_seq(1);
        cm.free_seq(3);
        assert_eq!(cm.stats().blocks_used, 0);
    }

    #[test]
    fn swap_in_fails_cleanly_when_device_pool_full() {
        let mut cm = tiered(8);
        let prompt: Vec<u32> = (0..12).map(|i| 70 + i).collect();
        cm.prefill(1, &prompt, &COOPT).unwrap();
        cm.swap_out(1).unwrap();
        // fill the device pool down to a single free block
        let mut id = 10u64;
        while cm.can_admit(12, &COOPT) {
            let p: Vec<u32> = (0..12).map(|x| id as u32 * 100 + x).collect();
            cm.prefill(id, &p, &COOPT).unwrap();
            id += 1;
        }
        let free_before = cm.num_free_blocks();
        assert!(free_before < cm.swap_in_blocks_needed(1));
        assert!(cm.swap_in(1).is_err());
        assert!(cm.is_swapped(1), "failed swap-in leaves the host copy intact");
        assert_eq!(cm.num_free_blocks(), free_before, "nothing allocated");
        // free everything: swap-in now succeeds
        for seq in 10..id {
            cm.free_seq(seq);
        }
        cm.swap_in(1).unwrap();
        cm.free_seq(1);
        assert_eq!(cm.stats().blocks_used, 0);
    }

    #[test]
    fn drop_swapped_releases_both_tiers() {
        let mut cm = tiered(8);
        let prompt = [7u32, 8, 9, 10, 20, 21, 22, 23, 5];
        cm.prefill(1, &prompt, &COOPT).unwrap();
        cm.prefill(2, &prompt, &COOPT).unwrap();
        cm.swap_out(2).unwrap();
        let slots = cm.drop_swapped(2);
        assert_eq!(slots.len(), 1, "the abandoned host slot is reported for discard");
        assert!(!cm.is_swapped(2));
        assert_eq!(cm.tier_stats().host_used_blocks, 0);
        // seq 1 unharmed, and the pool drains to zero afterwards
        cm.append_token(1).unwrap();
        cm.free_seq(1);
        assert_eq!(cm.stats().blocks_used, 0);
        // free_seq on a swapped id routes through drop_swapped too, and
        // surfaces the freed host slots for the backend to discard
        cm.prefill(3, &prompt, &COOPT).unwrap();
        cm.swap_out(3).unwrap();
        let freed = cm.free_seq(3);
        // seq 3 swapped alone: all 3 sole-owner blocks went to the host,
        // and all 3 slots come back for the backend to discard
        assert_eq!(freed.len(), 3, "host slots reported for swap_discard");
        assert!(!cm.is_swapped(3));
        assert_eq!(cm.stats().blocks_used, 0);
        assert_eq!(cm.tier_stats().host_used_blocks, 0);
    }

    // ---- cross-replica migration (disaggregated PD hand-off) --------------

    #[test]
    fn migrate_out_in_roundtrip_across_managers() {
        let mut src = tiered(8);
        let mut dst = tiered(8);
        let prompt: Vec<u32> = (0..10).map(|i| 50 + i).collect();
        src.prefill(1, &prompt, &COOPT).unwrap();
        src.append_token(1).unwrap();
        let len = src.seq_len(1);
        assert_eq!(src.seq_blocks(1), 3);

        let out = src.migrate_out(1).unwrap();
        assert_eq!(out.stages.len(), 3, "every block stages through the host tier");
        assert_eq!(out.resume_len, len);
        assert!(!src.has_seq(1), "the source forgets the sequence");
        assert_eq!(src.stats().blocks_used, 0, "source device blocks freed");
        for &(_, slot) in &out.stages {
            src.release_host_slot(slot);
        }
        assert_eq!(src.tier_stats().host_used_blocks, 0, "staging is transient");

        let inn = dst
            .migrate_in(1, &out.hashes, out.resume_len, out.min_blocks)
            .unwrap();
        assert_eq!(inn.imports.len(), 3, "cold destination imports every block");
        assert_eq!(inn.reused_blocks, 0);
        assert_eq!(dst.seq_len(1), len, "resumes at the exact decode offset");
        // decoding continues as if the sequence had always lived here
        dst.append_token(1).unwrap();
        dst.free_seq(1);
        assert_eq!(dst.stats().blocks_used, 0);
    }

    #[test]
    fn migrate_in_reuses_hash_matched_blocks_and_reindexes() {
        let mut src = tiered(8);
        let mut dst = tiered(8);
        let prompt = [7u32, 8, 9, 10, 20, 21, 22, 23, 5];
        // the destination already serves the same tenant prompt
        dst.prefill(9, &prompt, &COOPT).unwrap();
        src.prefill(1, &prompt, &COOPT).unwrap();
        let out = src.migrate_out(1).unwrap();
        assert_eq!(out.hashes.iter().filter(|h| h.is_some()).count(), 2);
        let inn = dst
            .migrate_in(1, &out.hashes, out.resume_len, out.min_blocks)
            .unwrap();
        assert_eq!(inn.reused_blocks, 2, "full prefix blocks reused on arrival");
        assert_eq!(inn.imports.len(), 1, "only the private tail block imports");
        assert_eq!(
            dst.block_table_row(1)[..2],
            dst.block_table_row(9)[..2],
            "migrated sequence shares the destination's physical blocks"
        );
        dst.free_seq(9);
        // imported blocks re-entered the prefix index: a later identical
        // prompt shares them even though the original sharer is gone
        dst.append_token(1).unwrap();
        let p3 = dst.prefill(3, &prompt, &COOPT).unwrap();
        assert_eq!(p3.reused_blocks, 2, "prefix re-indexing preserved");
        dst.free_seq(1);
        dst.free_seq(3);
        assert_eq!(dst.stats().blocks_used, 0);
    }

    #[test]
    fn migrate_out_stages_shared_blocks_without_harming_survivors() {
        let mut src = tiered(8);
        let prompt = [7u32, 8, 9, 10, 20, 21, 22, 23, 5];
        src.prefill(1, &prompt, &COOPT).unwrap();
        let p2 = src.prefill(2, &prompt, &COOPT).unwrap();
        assert_eq!(p2.reused_blocks, 2);
        let shared: Vec<i32> = src.block_table_row(1)[..2].to_vec();
        let out = src.migrate_out(2).unwrap();
        assert_eq!(out.stages.len(), 3, "shared blocks travel too");
        for &(_, slot) in &out.stages {
            src.release_host_slot(slot);
        }
        // the survivor keeps decoding on the same physical blocks
        assert_eq!(src.block_table_row(1)[..2], shared[..]);
        src.append_token(1).unwrap();
        src.free_seq(1);
        assert_eq!(src.stats().blocks_used, 0);
        assert_eq!(src.tier_stats().host_used_blocks, 0);
    }

    #[test]
    fn migrate_refused_without_capacity_and_fails_clean() {
        // no host tier: nothing to stage through
        let mut cm = CacheManager::new(geom());
        cm.prefill(1, &[1, 2, 3, 4, 5], &COOPT).unwrap();
        assert!(!cm.can_migrate_out(1));
        assert!(cm.migrate_out(1).is_err());
        assert!(cm.has_seq(1), "refused migrate leaves the sequence resident");

        // host pool too small for the whole table
        let mut cm = tiered(1);
        cm.prefill(1, &[1, 2, 3, 4, 5], &COOPT).unwrap();
        assert!(!cm.can_migrate_out(1));
        assert!(cm.migrate_out(1).is_err());
        assert_eq!(cm.stats().blocks_used, 2, "nothing mutated");

        // destination pool too small: migrate_in fails without mutating
        let mut src = tiered(8);
        let prompt: Vec<u32> = (0..12).map(|i| 70 + i).collect();
        src.prefill(1, &prompt, &COOPT).unwrap();
        let out = src.migrate_out(1).unwrap();
        let mut dst = CacheManager::new(CacheGeometry {
            block_size: 4,
            max_blocks: 8,
            num_pool_blocks: 2,
            max_batch: 4,
            max_seq: 16,
        });
        assert!(dst
            .migrate_in(1, &out.hashes, out.resume_len, out.min_blocks)
            .is_err());
        assert_eq!(dst.stats().blocks_used, 0, "failed migrate-in allocates nothing");
        assert!(!dst.has_seq(1));
    }
}
