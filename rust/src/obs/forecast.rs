//! Predictive telemetry plane: a bounded time-series ring of cluster
//! signals plus three *self-scoring* online estimators that turn the
//! reactive controllers (router admission, cost estimate, preemption
//! victim choice, proactive eviction, spec cold-start) into predictive
//! ones — without ever being trusted blindly.
//!
//! The contract, mirroring PR 7's exact-attribution discipline: **every
//! prediction is scored against its own outcome**.  A prediction is
//! stamped at decision time, resolved when the request finishes (or the
//! burst horizon elapses), and folded into calibration metrics — mean
//! absolute percentage error and quantile *coverage* ("did 90% of
//! actuals land under the p90?").  Controllers consume a forecast only
//! while its coverage sits inside the configured band; out-of-band (or
//! still warming up) they fall back to today's reactive behaviour, so a
//! miscalibrated estimator degrades to the status quo, never below it.
//!
//! Three estimators ride on the ring:
//!
//! 1. **Output length** ([`LenEstimator`], per tenant): exact sliding-
//!    window quantiles over finished-request generated-token counts.
//!    The p90 replaces the router's blind `5 x max_new` decode term in
//!    `request_cost_estimate`, and `p90 - generated` ranks preemption
//!    victims (evict the lane furthest from finishing).
//! 2. **Arrival bursts** ([`BurstDetector`]): a short-vs-long-window
//!    arrival-rate ratio on the step clock.  While a detected burst is
//!    in calibration band, admission pre-tightens (queue bound divided
//!    by `burst_tighten`, projected wait multiplied by it) and the
//!    engine raises its proactive-eviction watermark to clear device
//!    headroom *ahead* of the burst.  Each detection is scored at a
//!    fixed horizon: a hit iff the arrival rate stayed at or above the
//!    detection-time baseline — a control-independent criterion, so the
//!    detector cannot mark itself wrong merely because tightening
//!    worked.
//! 3. **Queue wait** ([`WaitForecaster`]): an EWMA of observed
//!    `queue_wait_ms / load_score` replacing the `SLO_MS_PER_TOKEN`
//!    drain constant in `projected_wait_ms`.  Covered iff the actual
//!    wait landed under `2 x predicted + 1 ms` — the forecast may be
//!    loose upward (admission stays safe) but not a gross underestimate.
//!
//! Everything here is deterministic on the step clock except the wait
//! forecaster's wall-millisecond samples, and nothing in this module
//! touches token generation: forecasts change *who goes where and
//! when*, never what anyone gets back (`prop_forecast` poisons every
//! estimator on purpose and proves it).

use std::collections::{BTreeMap, VecDeque};

use crate::config::ForecastConfig;
use crate::util::json::{Object, Value};

/// Short arrival window (steps) for the burst ratio numerator.
pub const SHORT_WINDOW: usize = 8;
/// Long arrival window (steps) for the burst ratio baseline.
pub const LONG_WINDOW: usize = 64;
/// Steps after a burst detection at which it is scored.
pub const BURST_HORIZON: u64 = 16;
/// A burst needs at least this many arrivals in the short window —
/// one lone request after silence is noise, not a burst.
pub const MIN_BURST_ARRIVALS: u64 = 4;
/// Sliding window of actual output lengths per tenant.
pub const LEN_WINDOW: usize = 128;
/// Quantiles are withheld until this many lengths have been observed.
pub const MIN_LEN_SAMPLES: usize = 4;
/// Coverage is judged over the most recent outcomes only, so a long-
/// dead miscalibration cannot pin an estimator out of band forever.
pub const COVERAGE_WINDOW: usize = 64;
/// A wait prediction covers its outcome iff
/// `actual <= WAIT_COVER_FACTOR * predicted + WAIT_COVER_SLACK_MS`.
pub const WAIT_COVER_FACTOR: f64 = 2.0;
pub const WAIT_COVER_SLACK_MS: f64 = 1.0;
/// Distinct per-tenant estimators; overflow tenants share the
/// untenanted bucket instead of growing the maps without bound.
pub const MAX_TENANTS: usize = 64;

fn push_bounded<T>(q: &mut VecDeque<T>, v: T, cap: usize) {
    if q.len() >= cap.max(1) {
        q.pop_front();
    }
    q.push_back(v);
}

fn window_rate(q: &VecDeque<bool>) -> Option<f64> {
    if q.is_empty() {
        return None;
    }
    Some(q.iter().filter(|&&b| b).count() as f64 / q.len() as f64)
}

// ---------------------------------------------------------------------------
// signal ring
// ---------------------------------------------------------------------------

/// One step-boundary sample of the signals every controller feeds on.
#[derive(Debug, Clone, Copy, Default)]
pub struct SignalSample {
    /// Step-clock sequence number of this sample.
    pub seq: u64,
    /// Requests queued (admitted, not yet running).
    pub queue_depth: usize,
    /// Sequences actively prefilling or decoding.
    pub running: usize,
    /// Prompt tokens committed so far (run-cumulative).
    pub prefill_tokens: u64,
    /// Decode tokens committed so far (run-cumulative).
    pub decode_tokens: u64,
    /// Free device KV blocks at the sample instant.
    pub free_device_blocks: usize,
    /// Requests that arrived since the previous sample.
    pub arrivals: u64,
}

impl SignalSample {
    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("seq", self.seq as usize);
        o.insert("queue_depth", self.queue_depth);
        o.insert("running", self.running);
        o.insert("prefill_tokens", self.prefill_tokens as usize);
        o.insert("decode_tokens", self.decode_tokens as usize);
        o.insert("free_device_blocks", self.free_device_blocks);
        o.insert("arrivals", self.arrivals as usize);
        Value::Object(o)
    }
}

/// Bounded ring of [`SignalSample`]s — the raw material behind
/// `GET /admin/forecast` and any future offline estimator.
#[derive(Debug, Clone)]
pub struct SignalRing {
    cap: usize,
    samples: VecDeque<SignalSample>,
}

impl SignalRing {
    pub fn new(cap: usize) -> Self {
        SignalRing {
            cap: cap.max(1),
            samples: VecDeque::new(),
        }
    }

    pub fn push(&mut self, s: SignalSample) {
        push_bounded(&mut self.samples, s, self.cap);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn latest(&self) -> Option<&SignalSample> {
        self.samples.back()
    }

    pub fn iter(&self) -> impl Iterator<Item = &SignalSample> {
        self.samples.iter()
    }

    pub fn to_json(&self) -> Value {
        Value::Array(self.samples.iter().map(|s| s.to_json()).collect())
    }
}

// ---------------------------------------------------------------------------
// output-length estimator
// ---------------------------------------------------------------------------

/// Per-tenant output-length quantile estimator: exact quantiles over a
/// sliding window of observed generated-token counts, scored by p90
/// coverage and p50 MAPE over its own resolved predictions.
#[derive(Debug, Clone, Default)]
pub struct LenEstimator {
    window: VecDeque<u32>,
    resolved: u64,
    cover: VecDeque<bool>,
    mape: f64,
    mape_n: u64,
}

impl LenEstimator {
    /// Exact `q`-quantile (q in [0, 1]) of the window via ceil-rank:
    /// the smallest observed value with at least a `q` fraction of the
    /// window at or below it.  `None` until [`MIN_LEN_SAMPLES`] lengths
    /// have been seen — a guess from one sample is not a forecast.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let n = self.window.len();
        if n < MIN_LEN_SAMPLES {
            return None;
        }
        let mut v: Vec<u32> = self.window.iter().copied().collect();
        v.sort_unstable();
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as usize).clamp(1, n);
        Some(v[rank - 1] as f64)
    }

    /// Feed an observed length without scoring (no prediction was
    /// stamped — the estimator was still warming up at admission).
    pub fn observe(&mut self, actual: u32) {
        push_bounded(&mut self.window, actual, LEN_WINDOW);
    }

    /// Score a stamped prediction against its outcome, then feed the
    /// outcome into the window.  Coverage bit: `actual <= p90`.
    pub fn resolve(&mut self, p50: f64, p90: f64, actual: u32, alpha: f64) {
        self.resolved += 1;
        push_bounded(&mut self.cover, f64::from(actual) <= p90, COVERAGE_WINDOW);
        let err = (f64::from(actual) - p50).abs() / f64::from(actual).max(1.0);
        self.mape_n += 1;
        self.mape = if self.mape_n == 1 {
            err
        } else {
            (1.0 - alpha) * self.mape + alpha * err
        };
        self.observe(actual);
    }

    pub fn samples(&self) -> usize {
        self.window.len()
    }

    pub fn resolved(&self) -> u64 {
        self.resolved
    }

    /// Fraction of recent resolved predictions whose actual landed at
    /// or under the stamped p90.  `None` before the first resolution.
    pub fn coverage(&self) -> Option<f64> {
        window_rate(&self.cover)
    }

    /// EWMA of `|actual - p50| / actual` over resolved predictions.
    pub fn mape(&self) -> f64 {
        self.mape
    }

    /// Consumable iff enough predictions have resolved *and* the p90
    /// coverage sits inside `[lo, hi]`.
    pub fn in_band(&self, warmup: u64, lo: f64, hi: f64) -> bool {
        if self.resolved < warmup.max(1) {
            return false;
        }
        match self.coverage() {
            Some(c) => c >= lo && c <= hi,
            None => false,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("samples", self.samples());
        o.insert("resolved", self.resolved as usize);
        if let Some(p50) = self.quantile(0.5) {
            o.insert("p50", p50);
        }
        if let Some(p90) = self.quantile(0.9) {
            o.insert("p90", p90);
        }
        if let Some(c) = self.coverage() {
            o.insert("coverage", c);
        }
        if self.mape_n > 0 {
            o.insert("mape", self.mape);
        }
        Value::Object(o)
    }
}

// ---------------------------------------------------------------------------
// arrival-burst detector
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
struct PendingBurst {
    resolve_at: u64,
    cum_at_fire: u64,
    baseline_rate: f64,
}

/// Arrival-burst detector on the step clock: burst iff the short-window
/// arrival rate is at least `burst_ratio` times the long-window rate
/// (with a minimum absolute arrival count, so one request after silence
/// does not trip it).  Because the long window contains the short one,
/// a sustained burst raises its own baseline and self-expires — the
/// detector flags *onsets*, which is exactly when pre-tightening and
/// pre-eviction pay.
#[derive(Debug, Clone, Default)]
pub struct BurstDetector {
    per_step: VecDeque<u64>,
    cum_arrivals: u64,
    active: bool,
    detected: u64,
    pending: VecDeque<PendingBurst>,
    resolved: u64,
    hits: u64,
}

impl BurstDetector {
    /// Advance one step with `arrivals` new requests, re-evaluate the
    /// burst predicate, and score any detections whose horizon elapsed.
    pub fn tick(&mut self, step: u64, arrivals: u64, ratio: f64) {
        self.cum_arrivals += arrivals;
        push_bounded(&mut self.per_step, arrivals, LONG_WINDOW);
        let n = self.per_step.len();
        let short_n: u64 = self
            .per_step
            .iter()
            .rev()
            .take(SHORT_WINDOW)
            .sum();
        let long_n: u64 = self.per_step.iter().sum();
        let short_rate = short_n as f64 / n.min(SHORT_WINDOW) as f64;
        let long_rate = long_n as f64 / n as f64;
        let burst = n >= SHORT_WINDOW
            && short_n >= MIN_BURST_ARRIVALS
            && long_rate > 0.0
            && short_rate >= ratio * long_rate;
        if burst && !self.active {
            self.detected += 1;
            self.pending.push_back(PendingBurst {
                resolve_at: step + BURST_HORIZON,
                cum_at_fire: self.cum_arrivals,
                baseline_rate: long_rate,
            });
        }
        self.active = burst;
        while let Some(p) = self.pending.front().copied() {
            if p.resolve_at > step {
                break;
            }
            self.pending.pop_front();
            self.resolved += 1;
            let horizon_rate =
                (self.cum_arrivals - p.cum_at_fire) as f64 / BURST_HORIZON as f64;
            if horizon_rate >= p.baseline_rate {
                self.hits += 1;
            }
        }
    }

    pub fn active(&self) -> bool {
        self.active
    }

    pub fn detected(&self) -> u64 {
        self.detected
    }

    pub fn resolved(&self) -> u64 {
        self.resolved
    }

    /// Fraction of resolved detections where the elevated rate held
    /// through the horizon.  `None` before the first resolution.
    pub fn hit_rate(&self) -> Option<f64> {
        if self.resolved == 0 {
            return None;
        }
        Some(self.hits as f64 / self.resolved as f64)
    }

    /// Consumable iff at least two detections have been scored and the
    /// majority were real.
    pub fn in_band(&self) -> bool {
        self.resolved >= 2 && self.hit_rate().unwrap_or(0.0) >= 0.5
    }

    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("active", self.active);
        o.insert("detected", self.detected as usize);
        o.insert("resolved", self.resolved as usize);
        if let Some(h) = self.hit_rate() {
            o.insert("hit_rate", h);
        }
        o.insert("in_band", self.in_band());
        Value::Object(o)
    }
}

// ---------------------------------------------------------------------------
// queue-wait forecaster
// ---------------------------------------------------------------------------

/// Queue-wait forecaster: learns the cluster's real drain rate as an
/// EWMA of `observed_queue_wait_ms / load_score_at_admission`, replacing
/// the hardwired `SLO_MS_PER_TOKEN` constant in `projected_wait_ms`.
#[derive(Debug, Clone, Default)]
pub struct WaitForecaster {
    ms_per_load: f64,
    samples: u64,
    resolved: u64,
    cover: VecDeque<bool>,
}

impl WaitForecaster {
    /// Predicted queue wait for a request admitted at `load`.  `None`
    /// until at least one outcome has been folded in.
    pub fn predict_ms(&self, load: f64) -> Option<f64> {
        if self.samples == 0 {
            return None;
        }
        Some(self.ms_per_load * load.max(0.0))
    }

    /// Learned drain rate (milliseconds of queue wait per unit of load
    /// score); `None` until the first sample.
    pub fn ms_per_load(&self) -> Option<f64> {
        if self.samples == 0 {
            return None;
        }
        Some(self.ms_per_load)
    }

    /// Score a stamped prediction and fold the outcome into the EWMA.
    pub fn resolve(&mut self, predicted_ms: f64, load: f64, actual_ms: f64, alpha: f64) {
        self.resolved += 1;
        push_bounded(
            &mut self.cover,
            actual_ms <= WAIT_COVER_FACTOR * predicted_ms + WAIT_COVER_SLACK_MS,
            COVERAGE_WINDOW,
        );
        if load > 0.0 {
            let sample = actual_ms / load;
            self.samples += 1;
            self.ms_per_load = if self.samples == 1 {
                sample
            } else {
                (1.0 - alpha) * self.ms_per_load + alpha * sample
            };
        }
    }

    pub fn resolved(&self) -> u64 {
        self.resolved
    }

    pub fn coverage(&self) -> Option<f64> {
        window_rate(&self.cover)
    }

    pub fn in_band(&self, warmup: u64, lo: f64, hi: f64) -> bool {
        if self.resolved < warmup.max(1) || self.samples == 0 {
            return false;
        }
        match self.coverage() {
            Some(c) => c >= lo && c <= hi,
            None => false,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("resolved", self.resolved as usize);
        if let Some(m) = self.ms_per_load() {
            o.insert("ms_per_load", m);
        }
        if let Some(c) = self.coverage() {
            o.insert("coverage", c);
        }
        Value::Object(o)
    }
}

// ---------------------------------------------------------------------------
// prediction stamp
// ---------------------------------------------------------------------------

/// The predictions in force for one request at admission, stamped onto
/// its `ReqTrace` and resolved at finish.  Absent fields mean the
/// corresponding estimator was still warming up.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ForecastStamp {
    pub len_p50: Option<f64>,
    pub len_p90: Option<f64>,
    pub wait_ms: Option<f64>,
}

impl ForecastStamp {
    pub fn is_empty(&self) -> bool {
        self.len_p50.is_none() && self.len_p90.is_none() && self.wait_ms.is_none()
    }
}

// ---------------------------------------------------------------------------
// the plane
// ---------------------------------------------------------------------------

/// The composed predictive plane: signal ring + the three estimators +
/// per-tenant speculation-acceptance memory.  One instance lives in the
/// router (arrivals, wait, admission tightening) and one per engine
/// (step-boundary signals, length stamps, victim hints, eviction,
/// spec-prior seeding).  All methods are no-ops / `None` when the
/// config is disabled, so the default path is bit-identical to the
/// pre-forecast code.
#[derive(Debug, Clone)]
pub struct ForecastPlane {
    cfg: ForecastConfig,
    step: u64,
    ring: SignalRing,
    arrivals_this_step: u64,
    tenant_arrivals: BTreeMap<String, u64>,
    len: BTreeMap<String, LenEstimator>,
    burst: BurstDetector,
    wait: WaitForecaster,
    acceptance: BTreeMap<String, f64>,
}

impl ForecastPlane {
    pub fn new(cfg: ForecastConfig) -> Self {
        let ring = SignalRing::new(cfg.ring);
        ForecastPlane {
            cfg,
            step: 0,
            ring,
            arrivals_this_step: 0,
            tenant_arrivals: BTreeMap::new(),
            len: BTreeMap::new(),
            burst: BurstDetector::default(),
            wait: WaitForecaster::default(),
            acceptance: BTreeMap::new(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    pub fn cfg(&self) -> &ForecastConfig {
        &self.cfg
    }

    pub fn current_step(&self) -> u64 {
        self.step
    }

    pub fn ring(&self) -> &SignalRing {
        &self.ring
    }

    /// Tenants overflowing [`MAX_TENANTS`] share the untenanted bucket.
    fn tenant_key(&self, tenant: Option<&str>) -> String {
        let t = tenant.unwrap_or("");
        if self.len.contains_key(t) || self.len.len() < MAX_TENANTS {
            t.to_string()
        } else {
            String::new()
        }
    }

    /// Record one request arrival (router `submit` / engine
    /// `submit_tokens_class`), attributed to its tenant.
    pub fn observe_arrival(&mut self, tenant: Option<&str>) {
        if !self.cfg.enabled {
            return;
        }
        self.arrivals_this_step += 1;
        let key = self.tenant_key(tenant);
        *self.tenant_arrivals.entry(key).or_insert(0) += 1;
    }

    /// Advance the step clock: sample the signal ring and feed the
    /// burst detector with the arrivals accumulated since last tick.
    pub fn tick(
        &mut self,
        queue_depth: usize,
        running: usize,
        prefill_tokens: u64,
        decode_tokens: u64,
        free_device_blocks: usize,
    ) {
        if !self.cfg.enabled {
            return;
        }
        self.step += 1;
        let arrivals = std::mem::take(&mut self.arrivals_this_step);
        self.ring.push(SignalSample {
            seq: self.step,
            queue_depth,
            running,
            prefill_tokens,
            decode_tokens,
            free_device_blocks,
            arrivals,
        });
        self.burst.tick(self.step, arrivals, self.cfg.burst_ratio);
    }

    // ---- output length ---------------------------------------------------

    /// Raw (p50, p90) for stamping — available as soon as the window
    /// has [`MIN_LEN_SAMPLES`], *regardless* of calibration band:
    /// predictions must keep being stamped and scored even while they
    /// are not consumed, or coverage could never recover.
    pub fn len_quantiles(&self, tenant: Option<&str>) -> Option<(f64, f64)> {
        if !self.cfg.enabled {
            return None;
        }
        let est = self.len.get(&self.tenant_key(tenant))?;
        Some((est.quantile(0.5)?, est.quantile(0.9)?))
    }

    /// p90 length hint for controllers — `None` unless the tenant's
    /// estimator is warmed up *and* its coverage is in band.
    pub fn len_hint_p90(&self, tenant: Option<&str>) -> Option<f64> {
        if !self.cfg.enabled || !self.len_in_band(tenant) {
            return None;
        }
        self.len_quantiles(tenant).map(|(_, p90)| p90)
    }

    pub fn len_in_band(&self, tenant: Option<&str>) -> bool {
        if !self.cfg.enabled {
            return false;
        }
        match self.len.get(&self.tenant_key(tenant)) {
            Some(est) => {
                est.in_band(self.cfg.warmup, self.cfg.coverage_lo, self.cfg.coverage_hi)
            }
            None => false,
        }
    }

    /// Feed an observed length with no stamped prediction (warm-up
    /// finishes still teach the window).
    pub fn observe_len(&mut self, tenant: Option<&str>, actual: u32) {
        if !self.cfg.enabled {
            return;
        }
        let key = self.tenant_key(tenant);
        self.len.entry(key).or_default().observe(actual);
    }

    /// Score a stamped (p50, p90) length prediction against the actual
    /// generated-token count.
    pub fn resolve_len(&mut self, tenant: Option<&str>, p50: f64, p90: f64, actual: u32) {
        if !self.cfg.enabled {
            return;
        }
        let key = self.tenant_key(tenant);
        let alpha = self.cfg.ewma_alpha;
        self.len.entry(key).or_default().resolve(p50, p90, actual, alpha);
    }

    /// Pooled p90 coverage across tenants whose estimators are past
    /// warm-up — the single number the bench gate checks.
    pub fn len_coverage_pooled(&self) -> Option<f64> {
        let mut covered = 0usize;
        let mut total = 0usize;
        for est in self.len.values() {
            if est.resolved() < self.cfg.warmup.max(1) {
                continue;
            }
            total += est.cover.len();
            covered += est.cover.iter().filter(|&&b| b).count();
        }
        if total == 0 {
            return None;
        }
        Some(covered as f64 / total as f64)
    }

    // ---- queue wait ------------------------------------------------------

    /// Forecast queue wait at `load` — `None` unless the forecaster is
    /// warmed up and in coverage band (callers fall back to the
    /// reactive drain constant).
    pub fn predict_wait_ms(&self, load: f64) -> Option<f64> {
        if !self.cfg.enabled || !self.wait_in_band() {
            return None;
        }
        self.wait.predict_ms(load)
    }

    pub fn wait_in_band(&self) -> bool {
        self.cfg.enabled
            && self
                .wait
                .in_band(self.cfg.warmup, self.cfg.coverage_lo, self.cfg.coverage_hi)
    }

    /// Raw wait quote for *stamping* — available from the first resolved
    /// sample regardless of calibration band (predictions must keep
    /// being scored while out of band, or coverage could never recover).
    pub fn wait_quote_ms(&self, load: f64) -> Option<f64> {
        if !self.cfg.enabled {
            return None;
        }
        self.wait.predict_ms(load)
    }

    /// Learned drain rate (ms of queue wait per unit of load score) for
    /// `projected_wait_ms` — `None` unless in band, so callers fall back
    /// to the reactive `SLO_MS_PER_TOKEN` constant.
    pub fn wait_ms_per_load(&self) -> Option<f64> {
        if !self.wait_in_band() {
            return None;
        }
        self.wait.ms_per_load()
    }

    /// Score the wait prediction that admission actually used.
    pub fn resolve_wait(&mut self, predicted_ms: f64, load: f64, actual_ms: f64) {
        if !self.cfg.enabled {
            return;
        }
        let alpha = self.cfg.ewma_alpha;
        self.wait.resolve(predicted_ms, load, actual_ms, alpha);
    }

    pub fn wait_coverage(&self) -> Option<f64> {
        self.wait.coverage()
    }

    /// Wait predictions scored so far (stamp-and-resolve round trips).
    pub fn wait_resolved(&self) -> u64 {
        self.wait.resolved()
    }

    // ---- bursts ----------------------------------------------------------

    pub fn burst_active(&self) -> bool {
        self.cfg.enabled && self.burst.active()
    }

    pub fn burst_in_band(&self) -> bool {
        self.cfg.enabled && self.burst.in_band()
    }

    /// Burst onsets the detector has fired on so far.
    pub fn bursts_detected(&self) -> u64 {
        self.burst.detected()
    }

    /// Burst detections scored against their post-horizon arrival rate.
    pub fn bursts_resolved(&self) -> u64 {
        self.burst.resolved()
    }

    /// Fraction of resolved detections that held through the horizon.
    pub fn burst_hit_rate(&self) -> Option<f64> {
        self.burst.hit_rate()
    }

    /// Admission tightening factor: `burst_tighten` while a burst is
    /// active *and* the detector is in band, else 1.0 (reactive).
    pub fn admission_tighten(&self) -> f64 {
        if self.burst_active() && self.burst_in_band() {
            self.cfg.burst_tighten.max(1.0)
        } else {
            1.0
        }
    }

    /// Effective proactive-eviction watermark: raised to
    /// `burst_watermark` while a consumable burst is in flight.
    pub fn effective_watermark(&self, configured: usize) -> usize {
        if self.burst_active() && self.burst_in_band() {
            configured.max(self.cfg.burst_watermark)
        } else {
            configured
        }
    }

    // ---- speculation acceptance -----------------------------------------

    /// Fold a finished lane's observed acceptance rate into the
    /// tenant's EWMA (the spec controller's cold-start prior source).
    pub fn observe_acceptance(&mut self, tenant: Option<&str>, rate: f64) {
        if !self.cfg.enabled || !rate.is_finite() {
            return;
        }
        let key = self.tenant_key(tenant);
        let alpha = self.cfg.ewma_alpha;
        let rate = rate.clamp(0.0, 1.0);
        self.acceptance
            .entry(key)
            .and_modify(|a| *a = (1.0 - alpha) * *a + alpha * rate)
            .or_insert(rate);
    }

    /// Observed acceptance EWMA for a tenant, if any lane of that
    /// tenant has finished — seeds new lanes' spec priors.
    pub fn tenant_acceptance(&self, tenant: Option<&str>) -> Option<f64> {
        if !self.cfg.enabled {
            return None;
        }
        self.acceptance.get(&self.tenant_key(tenant)).copied()
    }

    // ---- exposition ------------------------------------------------------

    /// Full estimator + ring dump (the `GET /admin/forecast` payload).
    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("enabled", self.cfg.enabled);
        o.insert("step", self.step as usize);
        o.insert("burst", self.burst.to_json());
        o.insert("wait", self.wait.to_json());
        let mut len = Object::new();
        for (t, est) in &self.len {
            let key = if t.is_empty() { "default" } else { t.as_str() };
            len.insert(key, est.to_json());
        }
        o.insert("len", len);
        let mut acc = Object::new();
        for (t, a) in &self.acceptance {
            let key = if t.is_empty() { "default" } else { t.as_str() };
            acc.insert(key, *a);
        }
        o.insert("acceptance", acc);
        let mut arr = Object::new();
        for (t, n) in &self.tenant_arrivals {
            let key = if t.is_empty() { "default" } else { t.as_str() };
            arr.insert(key, *n as usize);
        }
        o.insert("tenant_arrivals", arr);
        o.insert("ring", self.ring.to_json());
        Value::Object(o)
    }

    /// Flat calibration gauges for `/metrics`: scalars plus one-level
    /// per-tenant numeric maps, which `prometheus_text` renders as
    /// labeled `llm_coopt_forecast_*` gauges for free.
    pub fn metrics_json(&self, o: &mut Object) {
        if !self.cfg.enabled {
            return;
        }
        o.insert("forecast_step", self.step as usize);
        o.insert("forecast_burst_active", usize::from(self.burst.active()));
        o.insert("forecast_bursts_detected", self.burst.detected() as usize);
        o.insert("forecast_bursts_resolved", self.burst.resolved() as usize);
        if let Some(h) = self.burst.hit_rate() {
            o.insert("forecast_burst_hit_rate", h);
        }
        o.insert("forecast_wait_resolved", self.wait.resolved() as usize);
        if let Some(m) = self.wait.ms_per_load() {
            o.insert("forecast_wait_ms_per_load", m);
        }
        if let Some(c) = self.wait.coverage() {
            o.insert("forecast_wait_coverage", c);
        }
        if let Some(c) = self.len_coverage_pooled() {
            o.insert("forecast_len_coverage_pooled", c);
        }
        let mut p90s = Object::new();
        let mut coverage = Object::new();
        let mut mape = Object::new();
        let mut resolved = Object::new();
        for (t, est) in &self.len {
            let key = if t.is_empty() { "default" } else { t.as_str() };
            if let Some(p90) = est.quantile(0.9) {
                p90s.insert(key, p90);
            }
            if let Some(c) = est.coverage() {
                coverage.insert(key, c);
            }
            if est.mape_n > 0 {
                mape.insert(key, est.mape());
            }
            resolved.insert(key, est.resolved() as usize);
        }
        if !p90s.is_empty() {
            o.insert("forecast_len_p90", p90s);
        }
        if !coverage.is_empty() {
            o.insert("forecast_len_coverage", coverage);
        }
        if !mape.is_empty() {
            o.insert("forecast_len_mape", mape);
        }
        if !resolved.is_empty() {
            o.insert("forecast_len_resolved", resolved);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_on() -> ForecastConfig {
        ForecastConfig {
            enabled: true,
            ..ForecastConfig::default()
        }
    }

    #[test]
    fn ring_is_bounded_and_ordered() {
        let mut r = SignalRing::new(4);
        for i in 0..10u64 {
            r.push(SignalSample {
                seq: i,
                ..SignalSample::default()
            });
        }
        assert_eq!(r.len(), 4);
        let seqs: Vec<u64> = r.iter().map(|s| s.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        assert_eq!(r.latest().unwrap().seq, 9);
    }

    #[test]
    fn len_quantiles_are_exact_ceil_rank() {
        let mut e = LenEstimator::default();
        for x in [10u32, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            e.observe(x);
        }
        // ceil-rank over n=10: p50 -> rank 5 -> 50; p90 -> rank 9 -> 90
        assert_eq!(e.quantile(0.5), Some(50.0));
        assert_eq!(e.quantile(0.9), Some(90.0));
        assert_eq!(e.quantile(1.0), Some(100.0));
        assert_eq!(e.quantile(0.0), Some(10.0)); // rank clamps to 1
    }

    #[test]
    fn len_estimator_withholds_until_min_samples() {
        let mut e = LenEstimator::default();
        for x in 0..MIN_LEN_SAMPLES as u32 - 1 {
            e.observe(x + 1);
            assert_eq!(e.quantile(0.9), None);
        }
        e.observe(99);
        assert!(e.quantile(0.9).is_some());
    }

    #[test]
    fn len_coverage_flips_in_band_and_back() {
        let mut e = LenEstimator::default();
        // perfectly covered predictions -> in band once past warm-up
        for _ in 0..8 {
            e.resolve(10.0, 20.0, 12, 0.2);
        }
        assert!(e.in_band(8, 0.8, 1.0));
        assert_eq!(e.coverage(), Some(1.0));
        // a run of busted p90s drags recent coverage out of band
        for _ in 0..COVERAGE_WINDOW {
            e.resolve(10.0, 20.0, 50, 0.2);
        }
        assert_eq!(e.coverage(), Some(0.0));
        assert!(!e.in_band(8, 0.8, 1.0));
    }

    #[test]
    fn burst_detector_fires_on_onset_and_scores_itself() {
        let mut b = BurstDetector::default();
        let mut step = 0u64;
        // long quiet baseline: one arrival every 4 steps
        for _ in 0..LONG_WINDOW {
            step += 1;
            b.tick(step, u64::from(step % 4 == 0), 2.0);
        }
        assert!(!b.active(), "steady trickle is not a burst");
        // onset: 3 arrivals per step
        let mut fired = false;
        for _ in 0..SHORT_WINDOW {
            step += 1;
            b.tick(step, 3, 2.0);
            fired |= b.active();
        }
        assert!(fired, "8 steps of 3x rate must trip the detector");
        assert_eq!(b.detected(), 1, "one onset, one detection");
        // burst persists through the horizon -> scored as a hit
        for _ in 0..BURST_HORIZON + 1 {
            step += 1;
            b.tick(step, 3, 2.0);
        }
        assert_eq!(b.resolved(), 1);
        assert_eq!(b.hit_rate(), Some(1.0));
    }

    #[test]
    fn burst_that_vanishes_scores_a_miss() {
        let mut b = BurstDetector::default();
        let mut step = 0u64;
        for _ in 0..LONG_WINDOW {
            step += 1;
            b.tick(step, u64::from(step % 2 == 0), 2.0);
        }
        // a one-step spike big enough to trip the ratio...
        step += 1;
        b.tick(step, 12, 2.0);
        assert_eq!(b.detected(), 1);
        // ...then dead silence through the horizon: rate < baseline
        for _ in 0..BURST_HORIZON + 1 {
            step += 1;
            b.tick(step, 0, 2.0);
        }
        assert_eq!(b.resolved(), 1);
        assert_eq!(b.hit_rate(), Some(0.0));
        assert!(!b.in_band());
    }

    #[test]
    fn wait_forecaster_learns_drain_and_covers() {
        let mut w = WaitForecaster::default();
        assert_eq!(w.predict_ms(10.0), None);
        for _ in 0..10 {
            w.resolve(100.0, 10.0, 50.0, 0.5);
        }
        // EWMA converges toward 5 ms per unit load
        let m = w.ms_per_load().unwrap();
        assert!((m - 5.0).abs() < 1e-6, "ms_per_load {m}");
        assert_eq!(w.predict_ms(4.0), Some(m * 4.0));
        // 50 <= 2*100 + 1: every prediction covered
        assert_eq!(w.coverage(), Some(1.0));
        assert!(w.in_band(8, 0.8, 1.0));
        // gross underestimates (actual >> 2x predicted) break the band
        for _ in 0..COVERAGE_WINDOW {
            w.resolve(1.0, 10.0, 1000.0, 0.5);
        }
        assert!(!w.in_band(8, 0.8, 1.0));
    }

    #[test]
    fn disabled_plane_is_inert() {
        let mut p = ForecastPlane::new(ForecastConfig::default());
        assert!(!p.enabled());
        p.observe_arrival(Some("t0"));
        p.tick(5, 5, 100, 100, 8);
        p.observe_len(Some("t0"), 20);
        p.resolve_len(Some("t0"), 10.0, 20.0, 20);
        p.resolve_wait(10.0, 5.0, 10.0);
        p.observe_acceptance(Some("t0"), 0.9);
        assert_eq!(p.current_step(), 0);
        assert!(p.ring().is_empty());
        assert_eq!(p.len_quantiles(Some("t0")), None);
        assert_eq!(p.predict_wait_ms(10.0), None);
        assert_eq!(p.admission_tighten(), 1.0);
        assert_eq!(p.tenant_acceptance(Some("t0")), None);
        let mut o = Object::new();
        p.metrics_json(&mut o);
        assert!(o.is_empty(), "disabled plane adds no metrics keys");
    }

    #[test]
    fn plane_gates_len_hint_on_coverage_band() {
        let mut p = ForecastPlane::new(ForecastConfig {
            enabled: true,
            warmup: 4,
            ..ForecastConfig::default()
        });
        // warm-up: raw quantiles appear, hint stays withheld
        for _ in 0..MIN_LEN_SAMPLES {
            p.observe_len(Some("t0"), 16);
        }
        assert_eq!(p.len_quantiles(Some("t0")), Some((16.0, 16.0)));
        assert_eq!(p.len_hint_p90(Some("t0")), None, "no resolutions yet");
        // resolve enough covered predictions to enter the band
        for _ in 0..4 {
            p.resolve_len(Some("t0"), 16.0, 16.0, 16);
        }
        assert!(p.len_in_band(Some("t0")));
        assert_eq!(p.len_hint_p90(Some("t0")), Some(16.0));
        // poison: actuals blow past the p90 until coverage leaves band
        for _ in 0..COVERAGE_WINDOW {
            p.resolve_len(Some("t0"), 16.0, 16.0, 64);
        }
        assert!(!p.len_in_band(Some("t0")));
        assert_eq!(p.len_hint_p90(Some("t0")), None, "out of band -> reactive");
    }

    #[test]
    fn plane_tightens_only_with_scored_bursts() {
        let mut p = ForecastPlane::new(ForecastConfig {
            enabled: true,
            burst_ratio: 2.0,
            burst_tighten: 1.5,
            ..ForecastConfig::default()
        });
        // quiet baseline
        for s in 0..LONG_WINDOW as u64 {
            if s % 4 == 0 {
                p.observe_arrival(None);
            }
            p.tick(0, 0, 0, 0, 8);
        }
        // first burst: active, but unscored -> no tightening yet
        for _ in 0..SHORT_WINDOW {
            for _ in 0..3 {
                p.observe_arrival(None);
            }
            p.tick(0, 0, 0, 0, 8);
        }
        assert!(p.burst_active());
        assert!(!p.burst_in_band());
        assert_eq!(p.admission_tighten(), 1.0);
        assert_eq!(p.effective_watermark(0), 0);
        // let two bursts score as hits (sustained rate), separated by
        // enough quiet to re-arm the onset edge
        for round in 0..2 {
            for _ in 0..BURST_HORIZON + 2 {
                for _ in 0..3 {
                    p.observe_arrival(None);
                }
                p.tick(0, 0, 0, 0, 8);
            }
            if round == 0 {
                for _ in 0..LONG_WINDOW {
                    p.tick(0, 0, 0, 0, 8);
                }
                for _ in 0..SHORT_WINDOW {
                    for _ in 0..3 {
                        p.observe_arrival(None);
                    }
                    p.tick(0, 0, 0, 0, 8);
                }
            }
        }
        assert!(p.burst_in_band(), "two sustained bursts score as hits");
        assert!(p.burst_active());
        assert_eq!(p.admission_tighten(), 1.5);
        assert_eq!(p.effective_watermark(0), p.cfg().burst_watermark);
        assert_eq!(p.effective_watermark(9), 9, "never lowers a higher watermark");
    }

    #[test]
    fn acceptance_memory_is_per_tenant_ewma() {
        let mut p = ForecastPlane::new(cfg_on());
        p.observe_acceptance(Some("a"), 0.8);
        p.observe_acceptance(Some("b"), 0.2);
        assert_eq!(p.tenant_acceptance(Some("a")), Some(0.8));
        assert_eq!(p.tenant_acceptance(Some("b")), Some(0.2));
        assert_eq!(p.tenant_acceptance(None), None);
        p.observe_acceptance(Some("a"), 0.0);
        let a = p.tenant_acceptance(Some("a")).unwrap();
        assert!(a < 0.8 && a > 0.0, "EWMA moved toward the new sample: {a}");
    }

    #[test]
    fn tenant_overflow_folds_into_default_bucket() {
        let mut p = ForecastPlane::new(cfg_on());
        for i in 0..MAX_TENANTS + 10 {
            p.observe_len(Some(&format!("t{i}")), 8);
        }
        // the 10 overflow tenants all landed in "" — which therefore
        // has enough samples to answer, while t-many never existed
        assert!(p.len_quantiles(None).is_some());
        assert_eq!(
            p.len_quantiles(Some(&format!("t{}", MAX_TENANTS + 5))),
            p.len_quantiles(None),
            "overflow tenants read the shared bucket"
        );
    }

    #[test]
    fn metrics_and_admin_json_expose_calibration() {
        let mut p = ForecastPlane::new(ForecastConfig {
            enabled: true,
            warmup: 2,
            ..ForecastConfig::default()
        });
        for _ in 0..4 {
            p.resolve_len(Some("t0"), 10.0, 20.0, 12);
            p.resolve_wait(5.0, 2.0, 4.0);
        }
        p.tick(1, 2, 30, 40, 5);
        let mut o = Object::new();
        p.metrics_json(&mut o);
        assert!(o.get("forecast_step").is_some());
        assert!(o.get("forecast_len_coverage_pooled").is_some());
        let p90s = o.get("forecast_len_p90").unwrap().as_object().unwrap();
        assert!(p90s.get("t0").is_some());
        let dump = p.to_json();
        assert_eq!(dump.get("step").unwrap().as_usize(), Some(1));
        assert_eq!(
            dump.get("ring").unwrap().as_array().unwrap().len(),
            1,
            "one tick, one sample"
        );
        let t0 = dump.get("len").unwrap().get("t0").unwrap();
        assert_eq!(t0.get("resolved").unwrap().as_usize(), Some(4));
    }
}
