//! Observability: request-lifecycle tracing, mergeable latency
//! histograms, and the per-replica flight recorder.
//!
//! Every request carries a [`ReqTrace`] through router → scheduler →
//! coordinator → KV tier → migration.  The trace partitions the
//! request's wallclock lifetime into exclusive phases — at any instant a
//! request is in exactly one of [`Phase`]'s states — so the per-phase
//! seconds *telescope*: closing each span at the next transition makes
//! queue + prefill + decode + swap-blocked + migration sum to the E2E
//! latency with no unattributed gap and no double count.  Simulated-Z100
//! attribution (including speculative draft overhead, which overlaps
//! decode and therefore cannot be a wall phase) rides alongside.
//!
//! [`LatencyHist`] is the cluster-mergeable replacement for percentile
//! `Summary`s in aggregated `/metrics`: every replica buckets into the
//! same canonical exponential bounds, so merging is an elementwise count
//! addition — the merged histogram *is* the histogram of the union of
//! samples (exact, unlike averaging per-replica percentiles).
//!
//! The [`FlightRecorder`] keeps a bounded ring of recent finished-request
//! timelines per engine, dumped by `GET /admin/trace` and exportable as
//! Chrome `trace_event` JSON ([`chrome_trace`]) from the bench harness.

pub mod forecast;

use std::collections::VecDeque;
use std::time::Instant;

use crate::config::ReqClass;
use crate::util::json::{Object, Value};
use crate::util::logging::{self, Level};

// ---------------------------------------------------------------------------
// phases
// ---------------------------------------------------------------------------

/// Exclusive request lifecycle states.  A request occupies exactly one
/// at any wall instant; transitions are driven by the coordinator as it
/// applies scheduler decisions, tier ops, and migration steps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// waiting for admission (incl. re-queued after a drop-preemption or
    /// a token-level migration fallback)
    Queued = 0,
    /// admitted, committing prefill windows (chunked or one-shot)
    Prefill = 1,
    /// decode / verify rounds
    Decode = 2,
    /// KV parked on the host tier after a swap-preemption
    SwapBlocked = 3,
    /// parked in `Migrating`: KV export, transit, and import
    Migration = 4,
}

impl Phase {
    pub const COUNT: usize = 5;
    pub const ALL: [Phase; Phase::COUNT] = [
        Phase::Queued,
        Phase::Prefill,
        Phase::Decode,
        Phase::SwapBlocked,
        Phase::Migration,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Queued => "queued",
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
            Phase::SwapBlocked => "swap_blocked",
            Phase::Migration => "migration",
        }
    }
}

/// One timestamped lifecycle event (wall offset since arrival plus the
/// request's accumulated simulated-Z100 seconds at that moment).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub t_wall_s: f64,
    pub sim_s: f64,
    pub label: &'static str,
    /// phase in effect *after* this event
    pub phase: Phase,
}

/// Cap on recorded events per request: a runaway decode cannot grow a
/// trace without bound; overflow is counted, not silently dropped.
pub const MAX_TRACE_EVENTS: usize = 256;

/// Per-request lifecycle trace: exclusive wall-phase accumulators plus
/// the (sampled) event timeline.  Phase accounting is always on — it
/// feeds the phase breakdown and the queue-wait histogram; only the
/// event timeline is gated by `--trace-sample`.
#[derive(Debug, Clone)]
pub struct ReqTrace {
    pub id: u64,
    pub corr_id: Option<String>,
    /// SLO class echo (priority / deadline / tenant): shed and deferred
    /// time must be attributable per class and per tenant in the
    /// flight-recorder payload
    pub class: ReqClass,
    pub arrival: Instant,
    cur_phase: Phase,
    cur_since: Instant,
    wall_s: [f64; Phase::COUNT],
    /// simulated seconds of speculative draft cost attributed to this
    /// request (overlaps the decode phase; sim-clock, not a wall phase)
    pub sim_spec_overhead_s: f64,
    /// running sim-second attribution mirror (events stamp this)
    pub sim_s: f64,
    /// phase to return to when a swap-blocked request resumes (a victim
    /// can be swapped mid-prefill or mid-decode)
    pub resume_phase: Phase,
    pub preemptions: u64,
    /// predicted output-length quantiles stamped at admission by the
    /// engine's forecast plane (self-scoring: resolved at finish
    /// against `actual_len`)
    pub predicted_len_p50: Option<f64>,
    pub predicted_len_p90: Option<f64>,
    /// queue-wait prediction (ms) the router's admission decision used
    pub predicted_wait_ms: Option<f64>,
    /// outcomes written at finish — generated tokens and observed queue
    /// wait — so every trace carries its own calibration evidence
    pub actual_len: Option<u64>,
    pub actual_wait_ms: Option<f64>,
    events: Vec<TraceEvent>,
    events_enabled: bool,
    dropped_events: u64,
    finished: bool,
}

impl ReqTrace {
    pub fn new(id: u64, arrival: Instant, events_enabled: bool) -> Self {
        let mut t = ReqTrace {
            id,
            corr_id: None,
            class: ReqClass::default(),
            arrival,
            cur_phase: Phase::Queued,
            cur_since: arrival,
            wall_s: [0.0; Phase::COUNT],
            sim_spec_overhead_s: 0.0,
            sim_s: 0.0,
            resume_phase: Phase::Decode,
            preemptions: 0,
            predicted_len_p50: None,
            predicted_len_p90: None,
            predicted_wait_ms: None,
            actual_len: None,
            actual_wait_ms: None,
            events: Vec::new(),
            events_enabled,
            dropped_events: 0,
            finished: false,
        };
        t.push_event(0.0, "queued", Phase::Queued);
        t
    }

    pub fn cur_phase(&self) -> Phase {
        self.cur_phase
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn phase_wall_s(&self, p: Phase) -> f64 {
        self.wall_s[p as usize]
    }

    fn push_event(&mut self, t_wall_s: f64, label: &'static str, phase: Phase) {
        if !self.events_enabled {
            return;
        }
        if self.events.len() >= MAX_TRACE_EVENTS {
            self.dropped_events += 1;
            return;
        }
        self.events.push(TraceEvent {
            t_wall_s,
            sim_s: self.sim_s,
            label,
            phase,
        });
    }

    /// Close the current phase span and enter `phase`.  The span's wall
    /// seconds land on the phase being *left*, so the per-phase totals
    /// telescope to exactly `finished - arrival`.
    pub fn transition(&mut self, now: Instant, phase: Phase, label: &'static str) {
        let span = (now - self.cur_since).as_secs_f64();
        self.wall_s[self.cur_phase as usize] += span;
        self.cur_phase = phase;
        self.cur_since = now;
        self.push_event((now - self.arrival).as_secs_f64(), label, phase);
    }

    /// Record an event without a phase change (prefill-chunk commits,
    /// decode/verify rounds, tier ops observed mid-phase).
    pub fn note(&mut self, now: Instant, label: &'static str) {
        let phase = self.cur_phase;
        self.push_event((now - self.arrival).as_secs_f64(), label, phase);
    }

    /// [`ReqTrace::note`] at the current wall time, skipping the clock
    /// read entirely when the event timeline is not sampled — the
    /// hot-loop form for per-round decode/verify marks.
    pub fn note_now(&mut self, label: &'static str) {
        if self.events_enabled {
            self.note(Instant::now(), label);
        }
    }

    /// Attribute simulated-Z100 seconds to this request (mirrors the
    /// metrics-side `sim_time_s` charge so events carry both clocks).
    pub fn add_sim(&mut self, s: f64) {
        self.sim_s += s;
    }

    /// Close the final span.  Idempotent: migration hand-off re-admission
    /// never re-finishes an already-finished trace.
    pub fn finish(&mut self, now: Instant) -> PhaseBreakdown {
        if !self.finished {
            let span = (now - self.cur_since).as_secs_f64();
            self.wall_s[self.cur_phase as usize] += span;
            self.cur_since = now;
            self.finished = true;
            self.push_event((now - self.arrival).as_secs_f64(), "finished", self.cur_phase);
        }
        PhaseBreakdown {
            queue_s: self.wall_s[Phase::Queued as usize],
            prefill_s: self.wall_s[Phase::Prefill as usize],
            decode_s: self.wall_s[Phase::Decode as usize],
            swap_blocked_s: self.wall_s[Phase::SwapBlocked as usize],
            migration_s: self.wall_s[Phase::Migration as usize],
            spec_overhead_sim_s: self.sim_spec_overhead_s,
            e2e_s: (now - self.arrival).as_secs_f64(),
        }
    }

    /// Full timeline as JSON (the flight-recorder / `/admin/trace`
    /// payload shape).
    pub fn to_json(&self, breakdown: &PhaseBreakdown) -> Value {
        let mut o = Object::new();
        o.insert("id", self.id as usize);
        match &self.corr_id {
            Some(c) => o.insert("corr_id", c.as_str()),
            None => o.insert("corr_id", Value::Null),
        }
        o.insert("class", self.class.priority.name());
        match self.class.deadline_ms {
            Some(ms) => o.insert("deadline_ms", ms as usize),
            None => o.insert("deadline_ms", Value::Null),
        }
        match &self.class.tenant {
            Some(t) => o.insert("tenant", t.as_str()),
            None => o.insert("tenant", Value::Null),
        }
        o.insert("phases", breakdown.to_json());
        o.insert("preemptions", self.preemptions as usize);
        if self.predicted_len_p50.is_some()
            || self.predicted_len_p90.is_some()
            || self.predicted_wait_ms.is_some()
            || self.actual_len.is_some()
        {
            let mut f = Object::new();
            if let Some(v) = self.predicted_len_p50 {
                f.insert("predicted_len_p50", v);
            }
            if let Some(v) = self.predicted_len_p90 {
                f.insert("predicted_len_p90", v);
            }
            if let Some(v) = self.predicted_wait_ms {
                f.insert("predicted_wait_ms", v);
            }
            if let Some(v) = self.actual_len {
                f.insert("actual_len", v as usize);
            }
            if let Some(v) = self.actual_wait_ms {
                f.insert("actual_wait_ms", v);
            }
            o.insert("forecast", f);
        }
        let mut evs = Vec::with_capacity(self.events.len());
        for e in &self.events {
            let mut eo = Object::new();
            eo.insert("t_wall_s", e.t_wall_s);
            eo.insert("sim_s", e.sim_s);
            eo.insert("label", e.label);
            eo.insert("phase", e.phase.name());
            evs.push(Value::Object(eo));
        }
        o.insert("events", Value::Array(evs));
        if self.dropped_events > 0 {
            o.insert("dropped_events", self.dropped_events as usize);
        }
        Value::Object(o)
    }
}

/// Where a finished request's latency went.  The five wall phases
/// partition `e2e_s` exactly (telescoping spans); `spec_overhead_sim_s`
/// is the simulated draft-cost share and overlaps decode.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseBreakdown {
    pub queue_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub swap_blocked_s: f64,
    pub migration_s: f64,
    pub spec_overhead_sim_s: f64,
    pub e2e_s: f64,
}

impl PhaseBreakdown {
    /// Sum of the exclusive wall phases — equals `e2e_s` up to float
    /// rounding of the span additions.
    pub fn phase_sum_s(&self) -> f64 {
        self.queue_s + self.prefill_s + self.decode_s + self.swap_blocked_s + self.migration_s
    }

    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("queue_s", self.queue_s);
        o.insert("prefill_s", self.prefill_s);
        o.insert("decode_s", self.decode_s);
        o.insert("swap_blocked_s", self.swap_blocked_s);
        o.insert("migration_s", self.migration_s);
        o.insert("spec_overhead_sim_s", self.spec_overhead_sim_s);
        o.insert("e2e_s", self.e2e_s);
        Value::Object(o)
    }
}

// ---------------------------------------------------------------------------
// mergeable histograms
// ---------------------------------------------------------------------------

/// Canonical bucket table: bounds `HIST_BASE_S * HIST_GROWTH^i`, i in
/// `0..HIST_BUCKETS` (1 µs … ~1100 s), plus one overflow bucket.  Every
/// replica uses the same table, which is what makes merges exact.
pub const HIST_BASE_S: f64 = 1e-6;
pub const HIST_GROWTH: f64 = 2.0;
pub const HIST_BUCKETS: usize = 40;

/// Upper bound of bucket `i` (seconds).
pub fn hist_bound(i: usize) -> f64 {
    HIST_BASE_S * HIST_GROWTH.powi(i as i32)
}

/// Log-bucketed latency histogram over the canonical bounds.  Merging
/// two histograms (elementwise count addition + sum/min/max folds)
/// yields exactly the histogram of the combined sample set, so cluster
/// percentiles are computed once over merged counts instead of averaging
/// per-replica percentiles.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    counts: Vec<u64>, // HIST_BUCKETS + 1 (last = overflow)
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist {
            counts: vec![0; HIST_BUCKETS + 1],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn record(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        let mut idx = HIST_BUCKETS;
        for i in 0..HIST_BUCKETS {
            if x < hist_bound(i) {
                idx = i;
                break;
            }
        }
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Elementwise merge: after this, `self` is exactly the histogram of
    /// the union of both sample sets.
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// q-th percentile (q in [0, 100]), linearly interpolated inside the
    /// winning bucket and clamped to the recorded min/max.  NaN when
    /// empty.  Depends only on (counts, min, max), so merged histograms
    /// answer exactly as the union would.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q / 100.0 * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let lo = if i == 0 { 0.0 } else { hist_bound(i - 1) };
                let hi = if i < HIST_BUCKETS {
                    hist_bound(i)
                } else {
                    self.max.max(lo)
                };
                let frac = (target - seen) as f64 / c as f64;
                let est = lo + (hi - lo) * frac;
                return est.clamp(self.min, self.max);
            }
            seen += c;
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Sparse JSON: only non-zero buckets travel over `/metrics`.
    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("count", self.total as usize);
        o.insert("sum", self.sum);
        if self.total > 0 {
            o.insert("min", self.min);
            o.insert("max", self.max);
        }
        let mut buckets = Object::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                buckets.insert(format!("{i}"), c as usize);
            }
        }
        o.insert("buckets", buckets);
        Value::Object(o)
    }

    /// Inverse of [`LatencyHist::to_json`]; `None` on malformed input
    /// (a replica speaking a different schema must not poison the merge).
    pub fn from_json(v: &Value) -> Option<LatencyHist> {
        let mut h = LatencyHist::new();
        h.total = v.get("count")?.as_usize()? as u64;
        h.sum = v.get("sum")?.as_f64()?;
        if h.total > 0 {
            h.min = v.get("min")?.as_f64()?;
            h.max = v.get("max")?.as_f64()?;
        }
        let buckets = v.get("buckets")?.as_object()?;
        let mut counted = 0u64;
        for (k, c) in buckets.iter() {
            let i: usize = k.parse().ok()?;
            if i > HIST_BUCKETS {
                return None;
            }
            let c = c.as_usize()? as u64;
            h.counts[i] = c;
            counted += c;
        }
        if counted != h.total {
            return None;
        }
        Some(h)
    }
}

// ---------------------------------------------------------------------------
// flight recorder
// ---------------------------------------------------------------------------

/// Bounded ring of recent finished-request timelines (one per engine).
/// Capacity comes from `--trace-depth`; 0 disables recording entirely.
#[derive(Debug, Default)]
pub struct FlightRecorder {
    cap: usize,
    ring: VecDeque<Value>,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> Self {
        FlightRecorder {
            cap,
            ring: VecDeque::with_capacity(cap.min(64)),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    pub fn push(&mut self, trace: Value) {
        if self.cap == 0 {
            return;
        }
        if self.ring.len() >= self.cap {
            self.ring.pop_front();
        }
        self.ring.push_back(trace);
    }

    /// Dump the ring (oldest first), optionally filtered by engine
    /// request id or client correlation id.
    pub fn to_json(&self, id: Option<u64>, corr: Option<&str>) -> Value {
        let items = self
            .ring
            .iter()
            .filter(|t| match id {
                Some(want) => t.get("id").and_then(Value::as_usize) == Some(want as usize),
                None => true,
            })
            .filter(|t| match corr {
                Some(want) => t.get("corr_id").and_then(Value::as_str) == Some(want),
                None => true,
            })
            .cloned()
            .collect();
        Value::Array(items)
    }
}

// ---------------------------------------------------------------------------
// deterministic sampling
// ---------------------------------------------------------------------------

fn fnv1a_u64(x: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in x.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Deterministic per-request sampling decision for `--trace-sample`:
/// hash the engine-assigned id so the same id samples the same way on
/// every replica and every run (no RNG on the request path).
pub fn trace_sampled(id: u64, sample: f64) -> bool {
    if sample >= 1.0 {
        true
    } else if sample <= 0.0 {
        false
    } else {
        (fnv1a_u64(id) % 10_000) as f64 < sample * 10_000.0
    }
}

// ---------------------------------------------------------------------------
// Chrome trace_event export
// ---------------------------------------------------------------------------

/// Convert flight-recorder timelines into Chrome `trace_event` JSON
/// (`chrome://tracing` / Perfetto): one complete-span ("X") event per
/// phase interval plus instant ("i") marks for the raw lifecycle events.
/// `pid` is the replica index, `tid` the engine request id, timestamps
/// are microseconds since the request's arrival.
pub fn chrome_trace(traces: &[(usize, Value)]) -> Value {
    let mut events = Vec::new();
    for (replica, trace) in traces {
        let id = trace.get("id").and_then(Value::as_usize).unwrap_or(0);
        let evs = match trace.get("events").and_then(Value::as_array) {
            Some(e) if !e.is_empty() => e,
            _ => continue,
        };
        let at = |e: &Value| e.get("t_wall_s").and_then(Value::as_f64).unwrap_or(0.0);
        let phase_of = |e: &Value| {
            e.get("phase")
                .and_then(Value::as_str)
                .unwrap_or("queued")
                .to_string()
        };
        let mut span_start = at(&evs[0]);
        let mut span_phase = phase_of(&evs[0]);
        for e in evs.iter().skip(1) {
            let t = at(e);
            let phase = phase_of(e);
            let is_last = e.get("label").and_then(Value::as_str) == Some("finished");
            if phase != span_phase || is_last {
                let mut x = Object::new();
                x.insert("name", span_phase.as_str());
                x.insert("cat", "phase");
                x.insert("ph", "X");
                x.insert("pid", *replica);
                x.insert("tid", id);
                x.insert("ts", span_start * 1e6);
                x.insert("dur", (t - span_start).max(0.0) * 1e6);
                events.push(Value::Object(x));
                span_start = t;
                span_phase = phase;
            }
            let mut i = Object::new();
            i.insert(
                "name",
                e.get("label").and_then(Value::as_str).unwrap_or("event"),
            );
            i.insert("cat", "lifecycle");
            i.insert("ph", "i");
            i.insert("s", "t");
            i.insert("pid", *replica);
            i.insert("tid", id);
            i.insert("ts", t * 1e6);
            events.push(Value::Object(i));
        }
    }
    let mut top = Object::new();
    top.insert("traceEvents", Value::Array(events));
    top.insert("displayTimeUnit", "ms");
    Value::Object(top)
}

// ---------------------------------------------------------------------------
// Prometheus text exposition
// ---------------------------------------------------------------------------

fn prom_name(key: &str) -> String {
    let mut s = String::with_capacity(key.len() + 10);
    s.push_str("llm_coopt_");
    for c in key.chars() {
        if c.is_ascii_alphanumeric() || c == '_' {
            s.push(c);
        } else {
            s.push('_');
        }
    }
    s
}

/// Emit one histogram's bucket/sum/count lines (no `# TYPE` header).
/// `label` is either empty or a `key="value",` prefix spliced before the
/// `le` label on every bucket line (and alone on `_sum`/`_count`).
fn push_hist_body(out: &mut String, name: &str, label: &str, h: &LatencyHist) {
    let mut cum = 0u64;
    for (i, &c) in h.counts().iter().enumerate() {
        cum += c;
        if i < HIST_BUCKETS {
            // only materialize populated + boundary lines:
            // full 41-bucket exposition per metric is noise
            if c == 0 && i > 0 && h.counts()[i - 1] == 0 {
                continue;
            }
            out.push_str(&format!(
                "{name}_bucket{{{label}le=\"{}\"}} {cum}\n",
                hist_bound(i)
            ));
        }
    }
    out.push_str(&format!("{name}_bucket{{{label}le=\"+Inf\"}} {}\n", h.count()));
    if label.is_empty() {
        out.push_str(&format!("{name}_sum {}\n", h.sum()));
        out.push_str(&format!("{name}_count {}\n", h.count()));
    } else {
        let bare = label.trim_end_matches(',');
        out.push_str(&format!("{name}_sum{{{bare}}} {}\n", h.sum()));
        out.push_str(&format!("{name}_count{{{bare}}} {}\n", h.count()));
    }
}

/// Render a flat `/metrics` JSON payload as Prometheus text exposition:
/// numbers become gauges, the `hist` object becomes `_bucket{le=...}`
/// series with `_sum`/`_count`, the `hist_class` object becomes the same
/// series under `<name>_class_seconds` with a `class="interactive|batch"`
/// label, and one-level numeric maps (e.g. `spec_k_hist`) become labeled
/// gauges.  Strings, bools, and nested arrays (per-replica snapshots)
/// are skipped — scrape each replica for those.
pub fn prometheus_text(v: &Value) -> String {
    let mut out = String::new();
    let obj = match v.as_object() {
        Some(o) => o,
        None => return out,
    };
    for (key, val) in obj.iter() {
        match val {
            Value::Num(n) if n.is_finite() => {
                let name = prom_name(key);
                out.push_str(&format!("# TYPE {name} gauge\n{name} {n}\n"));
            }
            Value::Object(sub) if key == "hist" => {
                for (hname, hval) in sub.iter() {
                    let h = match LatencyHist::from_json(hval) {
                        Some(h) => h,
                        None => continue,
                    };
                    let name = format!("{}_seconds", prom_name(hname));
                    out.push_str(&format!("# TYPE {name} histogram\n"));
                    push_hist_body(&mut out, &name, "", &h);
                }
            }
            Value::Object(sub) if key == "hist_class" => {
                // {class: {hist_name: hist}} — one labeled series per
                // class under a shared metric name, TYPE written once
                let mut typed: Vec<String> = Vec::new();
                for (class, chists) in sub.iter() {
                    let Some(ch) = chists.as_object() else { continue };
                    for (hname, hval) in ch.iter() {
                        let Some(h) = LatencyHist::from_json(hval) else { continue };
                        let name = format!("{}_class_seconds", prom_name(hname));
                        if !typed.contains(&name) {
                            out.push_str(&format!("# TYPE {name} histogram\n"));
                            typed.push(name.clone());
                        }
                        let label = format!("class=\"{class}\",");
                        push_hist_body(&mut out, &name, &label, &h);
                    }
                }
            }
            Value::Object(sub) => {
                let name = prom_name(key);
                let mut wrote_type = false;
                for (k, v) in sub.iter() {
                    if let Value::Num(n) = v {
                        if n.is_finite() {
                            if !wrote_type {
                                out.push_str(&format!("# TYPE {name} gauge\n"));
                                wrote_type = true;
                            }
                            out.push_str(&format!("{name}{{key=\"{k}\"}} {n}\n"));
                        }
                    }
                }
            }
            _ => {}
        }
    }
    out
}

// ---------------------------------------------------------------------------
// structured stderr events
// ---------------------------------------------------------------------------

/// Emit a structured one-line JSON event to stderr, gated by the global
/// log level (`--log-level` / `LLM_COOPT_LOG`).  This is the serving
/// path's replacement for silently discarding send errors: machine-
/// parseable, one line, no panic, no allocation when gated off.
pub fn log_json_event(level: Level, event: &str, fields: &[(&str, Value)]) {
    if !logging::enabled(level) {
        return;
    }
    let mut o = Object::new();
    o.insert("event", event);
    o.insert(
        "level",
        match level {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        },
    );
    o.insert("t_s", logging::elapsed_s());
    for (k, v) in fields {
        o.insert(*k, v.clone());
    }
    eprintln!("{}", Value::Object(o));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn hist_of(samples: &[f64]) -> LatencyHist {
        let mut h = LatencyHist::new();
        for &s in samples {
            h.record(s);
        }
        h
    }

    #[test]
    fn phase_partition_telescopes_to_e2e() {
        let t0 = Instant::now();
        let mut tr = ReqTrace::new(7, t0, true);
        let t1 = t0 + Duration::from_millis(10);
        tr.transition(t1, Phase::Prefill, "admitted");
        let t2 = t1 + Duration::from_millis(25);
        tr.transition(t2, Phase::Decode, "prefill_done");
        let t3 = t2 + Duration::from_millis(5);
        tr.resume_phase = tr.cur_phase();
        tr.transition(t3, Phase::SwapBlocked, "swap_out");
        let t4 = t3 + Duration::from_millis(40);
        tr.transition(t4, Phase::Decode, "swap_in");
        let t5 = t4 + Duration::from_millis(20);
        let b = tr.finish(t5);
        assert!((b.queue_s - 0.010).abs() < 1e-9);
        assert!((b.prefill_s - 0.025).abs() < 1e-9);
        assert!((b.decode_s - 0.025).abs() < 1e-9);
        assert!((b.swap_blocked_s - 0.040).abs() < 1e-9);
        assert_eq!(b.migration_s, 0.0);
        // the telescoping property: no gap, no double count
        assert!((b.phase_sum_s() - b.e2e_s).abs() < 1e-9);
        // finish is idempotent
        let b2 = tr.finish(t5 + Duration::from_millis(100));
        assert!((b2.phase_sum_s() - b.phase_sum_s()).abs() < 1e-12);
        // timeline recorded with labels in order
        let labels: Vec<&str> = tr.events().iter().map(|e| e.label).collect();
        assert_eq!(
            labels,
            ["queued", "admitted", "prefill_done", "swap_out", "swap_in", "finished"]
        );
    }

    #[test]
    fn trace_sampling_gates_events_not_phases() {
        let t0 = Instant::now();
        let mut tr = ReqTrace::new(1, t0, false);
        tr.transition(t0 + Duration::from_millis(3), Phase::Prefill, "admitted");
        let b = tr.finish(t0 + Duration::from_millis(8));
        assert!(tr.events().is_empty(), "unsampled: no timeline");
        assert!((b.phase_sum_s() - b.e2e_s).abs() < 1e-9, "phases still exact");
        assert!(b.queue_s > 0.0 && b.prefill_s > 0.0);
    }

    #[test]
    fn trace_event_cap_counts_drops() {
        let t0 = Instant::now();
        let mut tr = ReqTrace::new(1, t0, true);
        for i in 0..(MAX_TRACE_EVENTS + 10) {
            tr.note(t0 + Duration::from_micros(i as u64), "decode_round");
        }
        assert_eq!(tr.events().len(), MAX_TRACE_EVENTS);
        let b = tr.finish(t0 + Duration::from_millis(1));
        let j = tr.to_json(&b);
        assert!(j.req_usize("dropped_events").unwrap() > 0);
    }

    #[test]
    fn hist_records_and_interpolates() {
        let h = hist_of(&[0.5e-6, 2e-6, 3e-6, 0.01, 0.02, 0.04, 1.0, 2.0]);
        assert_eq!(h.count(), 8);
        assert!((h.sum() - 3.070005_5).abs() < 1e-6);
        assert_eq!(h.min(), 0.5e-6);
        assert_eq!(h.max(), 2.0);
        assert!(h.p50() <= h.p95());
        assert!(h.p95() <= h.p99());
        assert!(h.p99() <= h.max());
        assert!(h.percentile(0.0) >= h.min());
        // empty histogram: NaN percentile, zero mean, no min/max in JSON
        let e = LatencyHist::new();
        assert!(e.percentile(50.0).is_nan());
        assert_eq!(e.mean(), 0.0);
        assert!(!e.to_json().to_string().contains("min"));
    }

    #[test]
    fn hist_merge_is_exact_and_associative() {
        let a = hist_of(&[1e-5, 2e-5, 0.3, 0.4]);
        let b = hist_of(&[5e-4, 0.001, 7.0]);
        let c = hist_of(&[0.25, 90.0, 1e-6, 0.5]);
        // (a ⊕ b) ⊕ c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a ⊕ (b ⊕ c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left.counts(), right.counts());
        assert_eq!(left.count(), right.count());
        assert!((left.sum() - right.sum()).abs() < 1e-12);
        assert_eq!(left.min(), right.min());
        assert_eq!(left.max(), right.max());
        // merge == histogram of the union of samples (the exactness claim)
        let union = hist_of(&[
            1e-5, 2e-5, 0.3, 0.4, 5e-4, 0.001, 7.0, 0.25, 90.0, 1e-6, 0.5,
        ]);
        assert_eq!(left.counts(), union.counts());
        assert_eq!(left.count(), union.count());
        assert_eq!(left.min(), union.min());
        assert_eq!(left.max(), union.max());
        for q in [50.0, 90.0, 95.0, 99.0] {
            assert!(
                (left.percentile(q) - union.percentile(q)).abs() < 1e-12,
                "merged percentile must equal union percentile at q={q}"
            );
        }
        // commutative too
        let mut ba = b.clone();
        ba.merge(&a);
        let mut ab = a.clone();
        ab.merge(&b);
        assert_eq!(ab.counts(), ba.counts());
    }

    #[test]
    fn hist_empty_percentiles_are_nan_at_every_q() {
        let e = LatencyHist::new();
        for q in [0.0, 50.0, 90.0, 99.0, 100.0] {
            assert!(e.percentile(q).is_nan(), "empty hist must be NaN at q={q}");
        }
        assert_eq!(e.count(), 0);
        assert_eq!(e.sum(), 0.0);
    }

    #[test]
    fn hist_single_sample_every_percentile_is_the_sample() {
        let h = hist_of(&[0.0123]);
        for q in [0.0, 1.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(
                h.percentile(q),
                0.0123,
                "one sample: min==max clamps every q to it (q={q})"
            );
        }
        assert_eq!(h.min(), h.max());
        assert_eq!(h.mean(), 0.0123);
    }

    #[test]
    fn hist_overflow_bucket_percentiles_stay_in_range() {
        // every sample beyond the last finite bound lands in overflow;
        // percentiles must interpolate against the recorded max, not
        // the (infinite) bucket bound
        let top = hist_bound(HIST_BUCKETS - 1);
        let h = hist_of(&[top * 2.0, top * 4.0, top * 8.0]);
        assert_eq!(h.counts()[HIST_BUCKETS], 3, "all in overflow");
        for q in [50.0, 90.0, 99.0] {
            let p = h.percentile(q);
            assert!(
                p >= h.min() && p <= h.max(),
                "overflow percentile q={q} out of [min,max]: {p}"
            );
        }
        assert_eq!(h.percentile(100.0), h.max());
        // mixed: one finite-bucket sample, rest overflow — p99 still
        // bounded by max
        let m = hist_of(&[1e-3, top * 2.0, top * 2.0, top * 2.0]);
        assert!(m.percentile(99.0) <= m.max());
        assert!(m.percentile(1.0) >= m.min());
    }

    #[test]
    fn hist_merge_of_disjoint_buckets_is_union() {
        // a occupies only low buckets, b only high ones — the merge
        // must interleave exactly, not average
        let a = hist_of(&[1e-6, 2e-6, 4e-6, 8e-6]);
        let b = hist_of(&[1.0, 2.0, 4.0, 8.0]);
        let mut m = a.clone();
        m.merge(&b);
        let union = hist_of(&[1e-6, 2e-6, 4e-6, 8e-6, 1.0, 2.0, 4.0, 8.0]);
        assert_eq!(m.counts(), union.counts());
        assert_eq!(m.min(), 1e-6);
        assert_eq!(m.max(), 8.0);
        // p50 comes from a's half, p99 from b's half
        assert!(m.percentile(50.0) < 1e-4, "low half owns the median");
        assert!(m.percentile(99.0) > 1.0, "high half owns the tail");
        for q in [25.0, 50.0, 75.0, 99.0] {
            assert_eq!(m.percentile(q), union.percentile(q));
        }
        // merging an empty histogram is the identity
        let mut id = a.clone();
        id.merge(&LatencyHist::new());
        assert_eq!(id.counts(), a.counts());
        assert_eq!(id.min(), a.min());
        assert_eq!(id.max(), a.max());
    }

    #[test]
    fn trace_forecast_stamps_travel_to_json() {
        let t0 = Instant::now();
        let mut tr = ReqTrace::new(7, t0, true);
        let b = tr.finish(t0 + Duration::from_millis(2));
        assert!(
            !tr.to_json(&b).to_string().contains("forecast"),
            "no stamps: no forecast object"
        );
        tr.predicted_len_p50 = Some(12.0);
        tr.predicted_len_p90 = Some(30.0);
        tr.predicted_wait_ms = Some(4.5);
        tr.actual_len = Some(28);
        tr.actual_wait_ms = Some(3.25);
        let j = tr.to_json(&b);
        let f = j.get("forecast").expect("forecast object");
        assert_eq!(f.req_f64("predicted_len_p50").unwrap(), 12.0);
        assert_eq!(f.req_f64("predicted_len_p90").unwrap(), 30.0);
        assert_eq!(f.req_f64("predicted_wait_ms").unwrap(), 4.5);
        assert_eq!(f.req_usize("actual_len").unwrap(), 28);
        assert_eq!(f.req_f64("actual_wait_ms").unwrap(), 3.25);
    }

    #[test]
    fn hist_json_round_trip() {
        let h = hist_of(&[1e-6, 0.005, 0.005, 3.0, 700.0, 5e9]);
        let j = h.to_json();
        let back = LatencyHist::from_json(&j).expect("round trip");
        assert_eq!(back.counts(), h.counts());
        assert_eq!(back.count(), h.count());
        assert!((back.sum() - h.sum()).abs() < 1e-9);
        assert_eq!(back.min(), h.min());
        assert_eq!(back.max(), h.max());
        // overflow bucket (5e9 s) survives
        assert_eq!(h.counts()[HIST_BUCKETS], 1);
        // malformed inputs are rejected, not half-parsed
        assert!(LatencyHist::from_json(&Value::Null).is_none());
        let mut o = Object::new();
        o.insert("count", 3usize);
        o.insert("sum", 1.0);
        o.insert("min", 0.1);
        o.insert("max", 0.9);
        let mut b = Object::new();
        b.insert("0", 1usize); // count says 3, buckets say 1
        o.insert("buckets", b);
        assert!(LatencyHist::from_json(&Value::Object(o)).is_none());
    }

    #[test]
    fn flight_recorder_ring_bounds_and_filters() {
        let mut fr = FlightRecorder::new(3);
        for i in 0..5u64 {
            let t0 = Instant::now();
            let mut tr = ReqTrace::new(i, t0, true);
            if i == 4 {
                tr.corr_id = Some("req-x".into());
            }
            let b = tr.finish(t0 + Duration::from_millis(1));
            fr.push(tr.to_json(&b));
        }
        assert_eq!(fr.len(), 3, "ring bounded at capacity");
        let all = fr.to_json(None, None);
        let ids: Vec<usize> = all
            .as_array()
            .unwrap()
            .iter()
            .map(|t| t.req_usize("id").unwrap())
            .collect();
        assert_eq!(ids, [2, 3, 4], "oldest evicted first");
        assert_eq!(fr.to_json(Some(3), None).as_array().unwrap().len(), 1);
        assert_eq!(fr.to_json(Some(99), None).as_array().unwrap().len(), 0);
        assert_eq!(
            fr.to_json(None, Some("req-x")).as_array().unwrap().len(),
            1
        );
        // depth 0 disables recording
        let mut off = FlightRecorder::new(0);
        off.push(Value::Null);
        assert!(off.is_empty());
    }

    #[test]
    fn deterministic_sampling() {
        assert!(trace_sampled(42, 1.0));
        assert!(!trace_sampled(42, 0.0));
        // stable across calls and roughly proportional
        let hits: usize = (0..1000).filter(|&i| trace_sampled(i, 0.25)).count();
        assert!(hits > 150 && hits < 350, "got {hits}/1000 at 0.25");
        for i in 0..100 {
            assert_eq!(trace_sampled(i, 0.5), trace_sampled(i, 0.5));
        }
    }

    #[test]
    fn chrome_trace_spans_cover_phases() {
        let t0 = Instant::now();
        let mut tr = ReqTrace::new(9, t0, true);
        tr.transition(t0 + Duration::from_millis(2), Phase::Prefill, "admitted");
        tr.transition(t0 + Duration::from_millis(6), Phase::Decode, "prefill_done");
        let b = tr.finish(t0 + Duration::from_millis(11));
        let out = chrome_trace(&[(1, tr.to_json(&b))]);
        let evs = out.req("traceEvents").unwrap().as_array().unwrap();
        let spans: Vec<&Value> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Value::as_str) == Some("X"))
            .collect();
        assert_eq!(spans.len(), 3, "queued, prefill, decode spans");
        let names: Vec<&str> = spans.iter().map(|s| s.req_str("name").unwrap()).collect();
        assert_eq!(names, ["queued", "prefill", "decode"]);
        let total_dur: f64 = spans.iter().map(|s| s.req_f64("dur").unwrap()).sum();
        assert!((total_dur / 1e6 - b.e2e_s).abs() < 1e-6);
        for s in &spans {
            assert_eq!(s.req_usize("pid").unwrap(), 1);
            assert_eq!(s.req_usize("tid").unwrap(), 9);
        }
    }

    #[test]
    fn prometheus_exposition_shapes() {
        let mut hist = Object::new();
        hist.insert("ttft_wall", hist_of(&[0.01, 0.02, 5.0]).to_json());
        let mut k_hist = Object::new();
        k_hist.insert("0", 2usize);
        k_hist.insert("3", 5usize);
        let mut o = Object::new();
        o.insert("tokens_generated", 128usize);
        o.insert("throughput_sim_tok_s", 42.5);
        o.insert("spec_regime", "gemm-bound"); // string: skipped
        o.insert("spec_k_hist", k_hist);
        o.insert("hist", hist);
        let text = prometheus_text(&Value::Object(o));
        assert!(text.contains("# TYPE llm_coopt_tokens_generated gauge"));
        assert!(text.contains("llm_coopt_tokens_generated 128"));
        assert!(text.contains("llm_coopt_throughput_sim_tok_s 42.5"));
        assert!(!text.contains("gemm-bound"));
        assert!(text.contains("llm_coopt_spec_k_hist{key=\"3\"} 5"));
        assert!(text.contains("# TYPE llm_coopt_ttft_wall_seconds histogram"));
        assert!(text.contains("llm_coopt_ttft_wall_seconds_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("llm_coopt_ttft_wall_seconds_count 3"));
        // every line is either a comment or name[{labels}] value
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.contains(' '),
                "malformed line: {line}"
            );
        }
    }

    #[test]
    fn prometheus_exposition_labels_per_class_hists() {
        let mut interactive = Object::new();
        interactive.insert("ttft_wall", hist_of(&[0.005, 0.010]).to_json());
        let mut batch = Object::new();
        batch.insert("ttft_wall", hist_of(&[0.2]).to_json());
        let mut hc = Object::new();
        hc.insert("interactive", interactive);
        hc.insert("batch", batch);
        let mut o = Object::new();
        o.insert("hist_class", hc);
        let text = prometheus_text(&Value::Object(o));
        // one shared metric name, TYPE written once, one series per class
        assert_eq!(
            text.matches("# TYPE llm_coopt_ttft_wall_class_seconds histogram")
                .count(),
            1
        );
        assert!(text
            .contains("llm_coopt_ttft_wall_class_seconds_bucket{class=\"interactive\",le=\"+Inf\"} 2"));
        assert!(text
            .contains("llm_coopt_ttft_wall_class_seconds_bucket{class=\"batch\",le=\"+Inf\"} 1"));
        assert!(text.contains("llm_coopt_ttft_wall_class_seconds_count{class=\"interactive\"} 2"));
        assert!(text.contains("llm_coopt_ttft_wall_class_seconds_count{class=\"batch\"} 1"));
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.contains(' '),
                "malformed line: {line}"
            );
        }
    }
}
