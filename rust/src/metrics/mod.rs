//! Serving metrics: the paper's Eq. 11 (total latency) and Eq. 12
//! (generation throughput), plus per-request latency percentiles, engine
//! step accounting, and simulated-platform time.

use std::time::{Duration, Instant};

use crate::config::Priority;
use crate::obs::{LatencyHist, PhaseBreakdown};
use crate::util::json::{Object, Value};
use crate::util::stats::Summary;

/// One priority class's copy of the mergeable latency-histogram set
/// (TTFT / E2E / decode ITL / queue wait).  Recorded alongside the
/// class-blind histograms so cluster `/metrics` can expose interactive
/// and batch tails separately — the whole point of SLO-aware overload
/// control is that these two distributions diverge under pressure.
#[derive(Debug, Default)]
pub struct ClassHists {
    pub ttft_wall: LatencyHist,
    pub e2e_wall: LatencyHist,
    pub itl_sim: LatencyHist,
    pub queue_wall: LatencyHist,
}

impl ClassHists {
    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("ttft_wall", self.ttft_wall.to_json());
        o.insert("e2e_wall", self.e2e_wall.to_json());
        o.insert("itl_sim", self.itl_sim.to_json());
        o.insert("queue_wall", self.queue_wall.to_json());
        Value::Object(o)
    }
}

/// Smoothing of the windowed tokens-per-round EWMA: ~0.2 weights the
/// last ~5 rounds, fast enough that a speculation demotion reaches the
/// router's load signal within one snapshot interval.
pub const ROUND_RATE_EWMA_ALPHA: f64 = 0.2;

/// Index of a priority class in per-class metric arrays.
pub fn class_idx(c: Priority) -> usize {
    match c {
        Priority::Interactive => 0,
        Priority::Batch => 1,
    }
}

/// Per-request record (filled by the coordinator as the request advances).
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub id: u64,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub arrival: Instant,
    pub first_token: Option<Instant>,
    pub finished: Option<Instant>,
    /// simulated Z100 time attributed to this request (seconds)
    pub sim_time_s: f64,
}

impl RequestMetrics {
    pub fn latency(&self) -> Option<Duration> {
        self.finished.map(|f| f - self.arrival)
    }

    pub fn ttft(&self) -> Option<Duration> {
        self.first_token.map(|f| f - self.arrival)
    }
}

/// Aggregate over a run.
#[derive(Debug, Default)]
pub struct EngineMetrics {
    pub requests_finished: u64,
    pub tokens_generated: u64,
    pub prefill_steps: u64,
    /// prefill windows committed by chunked prefill (Opt-Pa step 1);
    /// zero when the engine runs one-shot prefill
    pub prefill_chunks: u64,
    /// prompt tokens run through prefill graphs (one-shot + chunked
    /// windows) — the forecast ring's prefill-rate signal
    pub prefill_tokens_committed: u64,
    /// simulated seconds spent between consecutive windows of the same
    /// prompt (inter-chunk stall — the price of interleaving decodes)
    pub chunk_stall_s: f64,
    pub decode_steps: u64,
    pub preemptions: u64,
    // --- speculative decoding (draft-and-verify) ---------------------------
    /// verify passes executed (each scores k+1 positions in one step)
    pub spec_rounds: u64,
    /// draft tokens proposed across all verify passes
    pub spec_drafted: u64,
    /// draft tokens accepted and committed
    pub spec_accepted: u64,
    /// tokens committed by decode + verify rounds (excludes the token
    /// sampled at the end of prefill) — the numerator of tokens/step
    pub decode_tokens_committed: u64,
    /// active lanes summed over decode + verify rounds (occupancy
    /// numerator)
    pub decode_lanes_sum: u64,
    /// batch slots offered over those rounds (occupancy denominator)
    pub decode_batch_slots: u64,
    /// windowed tokens-per-round EWMA (the routing load signal; see
    /// [`EngineMetrics::tokens_per_step_recent`])
    pub tokens_per_step_ewma: f64,
    /// rounds folded into the EWMA (0 — no decode round yet)
    pub round_rate_samples: u64,
    // --- adaptive speculation (online draft-length controller) -------------
    /// rounds by draft length: `spec_k_hist[k]` counts decode/verify
    /// rounds that ran at draft length k (index 0 = plain one-token
    /// rounds; empty when speculation is disabled)
    pub spec_k_hist: Vec<u64>,
    /// the controller's current global draft length (gauge)
    pub spec_k_current: usize,
    /// draft-length changes the controller has made
    pub spec_ctrl_transitions: u64,
    /// the controller's EWMA per-position acceptance estimate (gauge;
    /// 0.0 in fixed mode or before the first measurement)
    pub spec_acceptance_ewma: f64,
    /// cost-model regime of the last planned decode batch (gauge):
    /// "weight-stream-bound", "gemm-bound", or "" when unknown
    pub spec_regime: &'static str,
    /// decode/verify rounds and committed tokens split by the cost-model
    /// regime they ran in (tokens/step per regime is the controller's
    /// report card: > 1 where speculation pays, ~1 where it cannot)
    pub rounds_weight_stream_bound: u64,
    pub tokens_weight_stream_bound: u64,
    pub rounds_gemm_bound: u64,
    pub tokens_gemm_bound: u64,
    // --- Opt-KV tier manager (two-tier KV hierarchy) -----------------------
    /// preemptions that swapped the victim to the host tier
    pub swap_outs: u64,
    /// sequences brought back from the host tier
    pub swap_ins: u64,
    pub blocks_swapped_out: u64,
    pub blocks_swapped_in: u64,
    /// paper-scale bytes moved over the host<->device link
    pub bytes_swapped_out: u64,
    pub bytes_swapped_in: u64,
    /// swap-ins staged ahead by the async prefetch queue (overlapped)
    pub prefetch_hits: u64,
    /// swap-ins performed on demand (the scheduler had to wait)
    pub prefetch_misses: u64,
    /// tokens re-prefilled because a preemption dropped KV (recompute)
    pub tokens_recomputed: u64,
    /// tokens whose re-prefill the tier manager avoided by swapping
    pub recompute_avoided_tokens: u64,
    // --- PD disaggregation (cross-replica KV migration) --------------------
    /// sequences this engine handed off to another replica with their KV
    /// blocks exported through the host tier
    pub migrations_out: u64,
    /// sequences re-admitted here with imported KV blocks
    pub migrations_in: u64,
    pub migrated_blocks_out: u64,
    pub migrated_blocks_in: u64,
    /// paper-scale bytes a KV hand-off moved over the host tier (export
    /// plus import are each charged once by the side that performed them)
    pub migration_bytes: u64,
    /// hand-offs that fell back to token-level transfer (destination
    /// re-prefills; the cost model said migration wouldn't pay or the
    /// transport was unavailable)
    pub migrations_token_fallback: u64,
    // --- cluster-wide prefix reuse (directory-routed KV pulls) -------------
    /// cross-replica prefix pulls this replica committed (destination)
    pub prefix_pulls: u64,
    /// KV blocks imported by those pulls
    pub prefix_pull_blocks: u64,
    /// paper-scale bytes the pulls imported
    pub prefix_pull_bytes: u64,
    /// KV blocks this replica exported to other replicas' pulls (source)
    pub prefix_pull_blocks_out: u64,
    /// pulls that landed short of the directory's promise (stale entry,
    /// missing transport, or pool pressure) — the shortfall re-prefills
    pub prefix_pull_stale: u64,
    /// watermark-triggered swap-outs performed ahead of demand
    /// (`--evict-watermark`); subset of `swap_outs`
    pub proactive_swap_outs: u64,
    /// simulated seconds of swap traffic (total, incl. overlapped)
    pub sim_swap_s: f64,
    /// simulated swap seconds the engine actually waited on (prefetch
    /// misses); counted against Eq. 12 throughput
    pub sim_swap_blocked_s: f64,
    /// wallclock seconds inside PJRT execute calls
    pub wall_prefill_s: f64,
    pub wall_decode_s: f64,
    /// wallclock seconds in the coordinator outside PJRT (L3 overhead)
    pub wall_coordinator_s: f64,
    /// simulated Z100 seconds (platform model)
    pub sim_prefill_s: f64,
    pub sim_decode_s: f64,
    /// per-request latency summaries (wallclock + simulated)
    pub latency_wall: Summary,
    pub latency_sim: Summary,
    pub ttft_wall: Summary,
    /// per-sequence decode inter-token latency on the simulated clock,
    /// one sample per (decode step, active lane); includes the prefill
    /// windows the step ran first — the stall chunked prefill bounds
    pub itl_sim: Summary,
    // --- request-lifecycle observability (obs module) ----------------------
    /// log-bucketed *mergeable* latency histograms: unlike the `Summary`
    /// percentiles above, per-replica copies of these merge exactly, so
    /// the cluster aggregate's percentiles are true union percentiles
    pub hist_ttft_wall: LatencyHist,
    pub hist_e2e_wall: LatencyHist,
    pub hist_itl_sim: LatencyHist,
    pub hist_queue_wall: LatencyHist,
    /// the same histogram set split by priority class
    /// (index via [`class_idx`]; merged per class in cluster `/metrics`)
    pub hist_class: [ClassHists; 2],
    /// requests cancelled at a step boundary because their SLO deadline
    /// passed (`FinishReason::DeadlineExceeded`)
    pub deadline_cancellations: u64,
    /// wallclock seconds finished requests spent in each lifecycle phase
    /// (the phases partition each request's E2E, so these five sum to
    /// `total_latency_wall_s` up to clock-read jitter)
    pub phase_queue_s: f64,
    pub phase_prefill_s: f64,
    pub phase_decode_s: f64,
    pub phase_swap_blocked_s: f64,
    pub phase_migration_s: f64,
    /// simulated draft-cost seconds of speculation (overlaps decode on
    /// the sim clock; reported separately, not part of the partition)
    pub phase_spec_overhead_sim_s: f64,
    run_started: Option<Instant>,
    run_finished: Option<Instant>,
}

impl EngineMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn start_run(&mut self) {
        self.run_started = Some(Instant::now());
    }

    pub fn finish_run(&mut self) {
        self.run_finished = Some(Instant::now());
    }

    pub fn record_request(&mut self, r: &RequestMetrics) {
        self.requests_finished += 1;
        self.tokens_generated += r.generated_tokens as u64;
        if let Some(l) = r.latency() {
            let s = l.as_secs_f64();
            self.latency_wall.add(s);
            self.hist_e2e_wall.record(s);
        }
        if let Some(t) = r.ttft() {
            let s = t.as_secs_f64();
            self.ttft_wall.add(s);
            self.hist_ttft_wall.record(s);
        }
        self.latency_sim.add(r.sim_time_s);
    }

    /// One decode inter-token-latency sample (simulated clock).
    pub fn record_itl_sim(&mut self, s: f64) {
        self.itl_sim.add(s);
        self.hist_itl_sim.record(s);
    }

    /// [`Self::record_request`] plus the per-class TTFT/E2E histograms.
    pub fn record_request_class(&mut self, r: &RequestMetrics, class: Priority) {
        self.record_request(r);
        let h = &mut self.hist_class[class_idx(class)];
        if let Some(l) = r.latency() {
            h.e2e_wall.record(l.as_secs_f64());
        }
        if let Some(t) = r.ttft() {
            h.ttft_wall.record(t.as_secs_f64());
        }
    }

    /// [`Self::record_itl_sim`] plus the per-class ITL histogram.
    pub fn record_itl_sim_class(&mut self, s: f64, class: Priority) {
        self.record_itl_sim(s);
        self.hist_class[class_idx(class)].itl_sim.record(s);
    }

    /// [`Self::record_phases`] plus the per-class queue-wait histogram.
    pub fn record_phases_class(&mut self, b: &PhaseBreakdown, class: Priority) {
        self.record_phases(b);
        self.hist_class[class_idx(class)].queue_wall.record(b.queue_s);
    }

    /// Fold a finished request's phase breakdown into the run totals and
    /// the queue-wait histogram.
    pub fn record_phases(&mut self, b: &PhaseBreakdown) {
        self.phase_queue_s += b.queue_s;
        self.phase_prefill_s += b.prefill_s;
        self.phase_decode_s += b.decode_s;
        self.phase_swap_blocked_s += b.swap_blocked_s;
        self.phase_migration_s += b.migration_s;
        self.phase_spec_overhead_sim_s += b.spec_overhead_sim_s;
        self.hist_queue_wall.record(b.queue_s);
    }

    /// Eq. 11: total latency = sum over requests.
    pub fn total_latency_wall_s(&self) -> f64 {
        self.latency_wall.sum()
    }

    pub fn total_latency_sim_s(&self) -> f64 {
        self.latency_sim.sum()
    }

    /// Eq. 12: tokens generated / generation time (wallclock).
    pub fn throughput_wall(&self) -> f64 {
        match (self.run_started, self.run_finished) {
            (Some(a), Some(b)) if b > a => {
                self.tokens_generated as f64 / (b - a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    /// Eq. 12 on the simulated clock: engine-busy simulated seconds
    /// (prefill + decode + swap transfers the engine waited on; prefetch
    /// hits overlap and cost nothing here).
    pub fn throughput_sim(&self) -> f64 {
        let t = self.sim_prefill_s + self.sim_decode_s + self.sim_swap_blocked_s;
        if t > 0.0 {
            self.tokens_generated as f64 / t
        } else {
            0.0
        }
    }

    /// Draft-token acceptance rate of the speculative verify passes
    /// (0.0 when speculation never ran).
    pub fn acceptance_rate(&self) -> f64 {
        if self.spec_drafted > 0 {
            self.spec_accepted as f64 / self.spec_drafted as f64
        } else {
            0.0
        }
    }

    /// Tokens committed per decode/verify round — 1.0 on the one-token
    /// decode path, up to k+1 under speculation.  The first metric that
    /// can exceed one token per step.
    ///
    /// Run-cumulative: right for a run report card, wrong as a *load
    /// signal* — a replica demoted out of speculation keeps a high
    /// average long after its real rate fell back to ~1 token/round.
    /// Routing reads [`EngineMetrics::tokens_per_step_recent`] instead.
    pub fn tokens_per_step(&self) -> f64 {
        let rounds = self.decode_steps + self.spec_rounds;
        if rounds > 0 {
            self.decode_tokens_committed as f64 / rounds as f64
        } else {
            0.0
        }
    }

    /// Fold one decode/verify round's committed token count into the
    /// windowed rate estimate.  Called once per round next to the
    /// `decode_steps` / `spec_rounds` increment.
    pub fn record_round_rate(&mut self, committed: u64) {
        let sample = committed as f64;
        self.round_rate_samples += 1;
        self.tokens_per_step_ewma = if self.round_rate_samples == 1 {
            sample
        } else {
            (1.0 - ROUND_RATE_EWMA_ALPHA) * self.tokens_per_step_ewma
                + ROUND_RATE_EWMA_ALPHA * sample
        };
    }

    /// Windowed tokens-per-round EWMA — the load signal the router's
    /// `load_score` consumes.  Tracks the *current* commit rate: after
    /// a speculation demotion it decays to ~1 within a few rounds,
    /// where the cumulative average stays inflated for the whole run.
    pub fn tokens_per_step_recent(&self) -> f64 {
        if self.round_rate_samples > 0 {
            self.tokens_per_step_ewma
        } else {
            0.0
        }
    }

    /// Count one decode/verify round at draft length `k` and attribute
    /// its committed tokens to the cost-model regime it ran in.
    pub fn record_spec_round(&mut self, k: usize, committed: u64, memory_bound: Option<bool>) {
        if self.spec_k_hist.len() <= k {
            self.spec_k_hist.resize(k + 1, 0);
        }
        self.spec_k_hist[k] += 1;
        match memory_bound {
            Some(true) => {
                self.rounds_weight_stream_bound += 1;
                self.tokens_weight_stream_bound += committed;
            }
            Some(false) => {
                self.rounds_gemm_bound += 1;
                self.tokens_gemm_bound += committed;
            }
            None => {}
        }
    }

    /// Tokens committed per round inside the weight-stream-bound regime
    /// (0.0 when no round was classified there).
    pub fn tokens_per_step_weight_stream(&self) -> f64 {
        if self.rounds_weight_stream_bound > 0 {
            self.tokens_weight_stream_bound as f64 / self.rounds_weight_stream_bound as f64
        } else {
            0.0
        }
    }

    /// Tokens committed per round inside the GEMM-bound regime.
    pub fn tokens_per_step_gemm(&self) -> f64 {
        if self.rounds_gemm_bound > 0 {
            self.tokens_gemm_bound as f64 / self.rounds_gemm_bound as f64
        } else {
            0.0
        }
    }

    /// Mean fraction of the decode batch actually occupied by running
    /// lanes (batch efficiency, visible from `GET /metrics`).
    pub fn decode_batch_occupancy(&self) -> f64 {
        if self.decode_batch_slots > 0 {
            self.decode_lanes_sum as f64 / self.decode_batch_slots as f64
        } else {
            0.0
        }
    }

    /// Fraction of host-tier resumes the prefetch queue staged ahead of
    /// the scheduler (1.0 = swap latency fully hidden).
    pub fn prefetch_hit_rate(&self) -> f64 {
        let total = self.prefetch_hits + self.prefetch_misses;
        if total > 0 {
            self.prefetch_hits as f64 / total as f64
        } else {
            0.0
        }
    }

    /// L3 overhead share of wallclock (the §Perf target: < 10%).
    pub fn coordinator_overhead_frac(&self) -> f64 {
        let total = self.wall_prefill_s + self.wall_decode_s + self.wall_coordinator_s;
        if total > 0.0 {
            self.wall_coordinator_s / total
        } else {
            0.0
        }
    }

    pub fn to_json(&mut self) -> Value {
        let mut o = Object::new();
        o.insert("requests_finished", self.requests_finished as usize);
        o.insert("tokens_generated", self.tokens_generated as usize);
        o.insert("prefill_steps", self.prefill_steps as usize);
        o.insert("prefill_chunks", self.prefill_chunks as usize);
        o.insert("prefill_tokens_committed", self.prefill_tokens_committed as usize);
        o.insert("chunk_stall_sim_s", self.chunk_stall_s);
        o.insert("decode_steps", self.decode_steps as usize);
        o.insert("preemptions", self.preemptions as usize);
        o.insert("spec_rounds", self.spec_rounds as usize);
        o.insert("spec_drafted", self.spec_drafted as usize);
        o.insert("spec_accepted", self.spec_accepted as usize);
        o.insert("acceptance_rate", self.acceptance_rate());
        o.insert("tokens_per_step", self.tokens_per_step());
        o.insert("tokens_per_step_recent", self.tokens_per_step_recent());
        o.insert("decode_batch_occupancy", self.decode_batch_occupancy());
        // adaptive speculation: live controller state + round histogram
        o.insert("spec_k_current", self.spec_k_current);
        o.insert("spec_ctrl_transitions", self.spec_ctrl_transitions as usize);
        o.insert("spec_acceptance_ewma", self.spec_acceptance_ewma);
        o.insert("spec_regime", self.spec_regime);
        if !self.spec_k_hist.is_empty() {
            let mut hist = Object::new();
            for (k, &n) in self.spec_k_hist.iter().enumerate() {
                hist.insert(format!("{k}"), n as usize);
            }
            o.insert("spec_k_hist", hist);
        }
        if self.rounds_weight_stream_bound > 0 || self.rounds_gemm_bound > 0 {
            o.insert(
                "rounds_weight_stream_bound",
                self.rounds_weight_stream_bound as usize,
            );
            o.insert("rounds_gemm_bound", self.rounds_gemm_bound as usize);
            o.insert(
                "tokens_per_step_weight_stream",
                self.tokens_per_step_weight_stream(),
            );
            o.insert("tokens_per_step_gemm", self.tokens_per_step_gemm());
        }
        o.insert("swap_outs", self.swap_outs as usize);
        o.insert("swap_ins", self.swap_ins as usize);
        o.insert("blocks_swapped_out", self.blocks_swapped_out as usize);
        o.insert("blocks_swapped_in", self.blocks_swapped_in as usize);
        o.insert("bytes_swapped_out", self.bytes_swapped_out as usize);
        o.insert("bytes_swapped_in", self.bytes_swapped_in as usize);
        o.insert("prefetch_hits", self.prefetch_hits as usize);
        o.insert("prefetch_misses", self.prefetch_misses as usize);
        o.insert("prefetch_hit_rate", self.prefetch_hit_rate());
        o.insert("tokens_recomputed", self.tokens_recomputed as usize);
        o.insert(
            "recompute_avoided_tokens",
            self.recompute_avoided_tokens as usize,
        );
        o.insert("migrations_out", self.migrations_out as usize);
        o.insert("migrations_in", self.migrations_in as usize);
        o.insert("migrated_blocks_out", self.migrated_blocks_out as usize);
        o.insert("migrated_blocks_in", self.migrated_blocks_in as usize);
        o.insert("migration_bytes", self.migration_bytes as usize);
        o.insert(
            "migrations_token_fallback",
            self.migrations_token_fallback as usize,
        );
        o.insert("prefix_pulls", self.prefix_pulls as usize);
        o.insert("prefix_pull_blocks", self.prefix_pull_blocks as usize);
        o.insert("prefix_pull_bytes", self.prefix_pull_bytes as usize);
        o.insert(
            "prefix_pull_blocks_out",
            self.prefix_pull_blocks_out as usize,
        );
        o.insert("prefix_pull_stale", self.prefix_pull_stale as usize);
        o.insert("proactive_swap_outs", self.proactive_swap_outs as usize);
        o.insert("sim_swap_s", self.sim_swap_s);
        o.insert("sim_swap_blocked_s", self.sim_swap_blocked_s);
        // per-phase wallclock attribution of finished requests (sums to
        // total_latency_wall_s) + the sim-clock speculation overhead
        o.insert("phase_queue_s", self.phase_queue_s);
        o.insert("phase_prefill_s", self.phase_prefill_s);
        o.insert("phase_decode_s", self.phase_decode_s);
        o.insert("phase_swap_blocked_s", self.phase_swap_blocked_s);
        o.insert("phase_migration_s", self.phase_migration_s);
        o.insert("phase_spec_overhead_sim_s", self.phase_spec_overhead_sim_s);
        o.insert("deadline_cancellations", self.deadline_cancellations as usize);
        // mergeable log-bucketed histograms (exact cluster aggregation)
        let mut hist = Object::new();
        hist.insert("ttft_wall", self.hist_ttft_wall.to_json());
        hist.insert("e2e_wall", self.hist_e2e_wall.to_json());
        hist.insert("itl_sim", self.hist_itl_sim.to_json());
        hist.insert("queue_wall", self.hist_queue_wall.to_json());
        o.insert("hist", hist);
        // the same set split by priority class (merged per class in
        // cluster `/metrics`, exposed with class="..." labels in the
        // Prometheus exposition)
        let mut hc = Object::new();
        for p in Priority::ALL {
            hc.insert(p.name(), self.hist_class[class_idx(p)].to_json());
        }
        o.insert("hist_class", hc);
        if self.itl_sim.count() > 0 {
            o.insert("itl_sim_p50_s", self.itl_sim.p50());
            o.insert("itl_sim_p95_s", self.itl_sim.p95());
        }
        o.insert("throughput_wall_tok_s", self.throughput_wall());
        o.insert("throughput_sim_tok_s", self.throughput_sim());
        o.insert("total_latency_wall_s", self.total_latency_wall_s());
        o.insert("total_latency_sim_s", self.total_latency_sim_s());
        o.insert("latency_wall_p50_s", self.latency_wall.p50());
        o.insert("latency_wall_p99_s", self.latency_wall.p99());
        o.insert("ttft_wall_p50_s", self.ttft_wall.p50());
        o.insert("coordinator_overhead_frac", self.coordinator_overhead_frac());
        o.insert("sim_decode_s", self.sim_decode_s);
        o.insert("sim_prefill_s", self.sim_prefill_s);
        Value::Object(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_lifecycle() {
        let t0 = Instant::now();
        let r = RequestMetrics {
            id: 1,
            prompt_tokens: 10,
            generated_tokens: 5,
            arrival: t0,
            first_token: Some(t0 + Duration::from_millis(10)),
            finished: Some(t0 + Duration::from_millis(50)),
            sim_time_s: 0.123,
        };
        assert_eq!(r.latency().unwrap(), Duration::from_millis(50));
        assert_eq!(r.ttft().unwrap(), Duration::from_millis(10));
    }

    #[test]
    fn chunk_metrics_serialize() {
        let mut m = EngineMetrics::new();
        // empty engines must not emit NaN percentiles
        let j = m.to_json();
        assert!(!j.to_string().contains("itl_sim_p95_s"));
        m.prefill_chunks = 5;
        m.chunk_stall_s = 0.25;
        m.itl_sim.add(0.1);
        m.itl_sim.add(0.2);
        let j = m.to_json();
        assert_eq!(j.req_usize("prefill_chunks").unwrap(), 5);
        assert!((j.req_f64("chunk_stall_sim_s").unwrap() - 0.25).abs() < 1e-12);
        assert!(j.req_f64("itl_sim_p95_s").unwrap() >= j.req_f64("itl_sim_p50_s").unwrap());
    }

    #[test]
    fn phase_breakdowns_and_hists_serialize() {
        let mut m = EngineMetrics::new();
        // the hist object is always present; empty hists carry count 0
        let j = m.to_json();
        let h = j.get("hist").expect("hist object");
        assert_eq!(h.get("ttft_wall").unwrap().req_usize("count").unwrap(), 0);
        let t0 = Instant::now();
        m.record_request(&RequestMetrics {
            id: 7,
            prompt_tokens: 8,
            generated_tokens: 4,
            arrival: t0,
            first_token: Some(t0 + Duration::from_millis(5)),
            finished: Some(t0 + Duration::from_millis(40)),
            sim_time_s: 0.01,
        });
        m.record_itl_sim(0.002);
        m.record_phases(&PhaseBreakdown {
            queue_s: 0.010,
            prefill_s: 0.008,
            decode_s: 0.015,
            swap_blocked_s: 0.005,
            migration_s: 0.002,
            spec_overhead_sim_s: 0.001,
            e2e_s: 0.040,
        });
        let j = m.to_json();
        assert!((j.req_f64("phase_queue_s").unwrap() - 0.010).abs() < 1e-12);
        assert!((j.req_f64("phase_swap_blocked_s").unwrap() - 0.005).abs() < 1e-12);
        assert!((j.req_f64("phase_spec_overhead_sim_s").unwrap() - 0.001).abs() < 1e-12);
        // the five wall phases sum to the request's E2E
        let sum = j.req_f64("phase_queue_s").unwrap()
            + j.req_f64("phase_prefill_s").unwrap()
            + j.req_f64("phase_decode_s").unwrap()
            + j.req_f64("phase_swap_blocked_s").unwrap()
            + j.req_f64("phase_migration_s").unwrap();
        assert!((sum - 0.040).abs() < 1e-12);
        let h = j.get("hist").expect("hist object");
        for key in ["ttft_wall", "e2e_wall", "itl_sim", "queue_wall"] {
            let parsed = LatencyHist::from_json(h.get(key).unwrap()).expect(key);
            assert_eq!(parsed.count(), 1, "{key}");
        }
    }

    #[test]
    fn swap_metrics_serialize_and_hit_rate() {
        let mut m = EngineMetrics::new();
        assert_eq!(m.prefetch_hit_rate(), 0.0, "no resumes yet");
        m.swap_outs = 3;
        m.swap_ins = 3;
        m.prefetch_hits = 2;
        m.prefetch_misses = 1;
        m.tokens_recomputed = 7;
        m.recompute_avoided_tokens = 41;
        m.sim_swap_s = 0.5;
        m.sim_swap_blocked_s = 0.125;
        m.tokens_generated = 10;
        m.sim_decode_s = 0.375;
        let j = m.to_json();
        assert_eq!(j.req_usize("swap_outs").unwrap(), 3);
        assert_eq!(j.req_usize("recompute_avoided_tokens").unwrap(), 41);
        // migration counters ride the same record and serialize by key
        m.migrations_out = 2;
        m.migrations_in = 1;
        m.migrated_blocks_out = 6;
        m.migration_bytes = 4096;
        m.migrations_token_fallback = 1;
        let j = m.to_json();
        assert_eq!(j.req_usize("migrations_out").unwrap(), 2);
        assert_eq!(j.req_usize("migrations_in").unwrap(), 1);
        assert_eq!(j.req_usize("migrated_blocks_out").unwrap(), 6);
        assert_eq!(j.req_usize("migrated_blocks_in").unwrap(), 0);
        assert_eq!(j.req_usize("migration_bytes").unwrap(), 4096);
        assert_eq!(j.req_usize("migrations_token_fallback").unwrap(), 1);
        assert!((j.req_f64("prefetch_hit_rate").unwrap() - 2.0 / 3.0).abs() < 1e-12);
        // blocked swap time counts against Eq. 12; overlapped time doesn't
        assert!((m.throughput_sim() - 10.0 / 0.5).abs() < 1e-9);
    }

    #[test]
    fn spec_metrics_serialize_and_derive() {
        let mut m = EngineMetrics::new();
        assert_eq!(m.acceptance_rate(), 0.0);
        assert_eq!(m.tokens_per_step(), 0.0);
        assert_eq!(m.decode_batch_occupancy(), 0.0);
        // 5 plain decode rounds (1 token each) + 5 verify rounds that
        // committed 17 of 20 drafts plus their 5 correction tokens
        m.decode_steps = 5;
        m.spec_rounds = 5;
        m.spec_drafted = 20;
        m.spec_accepted = 17;
        m.decode_tokens_committed = 5 + 17 + 5;
        m.decode_lanes_sum = 30;
        m.decode_batch_slots = 40;
        assert!((m.acceptance_rate() - 0.85).abs() < 1e-12);
        assert!((m.tokens_per_step() - 2.7).abs() < 1e-12);
        assert!((m.decode_batch_occupancy() - 0.75).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.req_usize("spec_rounds").unwrap(), 5);
        assert_eq!(j.req_usize("spec_accepted").unwrap(), 17);
        assert!((j.req_f64("tokens_per_step").unwrap() - 2.7).abs() < 1e-12);
        assert!((j.req_f64("decode_batch_occupancy").unwrap() - 0.75).abs() < 1e-12);
        assert!((j.req_f64("acceptance_rate").unwrap() - 0.85).abs() < 1e-12);
    }

    #[test]
    fn adaptive_spec_metrics_serialize() {
        let mut m = EngineMetrics::new();
        // no speculation: the histogram and regime split stay out of the
        // JSON entirely
        let j = m.to_json().to_string();
        assert!(!j.contains("spec_k_hist"));
        assert!(!j.contains("rounds_gemm_bound"));
        // a run that spent 2 rounds at k=0 (GEMM-bound), then 3 at k=3
        // (weight-stream-bound) committing 4 tokens each
        m.record_spec_round(0, 1, Some(false));
        m.record_spec_round(0, 1, Some(false));
        for _ in 0..3 {
            m.record_spec_round(3, 4, Some(true));
        }
        m.spec_k_current = 3;
        m.spec_ctrl_transitions = 2;
        m.spec_acceptance_ewma = 0.87;
        m.spec_regime = crate::platform::regime_name(true);
        assert_eq!(m.spec_k_hist, vec![2, 0, 0, 3]);
        assert!((m.tokens_per_step_gemm() - 1.0).abs() < 1e-12);
        assert!((m.tokens_per_step_weight_stream() - 4.0).abs() < 1e-12);
        let j = m.to_json();
        let hist = j.get("spec_k_hist").expect("histogram serialized");
        assert_eq!(hist.req_usize("0").unwrap(), 2);
        assert_eq!(hist.req_usize("3").unwrap(), 3);
        assert_eq!(j.req_usize("spec_k_current").unwrap(), 3);
        assert_eq!(j.req_usize("spec_ctrl_transitions").unwrap(), 2);
        assert!((j.req_f64("spec_acceptance_ewma").unwrap() - 0.87).abs() < 1e-12);
        assert_eq!(j.req_str("spec_regime").unwrap(), "weight-stream-bound");
        assert_eq!(j.req_usize("rounds_weight_stream_bound").unwrap(), 3);
        assert_eq!(j.req_usize("rounds_gemm_bound").unwrap(), 2);
        assert!((j.req_f64("tokens_per_step_weight_stream").unwrap() - 4.0).abs() < 1e-12);
        // a round without a cost model is counted in the histogram only
        m.record_spec_round(1, 2, None);
        assert_eq!(m.spec_k_hist, vec![2, 1, 0, 3]);
        assert_eq!(m.rounds_weight_stream_bound + m.rounds_gemm_bound, 5);
    }

    #[test]
    fn round_rate_ewma_tracks_current_rate_not_run_history() {
        let mut m = EngineMetrics::new();
        assert_eq!(m.tokens_per_step_recent(), 0.0, "no round yet");
        // a long speculative streak: ~4 tokens per verify round
        for _ in 0..50 {
            m.spec_rounds += 1;
            m.decode_tokens_committed += 4;
            m.record_round_rate(4);
        }
        assert!((m.tokens_per_step() - 4.0).abs() < 1e-9);
        assert!((m.tokens_per_step_recent() - 4.0).abs() < 1e-9);
        // demotion to plain decode: 1 token per round from here on
        for _ in 0..25 {
            m.decode_steps += 1;
            m.decode_tokens_committed += 1;
            m.record_round_rate(1);
        }
        // the cumulative average is still badly inflated...
        assert!(m.tokens_per_step() > 2.5, "cumulative stays stale");
        // ...while the EWMA has converged to the true current rate
        assert!(
            m.tokens_per_step_recent() < 1.01,
            "EWMA must track the post-demotion rate, got {}",
            m.tokens_per_step_recent()
        );
        let j = m.to_json();
        assert!(j.req_f64("tokens_per_step_recent").unwrap() < 1.01);
    }

    #[test]
    fn per_class_hists_record_and_serialize() {
        let mut m = EngineMetrics::new();
        let t0 = Instant::now();
        let req = |id: u64, ttft_ms: u64, e2e_ms: u64| RequestMetrics {
            id,
            prompt_tokens: 8,
            generated_tokens: 4,
            arrival: t0,
            first_token: Some(t0 + Duration::from_millis(ttft_ms)),
            finished: Some(t0 + Duration::from_millis(e2e_ms)),
            sim_time_s: 0.01,
        };
        m.record_request_class(&req(1, 5, 40), Priority::Interactive);
        m.record_request_class(&req(2, 50, 400), Priority::Batch);
        m.record_request_class(&req(3, 60, 500), Priority::Batch);
        m.record_itl_sim_class(0.002, Priority::Interactive);
        m.record_phases_class(
            &PhaseBreakdown { queue_s: 0.020, ..Default::default() },
            Priority::Batch,
        );
        m.deadline_cancellations = 2;
        // class hists split; class-blind hists still see the union
        assert_eq!(m.hist_class[class_idx(Priority::Interactive)].ttft_wall.count(), 1);
        assert_eq!(m.hist_class[class_idx(Priority::Batch)].ttft_wall.count(), 2);
        assert_eq!(m.hist_ttft_wall.count(), 3);
        assert_eq!(m.hist_class[class_idx(Priority::Batch)].queue_wall.count(), 1);
        assert_eq!(m.hist_class[class_idx(Priority::Interactive)].itl_sim.count(), 1);
        let j = m.to_json();
        assert_eq!(j.req_usize("deadline_cancellations").unwrap(), 2);
        let hc = j.get("hist_class").expect("hist_class object");
        for class in ["interactive", "batch"] {
            let ch = hc.get(class).expect(class);
            for key in ["ttft_wall", "e2e_wall", "itl_sim", "queue_wall"] {
                LatencyHist::from_json(ch.get(key).unwrap()).expect(key);
            }
        }
        assert_eq!(
            hc.get("batch").unwrap().get("ttft_wall").unwrap().req_usize("count").unwrap(),
            2
        );
    }

    #[test]
    fn eq11_eq12_aggregation() {
        let mut m = EngineMetrics::new();
        m.start_run();
        let t0 = Instant::now();
        for i in 0..4u64 {
            let r = RequestMetrics {
                id: i,
                prompt_tokens: 8,
                generated_tokens: 10,
                arrival: t0,
                first_token: Some(t0),
                finished: Some(t0 + Duration::from_millis(100)),
                sim_time_s: 0.05,
            };
            m.record_request(&r);
        }
        m.sim_decode_s = 0.4;
        m.finish_run();
        assert_eq!(m.requests_finished, 4);
        assert_eq!(m.tokens_generated, 40);
        // Eq. 11: sum of latencies = 0.4s wallclock, 0.2s sim
        assert!((m.total_latency_wall_s() - 0.4).abs() < 1e-6);
        assert!((m.total_latency_sim_s() - 0.2).abs() < 1e-9);
        // Eq. 12 sim: 40 tokens / 0.4 sim-seconds
        assert!((m.throughput_sim() - 100.0).abs() < 1e-9);
        assert!(m.throughput_wall() > 0.0);
        let j = m.to_json();
        assert_eq!(j.req_usize("tokens_generated").unwrap(), 40);
    }
}
