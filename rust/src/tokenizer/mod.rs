//! Byte-level tokenizer, shared vocabulary with the python trainer
//! (`python/compile/data.py`): ids 0..=255 are raw bytes, plus PAD/BOS/EOS.

pub const PAD_ID: u32 = 256;
pub const BOS_ID: u32 = 257;
pub const EOS_ID: u32 = 258;
pub const VOCAB_SIZE: usize = 260;

#[derive(Debug, Clone, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn new() -> Self {
        Tokenizer
    }

    /// Encode UTF-8 text to token ids, optionally wrapping with BOS/EOS.
    pub fn encode(&self, text: &str, bos: bool, eos: bool) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() + 2);
        if bos {
            out.push(BOS_ID);
        }
        out.extend(text.as_bytes().iter().map(|&b| b as u32));
        if eos {
            out.push(EOS_ID);
        }
        out
    }

    /// Decode token ids back to text, dropping specials and replacing
    /// invalid UTF-8 sequences.
    pub fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&id| id < 256)
            .map(|&id| id as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn is_special(&self, id: u32) -> bool {
        id >= 256
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_ascii() {
        let t = Tokenizer::new();
        let ids = t.encode("Q: 2+3=? Answer:", true, true);
        assert_eq!(ids[0], BOS_ID);
        assert_eq!(*ids.last().unwrap(), EOS_ID);
        assert_eq!(t.decode(&ids), "Q: 2+3=? Answer:");
    }

    #[test]
    fn round_trip_utf8() {
        let t = Tokenizer::new();
        let s = "héllo 🌍";
        assert_eq!(t.decode(&t.encode(s, false, false)), s);
    }

    #[test]
    fn matches_python_layout() {
        // python: data.encode("A", bos=True) == [257, 65]
        let t = Tokenizer::new();
        assert_eq!(t.encode("A", true, false), vec![257, 65]);
        assert_eq!(VOCAB_SIZE, 260);
    }

    #[test]
    fn specials_filtered_on_decode() {
        let t = Tokenizer::new();
        assert_eq!(t.decode(&[BOS_ID, 72, 105, EOS_ID, PAD_ID]), "Hi");
        assert!(t.is_special(PAD_ID) && !t.is_special(255));
    }
}
