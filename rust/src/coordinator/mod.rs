//! The serving engine: admission -> continuous batching -> slot-mapping /
//! SkipSet construction -> PJRT step -> sampling -> streaming.
//!
//! This is the L3 request path.  Per [`Engine::step`]:
//!
//! 1. ask the [`Scheduler`] for a round plan — a list of prefill windows
//!    plus the decode batch, under a shared per-step token budget —
//!    subject to [`CacheManager`] admission;
//! 2. commit each prefill window (**chunked prefill**, Opt-Pa step 1):
//!    allocate the window's blocks and build the padded slot mapping (the
//!    **SkipSet** of Eq. 5 materializes here as -1 slots under
//!    `skip_filter` configs; committed earlier windows stay -1 too), run
//!    the prefill graph over the window, and sample token 0 only on the
//!    *final* window of a prompt.  One-shot mode is the single-window
//!    case.  A window that cannot get blocks preempts by recompute or is
//!    retried from its committed offset on a later round;
//! 3. commit the decode batch: reserve one slot per running sequence
//!    (preempting by recompute when the pool is exhausted), build padded
//!    decode inputs, run the decode graph, sample, advance, finish.
//!    Decodes are reserved out of the step budget before prefill windows,
//!    so chunked prefill bounds decode inter-token stalls instead of
//!    monopolizing steps;
//! 4. account wallclock (PJRT vs coordinator) and simulated Z100 time
//!    (platform model) for the paper's Eq. 11/12 metrics, plus per-chunk
//!    accounting (chunk count, inter-chunk stall, simulated decode
//!    inter-token latency) for the Fig. 6/7-style chunking deltas.
//!
//! The engine is generic over [`Backend`] so the whole L3 logic is unit-
//! tested against the contract-checking mock without artifacts.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::EngineConfig;
use crate::kvcache::{CacheManager, SeqId};
use crate::metrics::{EngineMetrics, RequestMetrics};
use crate::platform::{CostModel, SeqCostInput};
use crate::runtime::Backend;
use crate::sampling::{sample, SamplingParams};
use crate::scheduler::{PrefillWork, Scheduler};
use crate::tokenizer::{Tokenizer, EOS_ID, PAD_ID};
use crate::util::rng::Rng;

/// Why a sequence stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxNewTokens,
    MaxContext,
    /// preempted and its prefix no longer fits the prefill graph
    PreemptOverflow,
}

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
    /// benchmarking mode: always generate max_new_tokens (vLLM's
    /// --ignore-eos), so configs produce identical token counts
    pub ignore_eos: bool,
}

impl GenRequest {
    pub fn greedy(prompt: impl Into<String>, max_new_tokens: usize) -> Self {
        GenRequest {
            prompt: prompt.into(),
            max_new_tokens,
            sampling: SamplingParams::default(),
            ignore_eos: false,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: SeqId,
    pub prompt: String,
    pub text: String,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub latency_s: f64,
    pub ttft_s: f64,
    pub sim_time_s: f64,
}

#[derive(Debug)]
struct Sequence {
    #[allow(dead_code)]
    id: SeqId,
    /// prompt + generated (the tail token is sampled but not yet decoded)
    tokens: Vec<u32>,
    prompt_len: usize,
    max_new: usize,
    sampling: SamplingParams,
    ignore_eos: bool,
    metrics: RequestMetrics,
    finish: Option<FinishReason>,
    /// simulated clock when this sequence's last prefill chunk finished
    /// (drives the inter-chunk stall metric)
    last_chunk_sim_t: Option<f64>,
}

impl Sequence {
    fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }
}

pub struct Engine<B: Backend> {
    pub backend: B,
    cache: CacheManager,
    sched: Scheduler,
    seqs: HashMap<SeqId, Sequence>,
    /// sequences needing (re-)prefill — includes preempted ones
    cost: Option<CostModel>,
    pub metrics: EngineMetrics,
    tokenizer: Tokenizer,
    rng: Rng,
    next_id: SeqId,
    pub cfg: EngineConfig,
    finished: Vec<GenResult>,
    /// simulated prefill time accumulated inside the current step (feeds
    /// the decode inter-token latency samples: a decode that waited for a
    /// prefill window pays for it)
    step_prefill_sim_s: f64,
}

impl<B: Backend> Engine<B> {
    pub fn new(backend: B, mut cfg: EngineConfig) -> Self {
        let geometry = *backend.geometry();
        let max_batch = cfg.max_batch.min(geometry.max_batch);
        // engine contexts are sim-scale; map them to the paper's ShareGPT
        // operating point for the Z100 accounting (platform/mod.rs docs)
        let cost = Some(
            CostModel::for_preset(backend.preset(), geometry.block_size).with_ctx_scale(8.0),
        );
        if cfg.chunked_prefill && !backend.supports_chunked_prefill() {
            // a mid-prompt window would fail on every retry and wedge the
            // serving loop; degrade to one-shot prefill instead
            crate::log_warn!(
                "backend lacks a chunked prefill graph; falling back to one-shot prefill"
            );
            cfg.chunked_prefill = false;
        }
        // budget at least one above the decode batch, so a full decode
        // round always leaves room for one prefill window (no starvation,
        // and the shared-budget invariant stays strict)
        let mut sched =
            Scheduler::new(max_batch).with_step_budget(cfg.max_prefill_tokens.max(max_batch + 1));
        if cfg.chunked_prefill {
            sched = sched.with_chunked_prefill(cfg.prefill_chunk_tokens);
        }
        Engine {
            cache: CacheManager::new(geometry),
            sched,
            seqs: HashMap::new(),
            cost,
            metrics: EngineMetrics::new(),
            tokenizer: Tokenizer::new(),
            rng: Rng::new(cfg.seed),
            next_id: 1,
            cfg,
            backend,
            finished: Vec::new(),
            step_prefill_sim_s: 0.0,
        }
    }

    /// Disable the simulated-platform accounting (micro-benchmarks).
    pub fn without_cost_model(mut self) -> Self {
        self.cost = None;
        self
    }

    pub fn opt_name(&self) -> &'static str {
        self.backend.opt().name
    }

    pub fn cache_stats(&self) -> crate::kvcache::CacheStats {
        self.cache.stats()
    }

    pub fn num_pending(&self) -> usize {
        self.sched.num_waiting() + self.sched.num_running()
    }

    /// Submit a request; returns its sequence id.
    pub fn submit(&mut self, req: GenRequest) -> Result<SeqId> {
        let tokens = self.tokenizer.encode(&req.prompt, true, false);
        self.submit_tokens(tokens, req.max_new_tokens, req.sampling, req.ignore_eos)
    }

    pub fn submit_tokens(
        &mut self,
        tokens: Vec<u32>,
        max_new: usize,
        sampling: SamplingParams,
        ignore_eos: bool,
    ) -> Result<SeqId> {
        let max_seq = self.backend.geometry().max_seq;
        if tokens.is_empty() {
            bail!("empty prompt");
        }
        if tokens.len() > max_seq {
            bail!("prompt of {} tokens exceeds max_seq {max_seq}", tokens.len());
        }
        let id = self.next_id;
        self.next_id += 1;
        let prompt_len = tokens.len();
        self.seqs.insert(
            id,
            Sequence {
                id,
                tokens,
                prompt_len,
                max_new: max_new.max(1),
                sampling,
                ignore_eos,
                metrics: RequestMetrics {
                    id,
                    prompt_tokens: prompt_len,
                    generated_tokens: 0,
                    arrival: Instant::now(),
                    first_token: None,
                    finished: None,
                    sim_time_s: 0.0,
                },
                finish: None,
                last_chunk_sim_t: None,
            },
        );
        self.sched.submit(id, prompt_len);
        Ok(id)
    }

    /// Advance the engine one scheduling round.  Returns results finished
    /// during the round.
    pub fn step(&mut self) -> Result<Vec<GenResult>> {
        let round_t0 = Instant::now();
        let backend_wall_before = self.metrics.wall_prefill_s + self.metrics.wall_decode_s;
        self.step_prefill_sim_s = 0.0;
        let decision = self.sched.schedule(&self.cache, self.backend.opt());

        for work in decision.prefills.iter().copied() {
            self.run_prefill_work(work)?;
        }

        let decodes: Vec<SeqId> = decision
            .decodes
            .iter()
            .copied()
            .filter(|id| self.seqs.get(id).map(|s| s.finish.is_none()).unwrap_or(false))
            // a prefill window above may have preempted a planned decode;
            // its cache state is gone until re-admission
            .filter(|id| self.cache.has_seq(*id))
            .collect();
        if !decodes.is_empty() {
            self.run_decode(&decodes)?;
        } else if decision.prefills.is_empty() && !self.sched.is_idle() {
            // nothing runnable but work pending: the front request cannot be
            // admitted; make room or fail loudly
            if self.sched.num_running() == 0 {
                bail!(
                    "stuck: {} waiting requests but no admission possible \
                     (pool {} free blocks, step budget {} tokens{})",
                    self.sched.num_waiting(),
                    self.cache.num_free_blocks(),
                    self.cfg.max_prefill_tokens,
                    if self.cfg.chunked_prefill {
                        ", chunked"
                    } else {
                        "; long prompts need chunked_prefill"
                    }
                );
            }
        }

        // L3 overhead = round wallclock minus time spent inside backend calls
        let _ = self.backend.take_exec_time();
        let backend_wall =
            self.metrics.wall_prefill_s + self.metrics.wall_decode_s - backend_wall_before;
        let round = round_t0.elapsed().as_secs_f64();
        self.metrics.wall_coordinator_s += (round - backend_wall).max(0.0);

        Ok(std::mem::take(&mut self.finished))
    }

    /// Drive until all submitted requests finish.
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        let mut out = Vec::new();
        self.metrics.start_run();
        while !self.sched.is_idle() {
            out.extend(self.step()?);
        }
        self.metrics.finish_run();
        Ok(out)
    }

    /// Submit all prompts, run to completion (the batch API).
    pub fn generate(&mut self, reqs: Vec<GenRequest>) -> Result<Vec<GenResult>> {
        for r in reqs {
            self.submit(r)?;
        }
        let mut results = self.run_to_completion()?;
        results.sort_by_key(|r| r.id);
        Ok(results)
    }

    /// Score a prompt: returns the logits row at the last prompt position
    /// (the eval harness' single-token MCQ protocol).  Runs an isolated
    /// prefill; the KV blocks are freed immediately.
    pub fn score_tokens(&mut self, tokens: &[u32]) -> Result<Vec<f32>> {
        let geometry = *self.backend.geometry();
        let max_seq = geometry.max_seq;
        if tokens.is_empty() || tokens.len() > max_seq {
            bail!("score prompt must have 1..={max_seq} tokens");
        }
        let id = self.next_id;
        self.next_id += 1;
        let opt = *self.backend.opt();
        let plan = self.cache.prefill(id, tokens, &opt)?;
        let mut padded = vec![PAD_ID as i32; max_seq];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let t0 = Instant::now();
        let logits =
            self.backend
                .prefill(&padded, tokens.len() as i32, &plan.slot_mapping)?;
        self.metrics.wall_prefill_s += t0.elapsed().as_secs_f64();
        self.metrics.prefill_steps += 1;
        if let Some(cm) = &self.cost {
            self.metrics.sim_prefill_s += cm.prefill(tokens.len(), &opt).total_s;
        }
        self.cache.free_seq(id);
        let vocab = self.backend.preset().vocab;
        let at = (tokens.len() - 1) * vocab;
        Ok(logits[at..at + vocab].to_vec())
    }

    // -----------------------------------------------------------------------

    /// Commit one prefill window: cache blocks + slot mapping, the
    /// backend pass over the window, chunk accounting, and — on the final
    /// window only — sampling of the first generated token.  One-shot
    /// prefill is the `offset == 0, is_final` case.
    fn run_prefill_work(&mut self, work: PrefillWork) -> Result<()> {
        let opt = *self.backend.opt();
        let geometry = *self.backend.geometry();
        let max_seq = geometry.max_seq;
        let id = work.id;

        let Some(seq) = self.seqs.get(&id) else {
            // finished earlier in this round
            return Ok(());
        };
        if seq.finish.is_some() {
            return Ok(());
        }
        if self.sched.prefill_progress(id).is_none() {
            // preempted out of the running set by an earlier window's
            // recompute this round; committing now would leave cache state
            // behind a waiting sequence and poison its re-admission
            return Ok(());
        }
        let tokens = seq.tokens.clone();
        let end = work.offset + work.tokens;
        if tokens.len() > max_seq || end > max_seq {
            // can happen after preemption if the prefix outgrew the graph
            self.finish_seq(id, FinishReason::PreemptOverflow);
            return Ok(());
        }
        if end > tokens.len() {
            bail!(
                "prefill window [{}, {end}) beyond sequence {id} of {} tokens",
                work.offset,
                tokens.len()
            );
        }
        let is_final = end == tokens.len();

        // commit the window, preempting by recompute on pool exhaustion
        // (mirrors the decode path); preempting *ourselves* drops the
        // committed prefix and the sequence re-prefills from offset 0 on
        // a later round
        let plan = loop {
            match self
                .cache
                .prefill_chunk(id, &tokens, work.offset, work.tokens, &opt, is_final)
            {
                Ok(p) => break p,
                Err(_) => {
                    let seqs = &self.seqs;
                    let victim = self
                        .sched
                        .preempt_latest(|v| seqs.get(&v).map(|s| s.tokens.len()).unwrap_or(0));
                    match victim {
                        Some(v) if v != id => {
                            self.preempt_free(v);
                        }
                        Some(v) => {
                            self.preempt_free(v);
                            return Ok(());
                        }
                        None => bail!(
                            "stuck: prefill window of sequence {id} cannot get KV blocks \
                             (pool {} free)",
                            self.cache.num_free_blocks()
                        ),
                    }
                }
            }
        };
        self.sched.record_prefill_progress(id, work.tokens);

        let mut padded = vec![PAD_ID as i32; max_seq];
        for (i, &t) in tokens.iter().take(end).enumerate() {
            padded[i] = t as i32;
        }
        let t0 = Instant::now();
        let logits = self.backend.prefill_chunk(
            &padded,
            work.offset as i32,
            work.tokens as i32,
            &plan.slot_mapping,
        )?;
        self.metrics.wall_prefill_s += t0.elapsed().as_secs_f64();
        self.metrics.prefill_steps += 1;
        let chunked = self.cfg.chunked_prefill;
        if chunked {
            self.metrics.prefill_chunks += 1;
        }

        let sim_s = self.cost.as_ref().map(|cm| {
            if chunked {
                cm.prefill_chunk(work.tokens, work.offset, &opt).total_s
            } else {
                cm.prefill(tokens.len(), &opt).total_s
            }
        });
        // simulated clock before this window lands (for the inter-chunk
        // stall metric below)
        let sim_before = self.metrics.sim_prefill_s + self.metrics.sim_decode_s;
        if let Some(s) = sim_s {
            self.metrics.sim_prefill_s += s;
            self.step_prefill_sim_s += s;
        }

        // sample the first generated token from the last prompt position
        let vocab = self.backend.preset().vocab;
        let seq = self.seqs.get_mut(&id).unwrap();
        if let Some(prev) = seq.last_chunk_sim_t {
            self.metrics.chunk_stall_s += (sim_before - prev).max(0.0);
        }
        seq.last_chunk_sim_t = Some(sim_before + sim_s.unwrap_or(0.0));
        if let Some(s) = sim_s {
            seq.metrics.sim_time_s += s;
        }
        if is_final {
            let at = (end - 1) * vocab;
            let tok = sample(&logits[at..at + vocab], &seq.sampling, &mut self.rng);
            seq.metrics.first_token = Some(Instant::now());
            seq.tokens.push(tok);
            seq.metrics.generated_tokens = seq.generated();
            self.check_finish(id, tok);
        }
        Ok(())
    }

    fn run_decode(&mut self, ids: &[SeqId]) -> Result<()> {
        let opt = *self.backend.opt();
        let geometry = *self.backend.geometry();
        let b = geometry.max_batch;
        let mb = geometry.max_blocks;

        // 1. reserve a slot per sequence, preempting on pool exhaustion
        let mut active: Vec<SeqId> = Vec::with_capacity(ids.len());
        let mut slots: Vec<i32> = Vec::with_capacity(ids.len());
        let mut preempted_now: Vec<SeqId> = Vec::new();
        let allocs_before = self.cache.stats().blocks_used;
        for &id in ids.iter().take(b) {
            if preempted_now.contains(&id) {
                continue;
            }
            loop {
                match self.cache.append_token(id) {
                    Ok((slot, _pos)) => {
                        active.push(id);
                        slots.push(slot);
                        break;
                    }
                    Err(_) => {
                        // out of blocks (or max context): try preempting the
                        // newest running sequence that isn't `id` itself
                        let seq_len = self.cache.seq_len(id);
                        if seq_len + 1 > geometry.max_context() {
                            self.finish_seq(id, FinishReason::MaxContext);
                            break;
                        }
                        let seqs = &self.seqs;
                        let victim = self
                            .sched
                            .preempt_latest(|v| seqs.get(&v).map(|s| s.tokens.len()).unwrap_or(0));
                        match victim {
                            Some(v) if v != id => {
                                self.preempt_free(v);
                                preempted_now.push(v);
                                continue;
                            }
                            _ => {
                                // preempting ourselves or nothing to preempt
                                if let Some(v) = victim {
                                    self.preempt_free(v);
                                    preempted_now.push(v);
                                }
                                break;
                            }
                        }
                    }
                }
            }
        }
        active.retain(|id| !preempted_now.contains(id));
        if active.is_empty() {
            return Ok(());
        }
        let new_blocks = self.cache.stats().blocks_used.saturating_sub(allocs_before);

        // 2. build padded decode inputs
        let mut token_ids = vec![PAD_ID as i32; b];
        let mut positions = vec![0i32; b];
        let mut ctx_lens = vec![0i32; b];
        let mut slot_mapping = vec![-1i32; b];
        let mut block_tables = vec![0i32; b * mb];
        let mut cost_inputs: Vec<SeqCostInput> = Vec::with_capacity(active.len());
        for (lane, &id) in active.iter().enumerate() {
            let seq = &self.seqs[&id];
            let ctx = self.cache.seq_len(id); // includes the new token
            token_ids[lane] = *seq.tokens.last().unwrap() as i32;
            positions[lane] = (ctx - 1) as i32;
            ctx_lens[lane] = ctx as i32;
            slot_mapping[lane] = slots[lane];
            let row = self.cache.block_table_row(id);
            block_tables[lane * mb..(lane + 1) * mb].copy_from_slice(&row);
            cost_inputs.push(SeqCostInput {
                ctx_len: ctx,
                allocated_blocks: row_allocated(&row, ctx, geometry.block_size, &opt, geometry.max_seq),
            });
        }

        // 3. execute
        let t0 = Instant::now();
        let logits = self.backend.decode(
            &token_ids,
            &positions,
            &block_tables,
            &ctx_lens,
            &slot_mapping,
        )?;
        self.metrics.wall_decode_s += t0.elapsed().as_secs_f64();
        self.metrics.decode_steps += 1;

        let sim_s = self.cost.as_ref().map(|cm| {
            cm.decode_step(&cost_inputs, &opt, new_blocks, active.len())
                .total_s
        });
        if let Some(s) = sim_s {
            self.metrics.sim_decode_s += s;
            // decode inter-token latency on the simulated clock: each
            // active sequence waited for this step's prefill windows too —
            // the stall chunked prefill exists to bound
            let itl = self.step_prefill_sim_s + s;
            for _ in 0..active.len() {
                self.metrics.itl_sim.add(itl);
            }
        }

        // 4. sample + advance
        let vocab = self.backend.preset().vocab;
        let per_seq_sim = sim_s.map(|s| s / active.len() as f64);
        for (lane, &id) in active.iter().enumerate() {
            let row = &logits[lane * vocab..(lane + 1) * vocab];
            let seq = self.seqs.get_mut(&id).unwrap();
            let tok = sample(row, &seq.sampling, &mut self.rng);
            seq.tokens.push(tok);
            seq.metrics.generated_tokens = seq.generated();
            if let Some(s) = per_seq_sim {
                seq.metrics.sim_time_s += s;
            }
            self.check_finish(id, tok);
        }
        Ok(())
    }

    /// Recompute-preemption bookkeeping for a victim the scheduler just
    /// moved back to waiting: free its cache blocks and reset its chunk
    /// clock so `chunk_stall_s` never counts the requeue span as an
    /// inter-window stall.
    fn preempt_free(&mut self, victim: SeqId) {
        self.cache.free_seq(victim);
        if let Some(seq) = self.seqs.get_mut(&victim) {
            seq.last_chunk_sim_t = None;
        }
        self.metrics.preemptions += 1;
    }

    fn check_finish(&mut self, id: SeqId, last_token: u32) {
        let geometry = *self.backend.geometry();
        let seq = &self.seqs[&id];
        let reason = if last_token == EOS_ID && !seq.ignore_eos {
            Some(FinishReason::Eos)
        } else if seq.generated() >= seq.max_new {
            Some(FinishReason::MaxNewTokens)
        } else if seq.tokens.len() >= geometry.max_context() {
            Some(FinishReason::MaxContext)
        } else {
            None
        };
        if let Some(r) = reason {
            self.finish_seq(id, r);
        }
    }

    fn finish_seq(&mut self, id: SeqId, reason: FinishReason) {
        self.cache.free_seq(id);
        self.sched.finish(id);
        if let Some(mut seq) = self.seqs.remove(&id) {
            seq.metrics.finished = Some(Instant::now());
            seq.finish = Some(reason);
            self.metrics.record_request(&seq.metrics);
            self.metrics.tokens_generated = self.metrics.tokens_generated.max(0);
            let gen_tokens: Vec<u32> = seq.tokens[seq.prompt_len..]
                .iter()
                .copied()
                .filter(|&t| t != EOS_ID)
                .collect();
            self.finished.push(GenResult {
                id,
                prompt: self.tokenizer.decode(&seq.tokens[..seq.prompt_len]),
                text: self.tokenizer.decode(&gen_tokens),
                tokens: seq.tokens.clone(),
                finish: reason,
                prompt_tokens: seq.prompt_len,
                generated_tokens: seq.generated(),
                latency_s: seq
                    .metrics
                    .latency()
                    .map(|d| d.as_secs_f64())
                    .unwrap_or(0.0),
                ttft_s: seq.metrics.ttft().map(|d| d.as_secs_f64()).unwrap_or(0.0),
                sim_time_s: seq.metrics.sim_time_s,
            });
        }
    }
}

/// Blocks the attention kernel would traverse on the baseline: every block
/// the prefill/decode path has populated (padded prefill writes make this
/// the padded span, Eq. 2), vs ceil(ctx/B) for Opt-Pa.
fn row_allocated(
    row: &[i32],
    ctx: usize,
    block_size: usize,
    opt: &crate::config::OptConfig,
    max_seq: usize,
) -> usize {
    let valid = ctx.div_ceil(block_size);
    if opt.skip_filter {
        valid
    } else {
        // baseline padded prefill populated ceil(max_seq/B) blocks
        let padded = max_seq.div_ceil(block_size);
        let _ = row;
        padded.max(valid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, COOPT, ORIGINAL};
    use crate::runtime::mock::MockBackend;

    fn engine(opt: crate::config::OptConfig) -> Engine<MockBackend> {
        let be = MockBackend::new().with_opt(opt);
        let cfg = EngineConfig::new("llama-7b-sim", opt);
        Engine::new(be, cfg)
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine(COOPT);
        e.submit(GenRequest::greedy("Q: 1+1=?", 4)).unwrap();
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.generated_tokens, 4);
        assert_eq!(r.finish, FinishReason::MaxNewTokens);
        assert_eq!(e.cache_stats().blocks_used, 0, "all blocks freed");
        assert!(e.metrics.decode_steps >= 3);
    }

    #[test]
    fn batch_requests_complete_deterministically() {
        let mut e = engine(COOPT);
        let reqs: Vec<GenRequest> = (0..12)
            .map(|i| GenRequest::greedy(format!("prompt number {i}"), 6))
            .collect();
        let results = e.generate(reqs.clone()).unwrap();
        assert_eq!(results.len(), 12);
        for r in &results {
            assert!(r.generated_tokens >= 1);
        }
        // determinism: same engine config -> same outputs
        let mut e2 = engine(COOPT);
        let results2 = e2.generate(reqs).unwrap();
        for (a, b) in results.iter().zip(&results2) {
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn original_config_also_serves() {
        let mut e = engine(ORIGINAL);
        let results = e
            .generate(vec![
                GenRequest::greedy("hello world", 5),
                GenRequest::greedy("second prompt", 5),
            ])
            .unwrap();
        assert_eq!(results.len(), 2);
        // baseline fragments the pool while running but frees at the end
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    #[test]
    fn sim_time_accumulates_and_favors_coopt() {
        let mut mk = |opt| {
            let mut e = engine(opt);
            let reqs: Vec<GenRequest> = (0..6)
                .map(|i| GenRequest::greedy(format!("prompt {i} {}", "x".repeat(40)), 16))
                .collect();
            e.generate(reqs).unwrap();
            (
                e.metrics.sim_prefill_s + e.metrics.sim_decode_s,
                e.metrics.tokens_generated,
            )
        };
        let (t_orig, n1) = mk(ORIGINAL);
        let (t_coopt, n2) = mk(COOPT);
        assert_eq!(n1, n2);
        assert!(t_coopt < t_orig, "coopt {t_coopt} < original {t_orig}");
    }

    #[test]
    fn preemption_recovers() {
        // tiny pool forces preemption under load
        let geometry = crate::config::CacheGeometry {
            block_size: 4,
            max_blocks: 16,
            num_pool_blocks: 12,
            max_batch: 4,
            max_seq: 32,
        };
        let be = MockBackend::with_geometry(geometry).with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT);
        let mut e = Engine::new(be, cfg);
        let reqs: Vec<GenRequest> = (0..6)
            .map(|i| GenRequest::greedy(format!("pp{i} {}", "y".repeat(16)), 12))
            .collect();
        let results = e.generate(reqs).unwrap();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert!(
                r.generated_tokens >= 1,
                "every request makes progress despite preemption"
            );
        }
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    #[test]
    fn score_returns_vocab_row_and_frees() {
        let mut e = engine(COOPT);
        let toks = Tokenizer::new().encode("Q: 2+2=? Answer:", true, false);
        let row = e.score_tokens(&toks).unwrap();
        assert_eq!(row.len(), e.backend.preset().vocab);
        assert_eq!(e.cache_stats().blocks_used, 0);
        // deterministic
        let row2 = e.score_tokens(&toks).unwrap();
        assert_eq!(row, row2);
    }

    #[test]
    fn rejects_oversized_prompt() {
        let mut e = engine(COOPT);
        let huge = "z".repeat(4000);
        assert!(e.submit(GenRequest::greedy(huge, 4)).is_err());
    }

    fn chunked_engine(chunk: usize, budget: usize) -> Engine<MockBackend> {
        let be = MockBackend::new().with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_chunked_prefill(chunk)
            .with_step_budget(budget);
        Engine::new(be, cfg)
    }

    #[test]
    fn chunked_prefill_spans_steps_and_defers_sampling() {
        // 40-token prompt, 16-token chunks (= block size): three windows
        let mut e = chunked_engine(16, 64);
        let toks: Vec<u32> = (1..=40).collect();
        let id = e
            .submit_tokens(toks, 4, SamplingParams::default(), false)
            .unwrap();
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].id, id);
        assert_eq!(results[0].generated_tokens, 4);
        assert_eq!(
            e.backend.chunk_trace,
            vec![(0, 16), (16, 16), (32, 8)],
            "windows resume from the committed offset"
        );
        assert_eq!(e.metrics.prefill_chunks, 3);
        assert!(e.metrics.chunk_stall_s >= 0.0);
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    #[test]
    fn chunked_greedy_output_matches_oneshot() {
        let reqs: Vec<GenRequest> = (0..4)
            .map(|i| GenRequest::greedy(format!("prompt {i} {}", "q".repeat(30 + i)), 6))
            .collect();
        let mut one = engine(COOPT);
        let base = one.generate(reqs.clone()).unwrap();
        let mut chk = chunked_engine(8, 24);
        let ours = chk.generate(reqs).unwrap();
        assert_eq!(base.len(), ours.len());
        for (a, b) in base.iter().zip(&ours) {
            assert_eq!(a.tokens, b.tokens, "chunked ≡ one-shot greedy (seq {})", a.id);
            assert_eq!(a.finish, b.finish);
        }
        assert!(chk.metrics.prefill_chunks > 4, "long prompts actually chunked");
        assert_eq!(chk.cache_stats().blocks_used, 0);
    }

    #[test]
    fn chunked_falls_back_on_backends_without_chunk_support() {
        // a backend that leaves the trait defaults in place (like the
        // one-shot PJRT graphs) must not be driven with mid-prompt
        // windows — the engine degrades to one-shot scheduling
        struct OneShotOnly(MockBackend);
        impl Backend for OneShotOnly {
            fn preset(&self) -> &crate::config::ModelPreset {
                self.0.preset()
            }
            fn geometry(&self) -> &crate::config::CacheGeometry {
                self.0.geometry()
            }
            fn opt(&self) -> &crate::config::OptConfig {
                self.0.opt()
            }
            fn prefill(&mut self, t: &[i32], l: i32, s: &[i32]) -> Result<Vec<f32>> {
                self.0.prefill(t, l, s)
            }
            fn decode(
                &mut self,
                t: &[i32],
                p: &[i32],
                b: &[i32],
                c: &[i32],
                s: &[i32],
            ) -> Result<Vec<f32>> {
                self.0.decode(t, p, b, c, s)
            }
            fn reset_cache(&mut self) -> Result<()> {
                self.0.reset_cache()
            }
            fn take_exec_time(&mut self) -> std::time::Duration {
                self.0.take_exec_time()
            }
        }
        let be = OneShotOnly(MockBackend::new().with_opt(COOPT));
        let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_chunked_prefill(8);
        let mut e = Engine::new(be, cfg);
        assert!(!e.cfg.chunked_prefill, "degraded to one-shot scheduling");
        let r = e
            .generate(vec![GenRequest::greedy("fallback still serves", 4)])
            .unwrap();
        assert_eq!(r[0].generated_tokens, 4);
        assert_eq!(e.metrics.prefill_chunks, 0);
    }

    #[test]
    fn chunked_mixes_prefill_windows_with_decode_batches() {
        let mut e = chunked_engine(16, 24);
        // two short streams keep decoding while a long prompt prefills
        e.submit(GenRequest::greedy("stream a", 20)).unwrap();
        e.submit(GenRequest::greedy("stream b", 20)).unwrap();
        let long: Vec<u32> = (1..=100).collect();
        e.submit_tokens(long, 3, SamplingParams::default(), false)
            .unwrap();
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 3);
        // the long prompt took several windows...
        let long_windows: Vec<(i32, i32)> = e
            .backend
            .chunk_trace
            .iter()
            .copied()
            .filter(|&(o, l)| o > 0 || l > 16)
            .collect();
        assert!(long_windows.len() >= 5, "windows: {:?}", e.backend.chunk_trace);
        // ...and the streams decoded in between (interleaving, not phases)
        assert!(e.metrics.decode_steps >= 19);
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    #[test]
    fn coordinator_overhead_measured() {
        let mut e = engine(COOPT);
        e.generate(vec![GenRequest::greedy("measure me", 8)]).unwrap();
        // mock's "backend" time is near zero, so the coordinator share of
        // wallclock must dominate
        assert!(e.metrics.wall_coordinator_s > 0.0);
        assert!(e.metrics.coordinator_overhead_frac() > 0.2);
    }
}
