//! The serving engine: admission -> continuous batching -> slot-mapping /
//! SkipSet construction -> PJRT step -> sampling -> streaming.
//!
//! This is the L3 request path.  Per [`Engine::step`]:
//!
//! 1. ask the [`Scheduler`] for a round plan — a list of prefill windows
//!    plus the decode batch, under a shared per-step token budget —
//!    subject to [`CacheManager`] admission;
//! 2. commit each prefill window (**chunked prefill**, Opt-Pa step 1):
//!    allocate the window's blocks and build the padded slot mapping (the
//!    **SkipSet** of Eq. 5 materializes here as -1 slots under
//!    `skip_filter` configs; committed earlier windows stay -1 too), run
//!    the prefill graph over the window, and sample token 0 only on the
//!    *final* window of a prompt.  One-shot mode is the single-window
//!    case.  A window that cannot get blocks preempts by recompute or is
//!    retried from its committed offset on a later round;
//! 3. commit the decode batch: reserve one slot per running sequence
//!    (preempting when the pool is exhausted), build padded decode
//!    inputs, run the decode graph, sample, advance, finish.
//!    Decodes are reserved out of the step budget before prefill windows,
//!    so chunked prefill bounds decode inter-token stalls instead of
//!    monopolizing steps;
//! 4. account wallclock (PJRT vs coordinator) and simulated Z100 time
//!    (platform model) for the paper's Eq. 11/12 metrics, plus per-chunk
//!    accounting (chunk count, inter-chunk stall, simulated decode
//!    inter-token latency) for the Fig. 6/7-style chunking deltas.
//!
//! **Two-tier KV hierarchy (Opt-KV tier manager).**  With a host pool
//! configured ([`EngineConfig::with_host_pool`]) and a backend that
//! supports KV swap, preemption no longer always drops a victim's blocks:
//! a cost-based policy ([`crate::config::SwapPolicy`]) compares the PCIe
//! round trip of the victim's blocks (FP8 blocks move at half the FP16
//! bytes) against re-running its prefill, and swaps when the transfer is
//! cheaper.  Swapped sequences sit in the scheduler's `Swapped` state and
//! come back through an **async prefetch queue**: at the end of each step
//! the engine stages swap-ins one step ahead of the scheduler (oldest
//! first, capacity- and batch-aware); the next step drains completed
//! prefetches before scheduling, and the sequence resumes decoding at its
//! exact offset — no token is ever recomputed on the swap path.  When
//! nothing is runnable, a demand swap-in (prefetch miss) or, failing
//! that, a drop-to-recompute keeps the engine from wedging.  Backends
//! without swap support degrade to drop-and-recompute at construction.
//!
//! **Speculative decoding (draft-and-verify).**  With
//! [`crate::config::SpecConfig::draft_tokens`] `k > 0` and a backend that
//! supports speculation, a decode round becomes: reserve k+1 KV slots per
//! lane, draft k proposals with a shrunk draft model, score all k+1
//! positions in ONE verify pass (the whole KV cache — the decode
//! bottleneck Opt-KV exists for — is re-read once for up to k+1 token
//! commits), commit the accepted prefix plus one corrected/bonus token,
//! and roll the rejected suffix back
//! ([`crate::kvcache::CacheManager::truncate_seq`]).  Greedy speculation
//! is token-for-token identical to sequential greedy decode; stochastic
//! acceptance preserves the target distribution via standard rejection
//! sampling.  Speculative tokens are charged against the shared per-step
//! budget, so chunked prefill and preemption keep composing; backends
//! without draft/verify degrade to one-token decode at construction.
//!
//! **Adaptive speculation (`--spec-mode adaptive`).**  The draft length
//! need not be a constant: with [`crate::config::SpecMode::Adaptive`]
//! the engine runs a per-step [`SpecController`] that closes the
//! feedback loop between the measured acceptance rate (EWMA over
//! verified positions, global + per-sequence) and the cost model's
//! regime detector ([`CostModel::best_draft_len`]).  Each round, before
//! scheduling, the controller picks `k_t` — cold-start jump to the
//! cost-model optimum, then ±1 bounded steps, instant demotion to plain
//! decode when the batch turns GEMM-bound or acceptance collapses, and
//! sparse re-probing so a transient collapse is not permanent — and the
//! scheduler charges each decode lane exactly `1 + k_lane` of the shared
//! step budget (`Scheduler::set_spec_round`; acceptance-demoted lanes
//! ride along at k = 0 in the same round).  Knobs of record:
//! `--spec-mode fixed|adaptive`, `--spec-k-max` (search bound),
//! `--spec-ewma-alpha` (estimator smoothing); see
//! [`crate::coordinator::spec`] for the decision rule.  The controller
//! changes only *how many* tokens are drafted per round — acceptance
//! stays [`verify_token`], so greedy adaptive speculation remains
//! token-for-token identical to one-token decode while k moves.
//!
//! **Disaggregated prefill/decode (PD) replicas.**  With
//! [`crate::config::ReplicaRole::Prefill`] the engine parks every prompt
//! whose final window just sampled its first token in the scheduler's
//! `Migrating` state instead of decoding it locally
//! ([`Engine::take_handoff_ready`]); the router packages it
//! ([`Engine::make_handoff`]) — KV blocks staged through transient host
//! slots into portable payloads when the cost model prices the PCIe
//! round trip under a re-prefill of the committed prefix, a token-only
//! envelope otherwise — and re-admits it on a decode-capable replica at
//! its exact decode offset ([`Engine::migrate_in_seq`]).  Both paths are
//! token-for-token identical to an unconstrained single replica: the
//! sampled-but-undecoded tail token travels in the envelope and is never
//! re-sampled (a re-prefill window ends one position before it, so the
//! final-window sampling cannot re-run).
//!
//! The engine is generic over [`Backend`] so the whole L3 logic is unit-
//! tested against the contract-checking mock without artifacts.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::{EngineConfig, ReplicaRole, ReqClass, SpecMode, SwapPolicy};
use crate::kvcache::{CacheManager, SeqId};
use crate::metrics::{EngineMetrics, RequestMetrics};
use crate::obs::forecast::{ForecastPlane, ForecastStamp};
use crate::obs::{trace_sampled, FlightRecorder, Phase, PhaseBreakdown, ReqTrace};
use crate::platform::{CostModel, SeqCostInput};
use crate::runtime::Backend;
use crate::sampling::{sample, verify_token, SamplingParams, SpecDecision};
use crate::scheduler::{PrefillWork, Scheduler};
use crate::tokenizer::{Tokenizer, EOS_ID, PAD_ID};
use crate::util::rng::Rng;

pub mod spec;
pub use spec::SpecController;

/// Why a sequence stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxNewTokens,
    MaxContext,
    /// preempted and its prefix no longer fits the prefill graph
    PreemptOverflow,
    /// cancelled at a step boundary: its SLO deadline passed and finishing
    /// would burn a decode lane on an answer nobody is waiting for
    DeadlineExceeded,
}

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
    /// benchmarking mode: always generate max_new_tokens (vLLM's
    /// --ignore-eos), so configs produce identical token counts
    pub ignore_eos: bool,
    /// client-supplied correlation id, echoed in the result, the request
    /// trace, and `/admin/trace` lookups
    pub corr_id: Option<String>,
    /// SLO class: priority lane, optional deadline, optional tenant
    /// (defaults to interactive — untagged traffic is the protected
    /// class, so class-blind callers keep the pre-SLO behaviour)
    pub class: ReqClass,
}

impl GenRequest {
    pub fn greedy(prompt: impl Into<String>, max_new_tokens: usize) -> Self {
        GenRequest {
            prompt: prompt.into(),
            max_new_tokens,
            sampling: SamplingParams::default(),
            ignore_eos: false,
            corr_id: None,
            class: ReqClass::default(),
        }
    }

    pub fn with_class(mut self, class: ReqClass) -> Self {
        self.class = class;
        self
    }
}

/// A replica's live load signals (see [`Engine::load_signals`]).
#[derive(Debug, Clone, Copy)]
pub struct LoadSignals {
    /// requests submitted and not yet finished (waiting+running+swapped)
    pub pending: usize,
    pub free_device_blocks: usize,
    pub total_device_blocks: usize,
    pub free_host_blocks: usize,
    /// tokens committed per decode/verify round (run-cumulative average)
    pub tokens_per_step: f64,
    /// cost-model regime of the last planned decode batch
    pub gemm_bound: bool,
    /// open decode-batch slots right now (`max_batch - running`); the
    /// threaded dispatcher defers hand-offs while every destination
    /// reads zero here instead of burning them on the token fallback
    pub batch_slots_free: usize,
}

/// One KV block's payload travelling in a [`SeqHandoff`] envelope.
#[derive(Debug, Clone)]
pub struct BlockExport {
    /// opaque backend payload handle, staged through a host slot by
    /// [`crate::runtime::Backend::export_block`]
    pub payload: u64,
    /// content+position hash when the block was full and prefix-indexed
    /// on the source — lets the destination reuse an identical block it
    /// already holds instead of importing
    pub hash: Option<u64>,
}

/// A prefix's KV blocks packaged for a cross-replica *pull* (cluster
/// prefix reuse): [`SeqHandoff`] generalized to a bare block range — no
/// sequence travels, only prefix-indexed KV.  Produced by
/// [`Engine::export_prefix`] on the replica the directory names as
/// owner, consumed by [`Engine::pull_commit`] on the destination before
/// the pulled request's prefill is scheduled — prefill then covers only
/// the unmatched tail.
#[derive(Debug, Clone)]
pub struct PrefixPull {
    /// chain depth the directory promised (complete leading blocks)
    pub requested: usize,
    /// exported payloads in chain order, each tagged with its
    /// content+position hash; may stop short of `requested` when the
    /// source evicted blocks before the pull landed (stale directory
    /// entry — the destination re-prefills the difference, exact by
    /// construction)
    pub blocks: Vec<BlockExport>,
}

/// How many engine steps a pulled-prefix block stays pinned waiting for
/// the request that triggered the pull.  Consumed pins release as soon
/// as a prefill reuses the block; unconsumed ones (the routed request
/// died, or routing raced an eviction) expire here so pulled KV can
/// never leak device blocks.
const PULL_PIN_TTL_STEPS: u32 = 256;

/// A sequence packaged for cross-replica migration (disaggregated PD
/// hand-off).  Produced by [`Engine::make_handoff`] on the source,
/// consumed by [`Engine::migrate_in_seq`] on the destination.
#[derive(Debug, Clone)]
pub struct SeqHandoff {
    /// prompt + generated, including the sampled-but-undecoded tail
    pub tokens: Vec<u32>,
    pub prompt_len: usize,
    pub max_new: usize,
    pub sampling: SamplingParams,
    pub ignore_eos: bool,
    /// committed KV length on the source (`tokens.len() - 1`: the tail
    /// token's KV position is unwritten, the decode-path invariant)
    pub resume_len: usize,
    /// source-side preemption-headroom floor, carried so the
    /// destination's re-admission keeps the same guarantee
    pub min_blocks: usize,
    /// KV payloads in block-table order; empty = token-only hand-off
    /// (the destination re-prefills the committed prefix)
    pub blocks: Vec<BlockExport>,
    /// request accounting carried across replicas (arrival, TTFT — the
    /// first token was sampled on the source)
    pub metrics: RequestMetrics,
    /// lifecycle trace carried across replicas: the `Migration` phase
    /// opened on the source stays open through transit, so hand-off time
    /// lands in the destination's per-phase breakdown
    pub trace: ReqTrace,
    /// SLO class carried across replicas: the destination's scheduler and
    /// deadline enforcement keep treating the request as the source did
    pub class: ReqClass,
}

#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: SeqId,
    pub prompt: String,
    pub text: String,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub latency_s: f64,
    pub ttft_s: f64,
    pub sim_time_s: f64,
    /// echo of [`GenRequest::corr_id`]
    pub corr_id: Option<String>,
    /// per-phase latency attribution (queue / prefill / decode /
    /// swap-blocked / migration wallclock partitions `latency_s`;
    /// spec overhead is sim-clock and overlaps decode)
    pub phases: PhaseBreakdown,
    /// echo of [`GenRequest::class`]
    pub class: ReqClass,
}

#[derive(Debug)]
struct Sequence {
    #[allow(dead_code)]
    id: SeqId,
    /// prompt + generated (the tail token is sampled but not yet decoded)
    tokens: Vec<u32>,
    prompt_len: usize,
    max_new: usize,
    sampling: SamplingParams,
    ignore_eos: bool,
    metrics: RequestMetrics,
    finish: Option<FinishReason>,
    /// simulated clock when this sequence's last prefill chunk finished
    /// (drives the inter-chunk stall metric)
    last_chunk_sim_t: Option<f64>,
    /// lifecycle trace: which phase the request is in right now, closed
    /// spans per phase, and (when sampled) the event timeline
    trace: ReqTrace,
    /// SLO class: priority lane, optional deadline, optional tenant
    class: ReqClass,
    /// engine sim clock (prefill + decode seconds) at submission —
    /// deadline enforcement measures simulated elapsed time against this,
    /// so deterministic traces cancel deterministically
    arrival_sim_s: f64,
}

impl Sequence {
    fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }
}

pub struct Engine<B: Backend> {
    pub backend: B,
    cache: CacheManager,
    sched: Scheduler,
    seqs: HashMap<SeqId, Sequence>,
    /// sequences needing (re-)prefill — includes preempted ones
    cost: Option<CostModel>,
    pub metrics: EngineMetrics,
    tokenizer: Tokenizer,
    rng: Rng,
    next_id: SeqId,
    pub cfg: EngineConfig,
    finished: Vec<GenResult>,
    /// simulated prefill time accumulated inside the current step (feeds
    /// the decode inter-token latency samples: a decode that waited for a
    /// prefill window pays for it)
    step_prefill_sim_s: f64,
    /// async prefetch queue: sequences whose swap-in was staged at the end
    /// of the previous step; they rejoin the running set at the start of
    /// the next one (the copy overlaps the step in between)
    in_flight_prefetch: Vec<SeqId>,
    /// paper-scale bytes one swapped block moves over PCIe (metrics)
    swap_block_bytes: f64,
    /// adaptive speculation: the online draft-length controller
    /// (`None` in fixed mode or with speculation off)
    spec_ctl: Option<SpecController>,
    /// this round's draft length, chosen by [`Engine::plan_spec_round`]
    /// before the scheduler runs (fixed mode: the configured constant)
    round_spec_k: usize,
    /// lanes taking the plain one-token path this round (per-lane k = 0:
    /// controller-demoted or too close to max context)
    round_plain: Vec<SeqId>,
    /// cost-model regime of this round's planned decode batch
    round_memory_bound: Option<bool>,
    /// prefill-role hand-off queue: sequences whose final prompt window
    /// landed this step and now sit in the scheduler's `Migrating` state
    /// (KV still resident) until the router packages them
    /// ([`Engine::make_handoff`]) or returns them
    /// ([`Engine::abort_handoff`])
    handoff_ready: Vec<SeqId>,
    /// bounded ring of recent finished-request timelines — the
    /// `GET /admin/trace` payload (`--trace-depth` sizes it)
    recorder: FlightRecorder,
    /// predictive telemetry plane: step-boundary signal ring plus the
    /// self-scoring estimators (length quantiles, burst detector, wait
    /// forecaster).  Inert unless `cfg.forecast.enabled`.
    forecast: ForecastPlane,
}

impl<B: Backend> Engine<B> {
    pub fn new(backend: B, mut cfg: EngineConfig) -> Self {
        let geometry = *backend.geometry();
        let max_batch = cfg.max_batch.min(geometry.max_batch);
        // engine contexts are sim-scale; map them to the paper's ShareGPT
        // operating point for the Z100 accounting (platform/mod.rs docs)
        let cost = Some(
            CostModel::for_preset(backend.preset(), geometry.block_size).with_ctx_scale(8.0),
        );
        if cfg.chunked_prefill && !backend.supports_chunked_prefill() {
            // a mid-prompt window would fail on every retry and wedge the
            // serving loop; degrade to one-shot prefill instead
            crate::log_warn!(
                "backend lacks a chunked prefill graph; falling back to one-shot prefill"
            );
            cfg.chunked_prefill = false;
        }
        if cfg.host_pool_blocks > 0 && !backend.supports_kv_swap() {
            // a host tier the backend cannot copy into would wedge every
            // swap; degrade to single-tier drop-and-recompute preemption
            crate::log_warn!(
                "backend lacks KV swap support; host tier disabled \
                 (preemption falls back to drop-and-recompute)"
            );
            cfg.host_pool_blocks = 0;
        }
        if cfg.spec.enabled() && !backend.supports_speculation() {
            // verify would fail on the first round and wedge the serving
            // loop; degrade to one-token decode instead (mirrors the
            // chunked-prefill and swap fallbacks)
            crate::log_warn!(
                "backend lacks draft/verify support; speculative decoding disabled \
                 (one-token decode)"
            );
            cfg.spec.disable();
        }
        // budget at least one above the decode batch, so a full decode
        // round always leaves room for one prefill window (no starvation,
        // and the shared-budget invariant stays strict).  Speculation
        // deliberately does NOT raise this floor: a user's tight
        // prefill budget keeps binding (the speculative reserve can eat
        // the whole budget, in which case the scheduler's one-token
        // progress floor still advances prefill)
        let mut sched =
            Scheduler::new(max_batch).with_step_budget(cfg.max_prefill_tokens.max(max_batch + 1));
        if cfg.chunked_prefill {
            sched = sched.with_chunked_prefill(cfg.prefill_chunk_tokens);
        }
        if cfg.spec.enabled() {
            // worst-case charge until the first plan_spec_round; adaptive
            // mode re-sets the per-lane charge every round
            sched = sched.with_speculation(cfg.spec.max_draft());
        }
        sched = sched.with_interactive_reserve(cfg.slo.interactive_prefill_reserve);
        let mut cache = CacheManager::new(geometry);
        if cfg.host_pool_blocks > 0 {
            cache.enable_host_tier(cfg.host_pool_blocks);
        }
        let swap_block_bytes = cost
            .as_ref()
            .map(|cm| cm.swap_block_bytes(backend.opt()))
            .unwrap_or(0.0);
        let spec_ctl = if cfg.spec.enabled() && cfg.spec.mode == SpecMode::Adaptive {
            Some(SpecController::new(&cfg.spec))
        } else {
            None
        };
        let recorder = FlightRecorder::new(cfg.trace_depth);
        let forecast = ForecastPlane::new(cfg.forecast);
        Engine {
            cache,
            sched,
            seqs: HashMap::new(),
            cost,
            metrics: EngineMetrics::new(),
            tokenizer: Tokenizer::new(),
            rng: Rng::new(cfg.seed),
            next_id: 1,
            cfg,
            backend,
            finished: Vec::new(),
            step_prefill_sim_s: 0.0,
            in_flight_prefetch: Vec::new(),
            swap_block_bytes,
            spec_ctl,
            round_spec_k: 0,
            round_plain: Vec::new(),
            round_memory_bound: None,
            handoff_ready: Vec::new(),
            recorder,
            forecast,
        }
    }

    /// The adaptive controller's chosen-k decision trace (bench
    /// evidence; empty in fixed mode).
    pub fn spec_k_trace(&self) -> Vec<u8> {
        self.spec_ctl
            .as_ref()
            .map(|c| c.k_trace().to_vec())
            .unwrap_or_default()
    }

    /// Disable the simulated-platform accounting (micro-benchmarks).
    pub fn without_cost_model(mut self) -> Self {
        self.cost = None;
        self
    }

    pub fn opt_name(&self) -> &'static str {
        self.backend.opt().name
    }

    pub fn cache_stats(&self) -> crate::kvcache::CacheStats {
        self.cache.stats()
    }

    /// Host-tier occupancy (Opt-KV tier manager).
    pub fn tier_stats(&self) -> crate::kvcache::tier::TierStats {
        self.cache.tier_stats()
    }

    /// Live load signals for the multi-replica router — ONE derivation
    /// shared by the sync bench/test driver ([`crate::router::Router`])
    /// and the serving snapshot publisher
    /// ([`crate::server::MetricsSnapshot`]), so what CI benchmarks and
    /// what production routes on can never drift apart.
    pub fn load_signals(&self) -> LoadSignals {
        let cs = self.cache.stats();
        let ts = self.cache.tier_stats();
        LoadSignals {
            pending: self.num_pending(),
            free_device_blocks: cs.blocks_total.saturating_sub(cs.blocks_used),
            total_device_blocks: cs.blocks_total,
            free_host_blocks: ts.host_capacity_blocks.saturating_sub(ts.host_used_blocks),
            tokens_per_step: self.metrics.tokens_per_step_recent(),
            gemm_bound: self.metrics.spec_regime == crate::platform::regime_name(false),
            batch_slots_free: self.sched.max_batch().saturating_sub(self.sched.num_running()),
        }
    }

    /// Engine metrics plus cache/tier stats as one JSON object — the
    /// `GET /metrics` payload.
    pub fn stats_json(&mut self) -> crate::util::json::Value {
        let cs = self.cache.stats();
        let ts = self.cache.tier_stats();
        let mut v = self.metrics.to_json();
        if let crate::util::json::Value::Object(o) = &mut v {
            o.insert("cache_blocks_total", cs.blocks_total);
            o.insert("cache_blocks_used", cs.blocks_used);
            o.insert("cache_fragmentation", cs.fragmentation);
            o.insert("cache_prefix_hits", cs.prefix_hits as usize);
            o.insert("host_pool_blocks", ts.host_capacity_blocks);
            o.insert("host_blocks_used", ts.host_used_blocks);
            o.insert("host_blocks_peak", ts.host_used_peak_blocks);
            o.insert("swapped_seqs", ts.swapped_seqs);
            o.insert("pinned_shared_blocks", ts.pinned_shared_blocks);
            o.insert("pulled_prefix_pins", self.cache.num_pulled_pins());
            o.insert("replica_role", self.cfg.role.name());
            self.forecast.metrics_json(o);
        }
        v
    }

    /// Forecast-plane dump — the per-replica half of the
    /// `GET /admin/forecast` payload: signal ring plus estimator states.
    pub fn forecast_json(&self) -> crate::util::json::Value {
        self.forecast.to_json()
    }

    /// The predictive telemetry plane (read side: tests and the bench
    /// harness inspect estimator calibration through this).
    pub fn forecast_plane(&self) -> &ForecastPlane {
        &self.forecast
    }

    /// Mutable plane access — property tests poison estimators through
    /// this to prove out-of-band coverage falls back to reactive control.
    pub fn forecast_plane_mut(&mut self) -> &mut ForecastPlane {
        &mut self.forecast
    }

    /// Merge a router-side forecast stamp (queue-wait prediction and any
    /// length hints the router used for admission) onto the request's
    /// trace, so the prediction resolves against actuals at finish.
    pub fn stamp_forecast(&mut self, id: SeqId, stamp: ForecastStamp) {
        if let Some(seq) = self.seqs.get_mut(&id) {
            if stamp.len_p50.is_some() {
                seq.trace.predicted_len_p50 = stamp.len_p50;
            }
            if stamp.len_p90.is_some() {
                seq.trace.predicted_len_p90 = stamp.len_p90;
            }
            if stamp.wait_ms.is_some() {
                seq.trace.predicted_wait_ms = stamp.wait_ms;
            }
        }
    }

    /// Flight-recorder dump — the `GET /admin/trace` payload: recent
    /// finished-request timelines, oldest first, optionally filtered by
    /// engine-assigned id or client correlation id.
    pub fn trace_json(&self, id: Option<u64>, corr: Option<&str>) -> crate::util::json::Value {
        self.recorder.to_json(id, corr)
    }

    pub fn num_pending(&self) -> usize {
        self.sched.num_waiting()
            + self.sched.num_running()
            + self.sched.num_swapped()
            + self.sched.num_migrating()
    }

    /// Sequences parked for cross-replica hand-off (waiting on the
    /// router to collect them, not on this engine's scheduler).
    pub fn num_migrating(&self) -> usize {
        self.sched.num_migrating()
    }

    /// This replica's PD role (scheduling specialization).
    pub fn role(&self) -> ReplicaRole {
        self.cfg.role
    }

    /// Re-role a live replica (the PD autoscaler's lever).  Takes effect
    /// at the next step: a Prefill replica turning Mixed simply stops
    /// parking finished prompts; sequences already parked stay in the
    /// hand-off queue until collected or aborted.
    pub fn set_role(&mut self, role: ReplicaRole) {
        self.cfg.role = role;
    }

    /// Submit a request; returns its sequence id.
    pub fn submit(&mut self, req: GenRequest) -> Result<SeqId> {
        let tokens = self.tokenizer.encode(&req.prompt, true, false);
        let id = self.submit_tokens_class(
            tokens,
            req.max_new_tokens,
            req.sampling,
            req.ignore_eos,
            req.class,
        )?;
        if req.corr_id.is_some() {
            if let Some(seq) = self.seqs.get_mut(&id) {
                seq.trace.corr_id = req.corr_id;
            }
        }
        Ok(id)
    }

    pub fn submit_tokens(
        &mut self,
        tokens: Vec<u32>,
        max_new: usize,
        sampling: SamplingParams,
        ignore_eos: bool,
    ) -> Result<SeqId> {
        self.submit_tokens_class(tokens, max_new, sampling, ignore_eos, ReqClass::default())
    }

    pub fn submit_tokens_class(
        &mut self,
        tokens: Vec<u32>,
        max_new: usize,
        sampling: SamplingParams,
        ignore_eos: bool,
        class: ReqClass,
    ) -> Result<SeqId> {
        let max_seq = self.backend.geometry().max_seq;
        if tokens.is_empty() {
            bail!("empty prompt");
        }
        if tokens.len() > max_seq {
            bail!("prompt of {} tokens exceeds max_seq {max_seq}", tokens.len());
        }
        let id = self.next_id;
        self.next_id += 1;
        let prompt_len = tokens.len();
        let arrival = Instant::now();
        let mut trace = ReqTrace::new(id, arrival, trace_sampled(id, self.cfg.trace_sample));
        trace.class = class.clone();
        let priority = class.priority;
        let tenant = class.tenant.as_deref();
        self.forecast.observe_arrival(tenant);
        // stamp the raw length quantiles (band-independent) so every
        // prediction self-scores at finish even while out of band
        if let Some((p50, p90)) = self.forecast.len_quantiles(tenant) {
            trace.predicted_len_p50 = Some(p50);
            trace.predicted_len_p90 = Some(p90);
        }
        // cold-start the speculation controller's per-lane prior from the
        // tenant's observed acceptance instead of the global optimum
        if let Some(acc) = self.forecast.tenant_acceptance(tenant) {
            if let Some(ctl) = self.spec_ctl.as_mut() {
                ctl.seed_lane(id, acc);
            }
        }
        self.seqs.insert(
            id,
            Sequence {
                id,
                tokens,
                prompt_len,
                max_new: max_new.max(1),
                sampling,
                ignore_eos,
                metrics: RequestMetrics {
                    id,
                    prompt_tokens: prompt_len,
                    generated_tokens: 0,
                    arrival,
                    first_token: None,
                    finished: None,
                    sim_time_s: 0.0,
                },
                finish: None,
                last_chunk_sim_t: None,
                trace,
                class,
                arrival_sim_s: self.sim_now(),
            },
        );
        self.sched.submit_class(id, prompt_len, priority);
        Ok(id)
    }

    /// The engine's simulated clock (prefill + decode seconds committed
    /// so far) — the deterministic time base deadline enforcement uses
    /// alongside wallclock.
    fn sim_now(&self) -> f64 {
        self.metrics.sim_prefill_s + self.metrics.sim_decode_s
    }

    /// Advance the engine one scheduling round.  Returns results finished
    /// during the round.
    pub fn step(&mut self) -> Result<Vec<GenResult>> {
        let round_t0 = Instant::now();
        let backend_wall_before = self.metrics.wall_prefill_s + self.metrics.wall_decode_s;
        self.step_prefill_sim_s = 0.0;
        // prefetches staged at the end of the previous step have landed:
        // swapped sequences rejoin the running set one step ahead of the
        // decode batch that needs them (the copy overlapped that step)
        self.drain_prefetches();
        // deadline enforcement at the step boundary: a request past its
        // SLO deadline frees its lane and KV instead of finishing uselessly
        self.enforce_deadlines();
        // pulled-prefix pins: unpin blocks a prefill consumed last round,
        // expire pulls whose request never arrived (stale routing)
        self.cache.tick_pulled_pins(PULL_PIN_TTL_STEPS);
        // watermark eviction: free device headroom ahead of demand
        self.proactive_evict()?;
        // pick this round's draft length (and per-lane k=0 set) *before*
        // scheduling, so the shared budget charges the k actually in
        // flight — adaptive k shrinking immediately widens the very next
        // step's prefill windows
        self.plan_spec_round();
        let decision = self.sched.schedule(&self.cache, self.backend.opt());

        // stamp Queued→Prefill on every admission (first and re-admission
        // after a drop-recompute preemption alike)
        if !decision.admitted.is_empty() {
            let now = Instant::now();
            for id in &decision.admitted {
                if let Some(seq) = self.seqs.get_mut(id) {
                    seq.trace.transition(now, Phase::Prefill, "admitted");
                }
            }
        }

        for work in decision.prefills.iter().copied() {
            self.run_prefill_work(work)?;
        }

        let decodes: Vec<SeqId> = decision
            .decodes
            .iter()
            .copied()
            .filter(|id| self.seqs.get(id).map(|s| s.finish.is_none()).unwrap_or(false))
            // a prefill window above may have preempted a planned decode;
            // its cache state is gone until re-admission
            .filter(|id| self.cache.has_seq(*id))
            // a prefill-role replica may have parked a planned decode for
            // hand-off in this same round (a one-shot prompt lands in the
            // decode list of the very step that prefills it)
            .filter(|id| !self.handoff_ready.contains(id))
            .collect();
        if !decodes.is_empty() {
            let spec_k = self.round_spec_k;
            let max_ctx = self.backend.geometry().max_context();
            if spec_k > 0 {
                // draft-and-verify: lanes that can take a full k+1-slot
                // reservation speculate; lanes too close to max context —
                // or demoted by the controller's per-lane acceptance
                // estimate — ride along on the one-token path
                let (spec_ids, plain_ids): (Vec<SeqId>, Vec<SeqId>) =
                    decodes.iter().copied().partition(|id| {
                        self.cache.seq_len(*id) + spec_k + 1 <= max_ctx
                            && !self.round_plain.contains(id)
                    });
                if !spec_ids.is_empty() {
                    self.run_spec_decode(&spec_ids, spec_k)?;
                }
                // speculation above may have preempted a planned plain lane
                let plain_ids: Vec<SeqId> = plain_ids
                    .into_iter()
                    .filter(|id| {
                        self.seqs.get(id).map(|s| s.finish.is_none()).unwrap_or(false)
                    })
                    .filter(|id| self.cache.has_seq(*id))
                    .collect();
                if !plain_ids.is_empty() {
                    self.run_decode(&plain_ids)?;
                }
            } else {
                self.run_decode(&decodes)?;
            }
        } else if decision.prefills.is_empty() && !self.sched.is_idle() {
            // nothing runnable but work pending: resume a swapped
            // sequence (prefetch miss), make room, or fail loudly.
            // Parked hand-offs are the router's to collect — the engine
            // is waiting on the dispatcher, not stuck.
            if self.sched.num_running() == 0
                && !self.resume_swapped_now()?
                && self.sched.num_migrating() == 0
                // pulled-prefix pins hold device blocks for a request that
                // has not arrived yet; releasing them frees real capacity,
                // so retry the round before declaring the engine wedged
                && self.cache.release_pulled_pins() == 0
            {
                bail!(
                    "stuck: {} waiting requests but no admission possible \
                     (pool {} free blocks, step budget {} tokens{})",
                    self.sched.num_waiting(),
                    self.cache.num_free_blocks(),
                    self.cfg.max_prefill_tokens,
                    if self.cfg.chunked_prefill {
                        ", chunked"
                    } else {
                        "; long prompts need chunked_prefill"
                    }
                );
            }
        }

        // stage swap-ins one step ahead of the scheduler (async prefetch)
        self.issue_prefetches()?;

        // step-boundary signal sample for the predictive telemetry plane
        // (arrivals accumulated since the last tick feed the burst
        // detector; token counters are run-cumulative, consumers diff)
        self.forecast.tick(
            self.sched.num_waiting(),
            self.sched.num_running(),
            self.metrics.prefill_tokens_committed,
            self.metrics.decode_tokens_committed,
            self.cache.num_free_blocks(),
        );

        // L3 overhead = round wallclock minus time spent inside backend calls
        let _ = self.backend.take_exec_time();
        let backend_wall =
            self.metrics.wall_prefill_s + self.metrics.wall_decode_s - backend_wall_before;
        let round = round_t0.elapsed().as_secs_f64();
        self.metrics.wall_coordinator_s += (round - backend_wall).max(0.0);

        Ok(std::mem::take(&mut self.finished))
    }

    /// Drive until all submitted requests finish.
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        let mut out = Vec::new();
        self.metrics.start_run();
        while !self.sched.is_idle() {
            if self.sched.num_waiting() == 0
                && self.sched.num_running() == 0
                && self.sched.num_swapped() == 0
                && self.sched.num_migrating() > 0
            {
                // nobody is driving the hand-off: spinning here would
                // never terminate, so fail loudly instead
                bail!(
                    "run_to_completion with {} sequence(s) parked for hand-off; \
                     collect them via make_handoff or return them via abort_handoff",
                    self.sched.num_migrating()
                );
            }
            out.extend(self.step()?);
        }
        self.metrics.finish_run();
        Ok(out)
    }

    /// Submit all prompts, run to completion (the batch API).
    pub fn generate(&mut self, reqs: Vec<GenRequest>) -> Result<Vec<GenResult>> {
        for r in reqs {
            self.submit(r)?;
        }
        let mut results = self.run_to_completion()?;
        results.sort_by_key(|r| r.id);
        Ok(results)
    }

    /// Score a prompt: returns the logits row at the last prompt position
    /// (the eval harness' single-token MCQ protocol).  Runs an isolated
    /// prefill; the KV blocks are freed immediately.
    pub fn score_tokens(&mut self, tokens: &[u32]) -> Result<Vec<f32>> {
        let geometry = *self.backend.geometry();
        let max_seq = geometry.max_seq;
        if tokens.is_empty() || tokens.len() > max_seq {
            bail!("score prompt must have 1..={max_seq} tokens");
        }
        let id = self.next_id;
        self.next_id += 1;
        let opt = *self.backend.opt();
        let plan = self.cache.prefill(id, tokens, &opt)?;
        let mut padded = vec![PAD_ID as i32; max_seq];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let t0 = Instant::now();
        let logits =
            self.backend
                .prefill(&padded, tokens.len() as i32, &plan.slot_mapping)?;
        self.metrics.wall_prefill_s += t0.elapsed().as_secs_f64();
        self.metrics.prefill_steps += 1;
        self.metrics.prefill_tokens_committed += tokens.len() as u64;
        if let Some(cm) = &self.cost {
            self.metrics.sim_prefill_s += cm.prefill(tokens.len(), &opt).total_s;
        }
        self.cache.free_seq(id);
        let vocab = self.backend.preset().vocab;
        let at = (tokens.len() - 1) * vocab;
        Ok(logits[at..at + vocab].to_vec())
    }

    // ---- cross-replica hand-off (disaggregated prefill/decode) ------------

    /// Drain the hand-off queue: sequences parked by a prefill-role
    /// replica, awaiting [`Engine::make_handoff`] or
    /// [`Engine::abort_handoff`].
    pub fn take_handoff_ready(&mut self) -> Vec<SeqId> {
        std::mem::take(&mut self.handoff_ready)
    }

    /// Re-park a sequence for a later dispatch round.  Used by the
    /// router when every decode-capable destination is batch-full right
    /// now: deferring keeps the KV hand-off path open (slots free as
    /// destination sequences finish) instead of burning the transfer on
    /// the token fallback.  The sequence stays in the scheduler's
    /// `Migrating` state throughout, so it is never stepped meanwhile.
    pub fn defer_handoff(&mut self, id: SeqId) {
        if !self.handoff_ready.contains(&id) {
            self.handoff_ready.push(id);
        }
    }

    /// Whether a migrated sequence could be admitted straight into the
    /// running batch — the KV path of [`Engine::migrate_in_seq`]; a
    /// full batch forces its token fallback.
    pub fn has_batch_slot(&self) -> bool {
        self.sched.num_running() < self.sched.max_batch()
    }

    /// True when at least one sequence is parked for hand-off.
    pub fn has_handoff_ready(&self) -> bool {
        !self.handoff_ready.is_empty()
    }

    /// Package a parked sequence for migration to another replica.
    ///
    /// The KV path stages every resident block through a transient host
    /// slot into a portable payload (the swap fabric reused as a
    /// transport), taken when the backend supports migration, the host
    /// tier has staging capacity, and the [`SwapPolicy`] prices the PCIe
    /// round trip under re-prefilling the committed prefix (`Always`
    /// forces it, `Never` forbids it, `Auto` asks the cost model —
    /// exactly the swap-vs-recompute rule).  Otherwise the envelope is
    /// token-only and the destination re-prefills.  Either way the
    /// sequence leaves this replica entirely.
    pub fn make_handoff(&mut self, id: SeqId) -> Result<SeqHandoff> {
        let Some(seq) = self.seqs.get(&id) else {
            bail!("hand-off of unknown sequence {id}");
        };
        debug_assert!(seq.finish.is_none(), "finished sequences are not parked");
        let resume_len = seq.tokens.len() - 1;
        let take_kv = if !self.backend.supports_kv_migration() || !self.cache.can_migrate_out(id)
        {
            false
        } else {
            match self.cfg.swap_policy {
                SwapPolicy::Never => false,
                SwapPolicy::Always => true,
                SwapPolicy::Auto => match &self.cost {
                    Some(cm) => cm.swap_beats_recompute(
                        self.cache.seq_blocks(id),
                        resume_len,
                        self.backend.opt(),
                    ),
                    // no platform model: moving bytes beats redoing work
                    None => true,
                },
            }
        };
        if !self.sched.complete_migration(id) {
            bail!("hand-off of sequence {id} that was never parked (begin_migration)");
        }
        self.handoff_ready.retain(|&h| h != id);
        let (blocks, resume_len, min_blocks) = if take_kv {
            let ops = self.cache.migrate_out(id)?;
            debug_assert_eq!(ops.resume_len, resume_len, "committed KV length drifted");
            let mut blocks = Vec::with_capacity(ops.stages.len());
            for (&(blk, slot), &hash) in ops.stages.iter().zip(&ops.hashes) {
                let payload = self.backend.export_block(blk, slot)?;
                self.cache.release_host_slot(slot);
                self.backend.swap_discard(slot)?;
                blocks.push(BlockExport { payload, hash });
            }
            self.metrics.migrations_out += 1;
            self.metrics.migrated_blocks_out += ops.stages.len() as u64;
            self.metrics.migration_bytes +=
                (ops.stages.len() as f64 * self.swap_block_bytes) as u64;
            if let Some(cm) = &self.cost {
                self.metrics.sim_swap_s +=
                    cm.swap_transfer(ops.stages.len(), self.backend.opt()).total_s;
            }
            (blocks, ops.resume_len, ops.min_blocks)
        } else {
            // token-only hand-off: drop residency here; the destination
            // pays the re-prefill (it accounts the recomputed tokens)
            for slot in self.cache.free_seq(id) {
                self.backend.swap_discard(slot)?;
            }
            self.metrics.migrations_token_fallback += 1;
            (Vec::new(), resume_len, 0)
        };
        let mut seq = self.seqs.remove(&id).expect("present per the lookup above");
        // the trace leaves in its Migration phase (opened when the
        // sequence was parked); transit time accrues until the
        // destination admits it
        seq.trace
            .note(Instant::now(), if take_kv { "migrate_out" } else { "migrate_out_tokens" });
        Ok(SeqHandoff {
            tokens: seq.tokens,
            prompt_len: seq.prompt_len,
            max_new: seq.max_new,
            sampling: seq.sampling,
            ignore_eos: seq.ignore_eos,
            resume_len,
            min_blocks,
            blocks,
            metrics: seq.metrics,
            trace: seq.trace,
            class: seq.class,
        })
    }

    /// Return a parked sequence to local decode (no destination could
    /// take it, or the router priced the migration out).  The KV is
    /// still resident; the scheduler re-ranks the sequence among the
    /// running set at its original admission stamp.
    pub fn abort_handoff(&mut self, id: SeqId) -> bool {
        self.handoff_ready.retain(|&h| h != id);
        let aborted = self.sched.abort_migration(id);
        if aborted {
            if let Some(seq) = self.seqs.get_mut(&id) {
                // back to local decode: the prompt is done, KV resident
                seq.trace.transition(Instant::now(), Phase::Decode, "migration_abort");
            }
        }
        aborted
    }

    /// Admit a handed-off sequence on this replica; returns its id here.
    ///
    /// The KV path re-admits decode-ready at the exact source offset:
    /// envelope payloads import into fresh device blocks, blocks whose
    /// hash this replica already holds are reused through the prefix
    /// index.  When the envelope carries no payloads, the backend cannot
    /// import, the batch is full, or the device pool cannot take the
    /// fresh blocks, the sequence falls back to re-prefilling its
    /// committed prefix — semantically identical, just slower: the
    /// re-prefill windows end one position before the sampled tail, so
    /// the first token is never re-sampled.
    pub fn migrate_in_seq(&mut self, h: SeqHandoff) -> Result<SeqId> {
        let max_seq = self.backend.geometry().max_seq;
        if h.tokens.is_empty() || h.resume_len + 1 != h.tokens.len() {
            bail!(
                "malformed hand-off envelope: {} tokens, committed {}",
                h.tokens.len(),
                h.resume_len
            );
        }
        if h.tokens.len() > max_seq {
            bail!(
                "hand-off of {} tokens exceeds max_seq {max_seq}",
                h.tokens.len()
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        let mut kv_landed = false;
        if !h.blocks.is_empty()
            && self.backend.supports_kv_migration()
            && self.sched.num_running() < self.sched.max_batch()
        {
            let hashes: Vec<Option<u64>> = h.blocks.iter().map(|b| b.hash).collect();
            // a full pool is a fallback, not a failure
            if let Ok(ops) = self.cache.migrate_in(id, &hashes, h.resume_len, h.min_blocks) {
                for &(idx, blk) in &ops.imports {
                    self.backend.import_block(blk, h.blocks[idx].payload)?;
                }
                self.sched.admit_migrated(id, h.resume_len, h.class.priority);
                self.metrics.migrations_in += 1;
                self.metrics.migrated_blocks_in += ops.imports.len() as u64;
                self.metrics.migration_bytes +=
                    (ops.imports.len() as f64 * self.swap_block_bytes) as u64;
                if let Some(cm) = &self.cost {
                    self.metrics.sim_swap_s +=
                        cm.swap_transfer(ops.imports.len(), self.backend.opt()).total_s;
                }
                kv_landed = true;
            }
        }
        if !kv_landed {
            // token fallback: the scheduler prefix ends at the committed
            // length, so prefill windows never cover the sampled tail
            // (is_final compares against the full token vector) and the
            // sequence turns decode-ready exactly where the source left it
            if !h.blocks.is_empty() {
                // a KV envelope that failed to land; token-only envelopes
                // were already counted by the source
                self.metrics.migrations_token_fallback += 1;
            }
            self.metrics.tokens_recomputed += h.resume_len as u64;
            self.sched.submit_class(id, h.resume_len, h.class.priority);
        }
        let mut metrics = h.metrics;
        metrics.id = id;
        let mut trace = h.trace;
        trace.id = id;
        if kv_landed {
            // decode-ready at the source offset: Migration closes here
            trace.transition(Instant::now(), Phase::Decode, "migrate_in");
        } else {
            // token fallback re-prefills: back through the waiting queue
            trace.transition(Instant::now(), Phase::Queued, "migrate_in_fallback");
        }
        // the sim clock differs per replica: anchor the deadline so that
        // simulated elapsed = source-accumulated sim time + whatever this
        // replica's clock advances from here
        let arrival_sim_s = self.sim_now() - h.metrics.sim_time_s;
        self.seqs.insert(
            id,
            Sequence {
                id,
                tokens: h.tokens,
                prompt_len: h.prompt_len,
                max_new: h.max_new,
                sampling: h.sampling,
                ignore_eos: h.ignore_eos,
                metrics,
                finish: None,
                last_chunk_sim_t: None,
                trace,
                class: h.class,
                arrival_sim_s,
            },
        );
        Ok(id)
    }

    // ---- cluster-wide prefix reuse (directory-routed KV pulls) ------------

    /// Drain prefix residency deltas for the cluster directory: blocks
    /// committed to the device tier, demoted to host by a swap-out, or
    /// evicted entirely, in occurrence order.  The feed is bounded
    /// (oldest deltas drop when nobody drains) — a lost delta only ever
    /// makes the directory *stale*, and stale entries fall back to
    /// re-prefill at pull time, exact by construction.
    pub fn take_prefix_deltas(&mut self) -> Vec<crate::kvcache::PrefixDelta> {
        self.cache.take_prefix_deltas()
    }

    /// Export the KV of a registered prefix chain for a cross-replica
    /// pull.  Walks `chain` shallow-to-deep and stops at the first hash
    /// no longer resident (the directory was stale for the rest): a
    /// device-resident block stages through a transient host slot
    /// exactly like [`Engine::make_handoff`]'s KV path — but *copies*,
    /// the local sequence keeps its residency — while a host-resident
    /// block exports straight from its swap slot
    /// ([`crate::runtime::Backend::export_host_block`]).  Never fails:
    /// a backend without the migration transport, or a fully stale
    /// chain, just returns an empty envelope and the puller re-prefills.
    pub fn export_prefix(&mut self, chain: &[u64]) -> PrefixPull {
        let mut blocks = Vec::new();
        if self.backend.supports_kv_migration() {
            for &hash in chain {
                let export = if let Some(blk) = self.cache.device_block_for_hash(hash) {
                    let Some(slot) = self.cache.alloc_host_slot() else {
                        break; // no staging capacity; ship what we have
                    };
                    let payload = self.backend.export_block(blk, slot);
                    self.cache.release_host_slot(slot);
                    let _ = self.backend.swap_discard(slot);
                    payload
                } else if let Some(slot) = self.cache.host_slot_for_hash(hash) {
                    self.backend.export_host_block(slot)
                } else {
                    break; // first miss ends the contiguous chain
                };
                match export {
                    Ok(payload) => blocks.push(BlockExport { payload, hash: Some(hash) }),
                    Err(_) => break,
                }
            }
        }
        let n = blocks.len();
        self.metrics.prefix_pull_blocks_out += n as u64;
        if let Some(cm) = &self.cost {
            self.metrics.sim_swap_s += cm.swap_transfer(n, self.backend.opt()).total_s;
        }
        PrefixPull { requested: chain.len(), blocks }
    }

    /// Land a pulled prefix into this replica's cache before the routed
    /// request's prefill is scheduled.  Each payload imports into a
    /// fresh device block committed under its chain hash and *pinned*
    /// until a prefill consumes it through the ordinary prefix-reuse
    /// path ([`CacheManager::commit_pulled_block`]); the request's
    /// prefill then covers only the unmatched tail.  Shortfalls — stale
    /// chain on the source, no transport, pool pressure here — are
    /// counted (`prefix_pull_stale`) and silently re-prefilled; a pull
    /// can slow a request down but never change its tokens.
    pub fn pull_commit(&mut self, pull: PrefixPull) -> Result<()> {
        // prefix reuse exists only under skip_filter configs (the
        // baseline rewrites every slot) and needs the import transport
        let usable = self.backend.opt().skip_filter && self.backend.supports_kv_migration();
        let mut committed = 0usize;
        let mut imported = 0usize;
        if usable {
            for b in &pull.blocks {
                let Some(hash) = b.hash else { break };
                if self.cache.has_prefix_block(hash) {
                    committed += 1; // already resident: nothing to move
                    continue;
                }
                if self.cache.num_free_blocks() <= 2 {
                    break; // keep admission headroom; re-prefill the rest
                }
                let Some(blk) = self.cache.commit_pulled_block(hash) else {
                    break;
                };
                self.backend.import_block(blk, b.payload)?;
                committed += 1;
                imported += 1;
            }
        }
        self.metrics.prefix_pulls += 1;
        self.metrics.prefix_pull_blocks += imported as u64;
        self.metrics.prefix_pull_bytes += (imported as f64 * self.swap_block_bytes) as u64;
        if committed < pull.requested {
            self.metrics.prefix_pull_stale += 1;
        }
        if let Some(cm) = &self.cost {
            let s = cm.swap_transfer(imported, self.backend.opt()).total_s;
            self.metrics.sim_swap_s += s;
            // the pull happens on the request's critical path (before its
            // prefill), so Eq. 12 throughput pays for the transfer — the
            // bench win must clear the cost of moving the bytes
            self.metrics.sim_swap_blocked_s += s;
        }
        Ok(())
    }

    // -----------------------------------------------------------------------

    /// Choose this round's draft length and plain-lane set, and hand the
    /// scheduler the per-lane budget charges, *before* the round is
    /// scheduled.  Fixed mode keeps the configured constant k (the PR 3
    /// behaviour) but still classifies the batch's regime for the
    /// metrics gauges; adaptive mode runs the [`SpecController`]
    /// decision rule over the decode-ready batch.
    fn plan_spec_round(&mut self) {
        self.round_spec_k = 0;
        self.round_plain.clear();
        self.round_memory_bound = None;
        if !self.cfg.spec.enabled() {
            return;
        }
        let opt = *self.backend.opt();
        let geometry = *self.backend.geometry();
        let max_ctx = geometry.max_context();
        let ids: Vec<SeqId> = self
            .sched
            .decode_ready_ids()
            .into_iter()
            .filter(|id| self.cache.has_seq(*id))
            .collect();
        let inputs: Vec<SeqCostInput> = ids
            .iter()
            .map(|&id| {
                let ctx = self.cache.seq_len(id);
                let row = self.cache.block_table_row(id);
                SeqCostInput {
                    ctx_len: ctx,
                    allocated_blocks: row_allocated(
                        &row,
                        ctx,
                        geometry.block_size,
                        &opt,
                        geometry.max_seq,
                    ),
                }
            })
            .collect();
        let (k, mut plain, memory_bound) = match self.spec_ctl.as_mut() {
            Some(ctl) => {
                let plan = ctl.decide(self.cost.as_ref(), &inputs, &ids, &opt);
                (plan.k, plan.plain, plan.memory_bound)
            }
            None => {
                let mb = if inputs.is_empty() {
                    None
                } else {
                    self.cost
                        .as_ref()
                        .map(|cm| cm.decode_is_memory_bound(&inputs, &opt))
                };
                (self.cfg.spec.draft_tokens, Vec::new(), mb)
            }
        };
        // lanes too close to max context cannot take a k+1 reservation;
        // charge them as the plain lanes they will decode as
        if k > 0 {
            for &id in &ids {
                if self.cache.seq_len(id) + k + 1 > max_ctx && !plain.contains(&id) {
                    plain.push(id);
                }
            }
        }
        self.sched.set_spec_round(k, plain.clone());
        self.metrics.spec_k_current = k;
        if let Some(ctl) = &self.spec_ctl {
            self.metrics.spec_ctrl_transitions = ctl.transitions;
            self.metrics.spec_acceptance_ewma = ctl.acceptance();
        }
        if let Some(mb) = memory_bound {
            self.metrics.spec_regime = crate::platform::regime_name(mb);
        }
        self.round_spec_k = k;
        self.round_plain = plain;
        self.round_memory_bound = memory_bound;
    }

    /// Commit one prefill window: cache blocks + slot mapping, the
    /// backend pass over the window, chunk accounting, and — on the final
    /// window only — sampling of the first generated token.  One-shot
    /// prefill is the `offset == 0, is_final` case.
    fn run_prefill_work(&mut self, work: PrefillWork) -> Result<()> {
        let opt = *self.backend.opt();
        let geometry = *self.backend.geometry();
        let max_seq = geometry.max_seq;
        let id = work.id;

        let Some(seq) = self.seqs.get(&id) else {
            // finished earlier in this round
            return Ok(());
        };
        if seq.finish.is_some() {
            return Ok(());
        }
        if self.sched.prefill_progress(id).is_none() {
            // preempted out of the running set by an earlier window's
            // recompute this round; committing now would leave cache state
            // behind a waiting sequence and poison its re-admission
            return Ok(());
        }
        let tokens = seq.tokens.clone();
        let end = work.offset + work.tokens;
        if tokens.len() > max_seq || end > max_seq {
            // can happen after preemption if the prefix outgrew the graph
            self.finish_seq(id, FinishReason::PreemptOverflow);
            return Ok(());
        }
        if end > tokens.len() {
            bail!(
                "prefill window [{}, {end}) beyond sequence {id} of {} tokens",
                work.offset,
                tokens.len()
            );
        }
        let is_final = end == tokens.len();

        // commit the window, preempting on pool exhaustion (mirrors the
        // decode path); the victim exits via swap or recompute per
        // policy.  Preempting *ourselves* either swaps the committed
        // prefix (resumed at the same offset later) or drops it (the
        // sequence re-prefills from offset 0 on a later round)
        let plan = loop {
            match self
                .cache
                .prefill_chunk(id, &tokens, work.offset, work.tokens, &opt, is_final)
            {
                Ok(p) => break p,
                Err(_) => match self.preempt_one(&[])? {
                    Some(v) if v != id => {}
                    Some(_) => return Ok(()),
                    None => {
                        if !self.in_flight_prefetch.is_empty() || self.sched.num_swapped() > 0
                        {
                            // blocks are pinned by host-tier traffic;
                            // retry this window on a later round once the
                            // swapped sequences drain
                            return Ok(());
                        }
                        bail!(
                            "stuck: prefill window of sequence {id} cannot get KV blocks \
                             (pool {} free)",
                            self.cache.num_free_blocks()
                        )
                    }
                },
            }
        };
        self.sched.record_prefill_progress(id, work.tokens);

        let mut padded = vec![PAD_ID as i32; max_seq];
        for (i, &t) in tokens.iter().take(end).enumerate() {
            padded[i] = t as i32;
        }
        let t0 = Instant::now();
        let logits = self.backend.prefill_chunk(
            &padded,
            work.offset as i32,
            work.tokens as i32,
            &plan.slot_mapping,
        )?;
        self.metrics.wall_prefill_s += t0.elapsed().as_secs_f64();
        self.metrics.prefill_steps += 1;
        self.metrics.prefill_tokens_committed += work.tokens as u64;
        let chunked = self.cfg.chunked_prefill;
        if chunked {
            self.metrics.prefill_chunks += 1;
        }

        // blocks reused through the prefix index at the window's leading
        // edge (local prefix hits and cross-replica pulls alike) were
        // never recomputed, so the simulated Eq. 12 prefill covers only
        // the unmatched tail — clamped so at least the final position is
        // always priced (its logits row is always produced).  With zero
        // leading reuse this is byte-identical to the undiscounted cost.
        let reused_tok = (plan.leading_reused * geometry.block_size)
            .min(work.tokens.saturating_sub(1));
        let sim_s = self.cost.as_ref().map(|cm| {
            if chunked {
                cm.prefill_chunk(work.tokens - reused_tok, work.offset + reused_tok, &opt)
                    .total_s
            } else if reused_tok > 0 {
                cm.prefill_chunk(tokens.len() - reused_tok, reused_tok, &opt).total_s
            } else {
                cm.prefill(tokens.len(), &opt).total_s
            }
        });
        // simulated clock before this window lands (for the inter-chunk
        // stall metric below)
        let sim_before = self.metrics.sim_prefill_s + self.metrics.sim_decode_s;
        if let Some(s) = sim_s {
            self.metrics.sim_prefill_s += s;
            self.step_prefill_sim_s += s;
        }

        // sample the first generated token from the last prompt position
        let vocab = self.backend.preset().vocab;
        let seq = self.seqs.get_mut(&id).unwrap();
        if let Some(prev) = seq.last_chunk_sim_t {
            self.metrics.chunk_stall_s += (sim_before - prev).max(0.0);
        }
        seq.last_chunk_sim_t = Some(sim_before + sim_s.unwrap_or(0.0));
        if let Some(s) = sim_s {
            seq.metrics.sim_time_s += s;
            seq.trace.add_sim(s);
        }
        if chunked && !is_final {
            seq.trace.note_now("prefill_chunk");
        }
        if is_final {
            let at = (end - 1) * vocab;
            let tok = sample(&logits[at..at + vocab], &seq.sampling, &mut self.rng);
            seq.metrics.first_token = Some(Instant::now());
            seq.tokens.push(tok);
            seq.metrics.generated_tokens = seq.generated();
            self.check_finish(id, tok);
            if self.cfg.role == ReplicaRole::Prefill
                && self.seqs.get(&id).map(|s| s.finish.is_none()).unwrap_or(false)
                && self.sched.begin_migration(id)
            {
                // prefill replica: prompt done, first token sampled —
                // park the sequence for the router to hand off to a
                // decode-capable replica (KV stays resident until
                // make_handoff packages or abort_handoff returns it)
                self.handoff_ready.push(id);
                if let Some(seq) = self.seqs.get_mut(&id) {
                    seq.trace.transition(Instant::now(), Phase::Migration, "migrate_park");
                }
            } else if let Some(seq) = self.seqs.get_mut(&id) {
                // still alive locally: the prompt is done, decode begins
                seq.trace.transition(Instant::now(), Phase::Decode, "prefill_done");
            }
        }
        Ok(())
    }

    fn run_decode(&mut self, ids: &[SeqId]) -> Result<()> {
        let opt = *self.backend.opt();
        let geometry = *self.backend.geometry();
        let b = geometry.max_batch;
        let mb = geometry.max_blocks;

        // 1. reserve a slot per sequence, preempting on pool exhaustion.
        // (id, slot) stay paired so dropping a lane that was preempted
        // after reserving can never desynchronize the decode inputs.
        let mut lanes: Vec<(SeqId, i32)> = Vec::with_capacity(ids.len());
        let mut preempted_now: Vec<SeqId> = Vec::new();
        let allocs_before = self.cache.stats().blocks_used;
        for &id in ids.iter().take(b) {
            if preempted_now.contains(&id) {
                continue;
            }
            loop {
                match self.cache.append_token(id) {
                    Ok((slot, _pos)) => {
                        lanes.push((id, slot));
                        break;
                    }
                    Err(_) => {
                        // out of blocks (or max context): try preempting the
                        // newest running sequence that isn't `id` itself
                        let seq_len = self.cache.seq_len(id);
                        if seq_len + 1 > geometry.max_context() {
                            self.finish_seq(id, FinishReason::MaxContext);
                            break;
                        }
                        // lanes that already reserved their decode slot
                        // this step must not swap — their reserved slot
                        // is only written by the decode pass below, so a
                        // swap would preserve an unwritten position.
                        // Dropping them is always safe.
                        let appended: Vec<SeqId> = lanes.iter().map(|&(l, _)| l).collect();
                        match self.preempt_one(&appended)? {
                            Some(v) if v != id => {
                                preempted_now.push(v);
                                continue;
                            }
                            Some(v) => {
                                // preempted ourselves
                                preempted_now.push(v);
                                break;
                            }
                            None => break,
                        }
                    }
                }
            }
        }
        lanes.retain(|(id, _)| !preempted_now.contains(id));
        if lanes.is_empty() {
            return Ok(());
        }
        let new_blocks = self.cache.stats().blocks_used.saturating_sub(allocs_before);

        // 2. build padded decode inputs
        let mut token_ids = vec![PAD_ID as i32; b];
        let mut positions = vec![0i32; b];
        let mut ctx_lens = vec![0i32; b];
        let mut slot_mapping = vec![-1i32; b];
        let mut block_tables = vec![0i32; b * mb];
        let mut cost_inputs: Vec<SeqCostInput> = Vec::with_capacity(lanes.len());
        for (lane, &(id, slot)) in lanes.iter().enumerate() {
            let seq = &self.seqs[&id];
            let ctx = self.cache.seq_len(id); // includes the new token
            token_ids[lane] = *seq.tokens.last().unwrap() as i32;
            positions[lane] = (ctx - 1) as i32;
            ctx_lens[lane] = ctx as i32;
            slot_mapping[lane] = slot;
            let row = self.cache.block_table_row(id);
            block_tables[lane * mb..(lane + 1) * mb].copy_from_slice(&row);
            cost_inputs.push(SeqCostInput {
                ctx_len: ctx,
                allocated_blocks: row_allocated(&row, ctx, geometry.block_size, &opt, geometry.max_seq),
            });
        }

        // 3. execute
        let t0 = Instant::now();
        let logits = self.backend.decode(
            &token_ids,
            &positions,
            &block_tables,
            &ctx_lens,
            &slot_mapping,
        )?;
        self.metrics.wall_decode_s += t0.elapsed().as_secs_f64();
        self.metrics.decode_steps += 1;
        self.metrics.decode_tokens_committed += lanes.len() as u64;
        self.metrics.record_round_rate(lanes.len() as u64);
        self.metrics.decode_lanes_sum += lanes.len() as u64;
        self.metrics.decode_batch_slots += self.sched.max_batch() as u64;

        let sim_s = self.cost.as_ref().map(|cm| {
            cm.decode_step(&cost_inputs, &opt, new_blocks, lanes.len())
                .total_s
        });
        if let Some(s) = sim_s {
            self.metrics.sim_decode_s += s;
            // decode inter-token latency on the simulated clock: each
            // active sequence waited for this step's prefill windows too —
            // the stall chunked prefill exists to bound
            let itl = self.step_prefill_sim_s + s;
            for &(id, _) in &lanes {
                let class = self.seqs[&id].class.priority;
                self.metrics.record_itl_sim_class(itl, class);
            }
        }

        // 4. sample + advance
        let vocab = self.backend.preset().vocab;
        let per_seq_sim = sim_s.map(|s| s / lanes.len() as f64);
        for (lane, &(id, _)) in lanes.iter().enumerate() {
            let row = &logits[lane * vocab..(lane + 1) * vocab];
            let seq = self.seqs.get_mut(&id).unwrap();
            let tok = sample(row, &seq.sampling, &mut self.rng);
            seq.tokens.push(tok);
            seq.metrics.generated_tokens = seq.generated();
            if let Some(s) = per_seq_sim {
                seq.metrics.sim_time_s += s;
                seq.trace.add_sim(s);
            }
            seq.trace.note_now("decode_round");
            self.check_finish(id, tok);
        }
        if self.cfg.spec.enabled() {
            // a k=0 round in the histogram + per-regime tokens/step
            self.metrics
                .record_spec_round(0, lanes.len() as u64, self.round_memory_bound);
        }
        Ok(())
    }

    /// One speculative decode round (draft-and-verify) over `ids`:
    ///
    /// 1. reserve `k+1` KV slots per lane (the positions a verify pass
    ///    writes), preempting on pool exhaustion exactly like the decode
    ///    path — a lane that cannot complete its reservation rolls the
    ///    partial window back and degrades to one-token decode;
    /// 2. draft `k` proposals per lane with the backend's draft model;
    /// 3. verify all `k+1` positions per lane in ONE batched pass —
    ///    the whole KV cache is re-read once for up to k+1 commits;
    /// 4. per lane, accept the longest agreeing draft prefix
    ///    ([`verify_token`]: greedy match or stochastic rejection
    ///    sampling), commit it plus one corrected/bonus token, and roll
    ///    the rejected suffix back ([`CacheManager::truncate_seq`]).
    ///
    /// Greedy speculation is token-for-token identical to sequential
    /// greedy decode (the verify rows are the same distributions decode
    /// would have produced); only the step count changes.
    fn run_spec_decode(&mut self, ids: &[SeqId], k: usize) -> Result<()> {
        struct SpecLane {
            id: SeqId,
            /// committed context before the reservation (first fed position)
            base: usize,
            /// the k+1 reserved write slots
            slots: Vec<i32>,
        }

        let opt = *self.backend.opt();
        let geometry = *self.backend.geometry();
        let b = geometry.max_batch;
        let mb = geometry.max_blocks;
        let vocab = self.backend.preset().vocab;

        // 1. reserve k+1 slots per lane, preempting on pool exhaustion
        let mut lanes: Vec<SpecLane> = Vec::with_capacity(ids.len());
        let mut preempted_now: Vec<SeqId> = Vec::new();
        let mut degraded: Vec<SeqId> = Vec::new();
        let allocs_before = self.cache.stats().blocks_used;
        'lane: for &id in ids.iter().take(b) {
            if preempted_now.contains(&id) {
                continue;
            }
            let base = self.cache.seq_len(id);
            let mut slots: Vec<i32> = Vec::with_capacity(k + 1);
            while slots.len() < k + 1 {
                match self.cache.append_token(id) {
                    Ok((slot, _pos)) => slots.push(slot),
                    Err(_) => {
                        // roll the partial reservation back *before*
                        // choosing a victim: with no unwritten slots left,
                        // even a self-preemption may exit via swap, so
                        // mid-speculation preemption stays semantically
                        // invisible.  Lanes that completed their window
                        // still hold unwritten slots and must drop, never
                        // swap — but the victim is always the newest
                        // admission, which sits at or after `id` in the
                        // admission-ordered decode batch, so in practice
                        // completed windows are never chosen.
                        self.cache.truncate_seq(id, base)?;
                        slots.clear();
                        if self.sched.num_running() <= 1 {
                            // alone in the pool: preempting ourselves
                            // would just swap-thrash; the one-token path
                            // needs a fraction of the blocks and always
                            // makes progress
                            degraded.push(id);
                            continue 'lane;
                        }
                        let no_swap: Vec<SeqId> = lanes.iter().map(|l| l.id).collect();
                        match self.preempt_one(&no_swap)? {
                            Some(v) if v != id => {
                                preempted_now.push(v);
                                lanes.retain(|l| l.id != v);
                                continue;
                            }
                            Some(v) => {
                                // preempted ourselves
                                preempted_now.push(v);
                                continue 'lane;
                            }
                            None => {
                                // pool wedged mid-speculation: fall back
                                // to the one-token decode path, which
                                // needs a fraction of the blocks
                                degraded.push(id);
                                continue 'lane;
                            }
                        }
                    }
                }
            }
            lanes.push(SpecLane { id, base, slots });
        }
        lanes.retain(|l| !preempted_now.contains(&l.id));
        if lanes.is_empty() {
            if !degraded.is_empty() {
                return self.run_decode(&degraded);
            }
            return Ok(());
        }
        let new_blocks = self.cache.stats().blocks_used.saturating_sub(allocs_before);

        // 2. draft k proposals per lane
        let n = k + 1;
        let mut token_ids = vec![PAD_ID as i32; b];
        let mut positions = vec![0i32; b];
        let mut draft_ctx = vec![0i32; b];
        for (lane, l) in lanes.iter().enumerate() {
            let seq = &self.seqs[&l.id];
            token_ids[lane] = *seq.tokens.last().unwrap() as i32;
            positions[lane] = l.base as i32;
            draft_ctx[lane] = (l.base + 1) as i32;
        }
        let t0 = Instant::now();
        let (draft_toks, draft_logits) =
            self.backend.draft(&token_ids, &positions, &draft_ctx, k)?;

        // 3. verify all k+1 positions in one batched pass
        let mut v_tokens = vec![PAD_ID as i32; b * n];
        let mut v_slots = vec![-1i32; b * n];
        let mut v_ctx = vec![0i32; b];
        let mut block_tables = vec![0i32; b * mb];
        let mut cost_inputs: Vec<SeqCostInput> = Vec::with_capacity(lanes.len());
        for (lane, l) in lanes.iter().enumerate() {
            v_tokens[lane * n] = token_ids[lane];
            for i in 0..k {
                v_tokens[lane * n + 1 + i] = draft_toks[lane * k + i];
            }
            for (i, &s) in l.slots.iter().enumerate() {
                v_slots[lane * n + i] = s;
            }
            let ctx = self.cache.seq_len(l.id); // base + k + 1
            v_ctx[lane] = ctx as i32;
            let row = self.cache.block_table_row(l.id);
            block_tables[lane * mb..(lane + 1) * mb].copy_from_slice(&row);
            cost_inputs.push(SeqCostInput {
                ctx_len: ctx,
                allocated_blocks: row_allocated(&row, ctx, geometry.block_size, &opt, geometry.max_seq),
            });
        }
        let logits = self
            .backend
            .verify(&v_tokens, &positions, &block_tables, &v_ctx, &v_slots, k)?;
        self.metrics.wall_decode_s += t0.elapsed().as_secs_f64();
        self.metrics.spec_rounds += 1;
        self.metrics.decode_lanes_sum += lanes.len() as u64;
        self.metrics.decode_batch_slots += self.sched.max_batch() as u64;

        // the draft pass is the speculative overhead: decode would have
        // run the verify-sized target pass anyway (trace attribution)
        let sim_parts = self.cost.as_ref().map(|cm| {
            let draft = cm.draft_step(&cost_inputs, &opt, k, self.cfg.spec.shrink);
            let verify = cm.verify_batch(&cost_inputs, &opt, k, new_blocks, lanes.len() * n);
            (draft.total_s, verify.total_s)
        });
        let sim_s = sim_parts.map(|(d, v)| d + v);
        if let Some(s) = sim_s {
            self.metrics.sim_decode_s += s;
            let itl = self.step_prefill_sim_s + s;
            for l in &lanes {
                let class = self.seqs[&l.id].class.priority;
                self.metrics.record_itl_sim_class(itl, class);
            }
        }

        // 4. accept, commit, roll back
        let per_seq_sim = sim_s.map(|s| s / lanes.len() as f64);
        let per_seq_draft = sim_parts.map(|(d, _)| d / lanes.len() as f64);
        let max_ctx = geometry.max_context();
        let policy = self.cfg.spec.policy;
        let mut round_committed = 0u64;
        let mut round_accepted = 0usize;
        let mut round_examined = 0usize;
        for (lane, l) in lanes.iter().enumerate() {
            let id = l.id;
            let (sampling, ignore_eos, max_new, gen_before, len_before) = {
                let s = &self.seqs[&id];
                (s.sampling, s.ignore_eos, s.max_new, s.generated(), s.tokens.len())
            };
            // decide the committed token list: the longest accepted draft
            // prefix, then one corrected (on rejection) or bonus (on full
            // acceptance) token from the target's own distribution
            let mut commit: Vec<u32> = Vec::with_capacity(n);
            let mut accepted_drafts = 0usize;
            let mut rejected = false;
            for i in 0..k {
                let d = draft_toks[lane * k + i] as u32;
                let target = &logits[(lane * n + i) * vocab..(lane * n + i + 1) * vocab];
                let draft = &draft_logits[(lane * k + i) * vocab..(lane * k + i + 1) * vocab];
                match verify_token(d, target, draft, &sampling, policy, &mut self.rng) {
                    SpecDecision::Accept => {
                        commit.push(d);
                        accepted_drafts += 1;
                    }
                    SpecDecision::Reject(c) => {
                        commit.push(c);
                        rejected = true;
                        break;
                    }
                }
            }
            if !rejected {
                // all k drafts accepted: the verify pass's final row is
                // the distribution after d_k — a free (k+1)-th commit.
                // Under the greedy rule (greedy request, or the Greedy
                // deterministic-verification override) the bonus is the
                // argmax like every verified position, so one rule
                // governs the whole round
                let target = &logits[(lane * n + k) * vocab..(lane * n + k + 1) * vocab];
                let tok = if sampling.temperature <= 0.0
                    || policy == crate::config::SpecPolicy::Greedy
                {
                    crate::sampling::argmax(target) as u32
                } else {
                    sample(target, &sampling, &mut self.rng)
                };
                commit.push(tok);
            }
            // stop at the first finish trigger, exactly where sequential
            // decode would have stopped (same checks, same order as
            // `check_finish`)
            let mut take = 0usize;
            for (j, &t) in commit.iter().enumerate() {
                take = j + 1;
                if (t == EOS_ID && !ignore_eos)
                    || gen_before + take >= max_new
                    || len_before + take >= max_ctx
                {
                    break;
                }
            }
            commit.truncate(take);

            // roll back the KV of rejected/unused suffix positions: keep
            // exactly the fed tokens preceding each committed one (the
            // last committed token's KV stays unwritten, the decode-path
            // invariant)
            self.cache.truncate_seq(id, l.base + commit.len())?;

            // feed the controller's acceptance estimator: each examined
            // position is one Bernoulli trial of the per-position rate
            // (pre-cutoff counts — draft quality, not finish artifacts)
            let examined = accepted_drafts + rejected as usize;
            round_accepted += accepted_drafts;
            round_examined += examined;
            if let Some(ctl) = self.spec_ctl.as_mut() {
                ctl.observe_lane(id, accepted_drafts, examined);
            }
            round_committed += commit.len() as u64;

            self.metrics.spec_drafted += k as u64;
            self.metrics.spec_accepted += accepted_drafts.min(commit.len()) as u64;
            self.metrics.decode_tokens_committed += commit.len() as u64;
            let seq = self.seqs.get_mut(&id).unwrap();
            seq.tokens.extend_from_slice(&commit);
            seq.metrics.generated_tokens = seq.generated();
            if let Some(s) = per_seq_sim {
                seq.metrics.sim_time_s += s;
                seq.trace.add_sim(s);
            }
            if let Some(d) = per_seq_draft {
                seq.trace.sim_spec_overhead_s += d;
            }
            seq.trace.note_now("verify_round");
            let last = *commit.last().unwrap();
            self.check_finish(id, last);
        }
        self.metrics
            .record_spec_round(k, round_committed, self.round_memory_bound);
        self.metrics.record_round_rate(round_committed);
        if let Some(ctl) = self.spec_ctl.as_mut() {
            ctl.observe_round(round_accepted, round_examined);
        }

        // lanes whose reservation could not complete take the one-token
        // path this round (no wedge, just a smaller commit)
        let degraded: Vec<SeqId> = degraded
            .into_iter()
            .filter(|id| self.seqs.get(id).map(|s| s.finish.is_none()).unwrap_or(false))
            .filter(|id| self.cache.has_seq(*id))
            .collect();
        if !degraded.is_empty() {
            self.run_decode(&degraded)?;
        }
        Ok(())
    }

    /// Evict one running sequence to make room: the newest admission is
    /// the victim; its exit — host-tier swap or drop-and-recompute — is
    /// chosen per the [`SwapPolicy`] and the platform cost model.
    /// Sequences in `no_swap` (lanes that already reserved an unwritten
    /// decode slot this step) always drop.  Returns the victim id, or
    /// `None` when nothing is evictable.
    fn preempt_one(&mut self, no_swap: &[SeqId]) -> Result<Option<SeqId>> {
        let Some(victim) = self.pick_preempt_victim() else {
            return Ok(None);
        };
        let committed = self.cache.seq_len(victim);
        if !no_swap.contains(&victim) && self.should_swap(victim) {
            // swap exit: sole-owner blocks stream to the host tier; the
            // scheduler keeps the sequence's progress for an exact resume
            let ops = self.cache.swap_out(victim)?;
            for &(blk, slot) in &ops.copies {
                self.backend.swap_out(blk, slot)?;
            }
            self.sched.preempt_swap(victim);
            if let Some(seq) = self.seqs.get_mut(&victim) {
                // remember where to resume (mid-prefill victims return to
                // Prefill, decode-ready ones to Decode)
                seq.trace.resume_phase = seq.trace.cur_phase();
                seq.trace.preemptions += 1;
                seq.trace.transition(Instant::now(), Phase::SwapBlocked, "swap_out");
            }
            self.metrics.swap_outs += 1;
            self.metrics.blocks_swapped_out += ops.copies.len() as u64;
            self.metrics.bytes_swapped_out +=
                (ops.copies.len() as f64 * self.swap_block_bytes) as u64;
            self.metrics.recompute_avoided_tokens += ops.tokens as u64;
            if let Some(cm) = &self.cost {
                self.metrics.sim_swap_s +=
                    cm.swap_transfer(ops.copies.len(), self.backend.opt()).total_s;
            }
        } else {
            // recompute exit: blocks dropped, the whole committed prefix
            // is re-prefilled on re-admission
            let full_len = self.seqs.get(&victim).map(|s| s.tokens.len()).unwrap_or(0);
            self.cache.free_seq(victim);
            self.sched.preempt_drop(victim, full_len);
            if let Some(seq) = self.seqs.get_mut(&victim) {
                seq.trace.preemptions += 1;
                seq.trace.transition(Instant::now(), Phase::Queued, "preempt_drop");
            }
            self.metrics.tokens_recomputed += committed as u64;
        }
        // either exit resets the victim's chunk clock so `chunk_stall_s`
        // never counts the off-device span as an inter-window stall
        if let Some(seq) = self.seqs.get_mut(&victim) {
            seq.last_chunk_sim_t = None;
        }
        self.metrics.preemptions += 1;
        Ok(Some(victim))
    }

    /// Forecast-hinted victim choice: when a lane's tenant has an
    /// in-band length estimator, its predicted work remaining (p90 minus
    /// generated) ranks it — the lane *furthest from finishing* is
    /// evicted first, so the blocks freed stay free longest.  Lanes
    /// without an in-band prediction keep the reactive newest-admission
    /// order; with forecasting off every lane is unhinted and the choice
    /// is bit-identical to [`Scheduler::peek_preempt_victim`].
    fn pick_preempt_victim(&self) -> Option<SeqId> {
        self.sched.peek_preempt_victim_by(|id| {
            let seq = self.seqs.get(&id)?;
            let p90 = self.forecast.len_hint_p90(seq.class.tenant.as_deref())?;
            Some((p90 as u64).saturating_sub(seq.generated() as u64))
        })
    }

    /// The Opt-KV evict-vs-recompute decision for `victim`.
    fn should_swap(&self, victim: SeqId) -> bool {
        if self.cfg.swap_policy == SwapPolicy::Never || !self.cache.has_host_tier() {
            return false;
        }
        // None = not resident or the host pool cannot take it
        let Some(plan) = self.cache.swap_out_plan(victim) else {
            return false;
        };
        match self.cfg.swap_policy {
            SwapPolicy::Always => true,
            SwapPolicy::Never => unreachable!("handled above"),
            SwapPolicy::Auto => match &self.cost {
                Some(cm) => {
                    cm.swap_beats_recompute(plan.host_blocks, plan.tokens, self.backend.opt())
                }
                // no platform model: preserving work beats redoing it
                None => true,
            },
        }
    }

    /// Watermark-based proactive eviction (`--evict-watermark`, default
    /// off): when device free blocks dip below the low watermark, swap
    /// the preemption-order victim's sole-owner blocks to the host tier
    /// *ahead of demand*, so admission-time prefix pulls and prefill
    /// windows find headroom instead of stalling on a synchronous
    /// eviction.  Swap-only — a proactive exit never drops KV to
    /// recompute (that would trade idle headroom for guaranteed work) —
    /// and at most one victim moves per step so the PCIe traffic stays
    /// bounded.  Counted separately as `proactive_swap_outs`.
    fn proactive_evict(&mut self) -> Result<()> {
        // a scored burst detector raises the configured watermark so
        // headroom opens *ahead* of the arrival wave (forecast-driven
        // control; reverts to the plain knob when out of band)
        let wm = self.forecast.effective_watermark(self.cfg.evict_watermark);
        if wm == 0 || !self.cache.has_host_tier() || self.cache.num_free_blocks() >= wm {
            return Ok(());
        }
        if self.sched.num_running() < 2 {
            // never park the only runnable sequence: nothing would be
            // left to spend the freed blocks on
            return Ok(());
        }
        let Some(victim) = self.pick_preempt_victim() else {
            return Ok(());
        };
        if !self.should_swap(victim) {
            return Ok(());
        }
        let ops = self.cache.swap_out(victim)?;
        for &(blk, slot) in &ops.copies {
            self.backend.swap_out(blk, slot)?;
        }
        self.sched.preempt_swap(victim);
        if let Some(seq) = self.seqs.get_mut(&victim) {
            seq.trace.resume_phase = seq.trace.cur_phase();
            seq.trace.preemptions += 1;
            seq.trace
                .transition(Instant::now(), Phase::SwapBlocked, "proactive_swap_out");
            seq.last_chunk_sim_t = None;
        }
        self.metrics.preemptions += 1;
        self.metrics.swap_outs += 1;
        self.metrics.proactive_swap_outs += 1;
        self.metrics.blocks_swapped_out += ops.copies.len() as u64;
        self.metrics.bytes_swapped_out +=
            (ops.copies.len() as f64 * self.swap_block_bytes) as u64;
        self.metrics.recompute_avoided_tokens += ops.tokens as u64;
        if let Some(cm) = &self.cost {
            self.metrics.sim_swap_s +=
                cm.swap_transfer(ops.copies.len(), self.backend.opt()).total_s;
        }
        Ok(())
    }

    /// Execute a swap-in end to end (cache metadata + backend copies);
    /// returns the number of blocks moved.
    fn swap_in_seq(&mut self, id: SeqId) -> Result<usize> {
        let ops = self.cache.swap_in(id)?;
        for &(slot, blk) in &ops.copies {
            self.backend.swap_in(slot, blk)?;
        }
        let n = ops.copies.len();
        self.metrics.swap_ins += 1;
        self.metrics.blocks_swapped_in += n as u64;
        self.metrics.bytes_swapped_in += (n as f64 * self.swap_block_bytes) as u64;
        if let Some(cm) = &self.cost {
            self.metrics.sim_swap_s += cm.swap_transfer(n, self.backend.opt()).total_s;
        }
        Ok(n)
    }

    /// Start of step: prefetches staged last step have completed; their
    /// sequences rejoin the running set (their swap latency overlapped
    /// the intervening step — a prefetch hit).
    fn drain_prefetches(&mut self) {
        for id in std::mem::take(&mut self.in_flight_prefetch) {
            if self.sched.resume_swapped(id) {
                self.metrics.prefetch_hits += 1;
                if let Some(seq) = self.seqs.get_mut(&id) {
                    let back = seq.trace.resume_phase;
                    seq.trace.transition(Instant::now(), back, "swap_in");
                }
            }
        }
    }

    /// End of step: stage swap-ins one step ahead of the scheduler's
    /// decode batch, oldest swapped sequence first, while device blocks
    /// and batch slots allow.  [`EngineConfig::prefetch_depth`] scales
    /// how far ahead the queue reaches: up to `depth` decode batches'
    /// worth of sequences may be staged (depth 1 — the default — stages
    /// exactly what the next step's batch can absorb, the original
    /// behaviour; deeper queues hide more swap latency at the cost of
    /// device blocks held by not-yet-schedulable sequences).
    fn issue_prefetches(&mut self) -> Result<()> {
        if !self.cache.has_host_tier() {
            return Ok(());
        }
        for id in self.sched.swapped_ids() {
            if self.in_flight_prefetch.contains(&id) {
                continue;
            }
            if self.sched.num_running() + self.in_flight_prefetch.len()
                >= self.sched.max_batch() * self.cfg.prefetch_depth.max(1)
            {
                break;
            }
            let needed = self.cache.swap_in_blocks_needed(id);
            // headroom: every running sequence — and every prefetch
            // already staged this pass — may claim a fresh block next
            // step; don't trade one preemption for another
            let headroom = self.sched.num_running() + self.in_flight_prefetch.len() + 1;
            if self.cache.num_free_blocks() < needed + headroom {
                break; // FCFS: a smaller sequence must not jump the queue
            }
            self.swap_in_seq(id)?;
            self.in_flight_prefetch.push(id);
        }
        Ok(())
    }

    /// Nothing is runnable: bring a swapped sequence back on demand (a
    /// prefetch miss — the engine waits on the transfer), or abandon its
    /// host copy and recompute.  Returns false when there is nothing to
    /// resume (genuinely stuck).
    fn resume_swapped_now(&mut self) -> Result<bool> {
        if !self.in_flight_prefetch.is_empty() {
            // staged prefetches resume at the next step
            return Ok(true);
        }
        let Some(&id) = self.sched.swapped_ids().first() else {
            return Ok(false);
        };
        if self.cache.num_free_blocks() < self.cache.swap_in_blocks_needed(id) {
            // the device pool cannot take it back even now: abandon the
            // host copy and recompute (a backend copy failure below, by
            // contrast, is a real error and propagates)
            let committed = self.cache.swapped_len(id);
            let full_len = self.seqs.get(&id).map(|s| s.tokens.len()).unwrap_or(0);
            for slot in self.cache.drop_swapped(id) {
                self.backend.swap_discard(slot)?;
            }
            self.sched.drop_swapped(id, full_len);
            if let Some(seq) = self.seqs.get_mut(&id) {
                seq.trace.transition(Instant::now(), Phase::Queued, "drop_swapped");
            }
            // the swap-out's credit was not earned after all: the tokens
            // are recomputed, not avoided
            self.metrics.recompute_avoided_tokens = self
                .metrics
                .recompute_avoided_tokens
                .saturating_sub(committed as u64);
            self.metrics.tokens_recomputed += committed as u64;
            return Ok(true);
        }
        let blocks = self.swap_in_seq(id)?;
        self.sched.resume_swapped(id);
        if let Some(seq) = self.seqs.get_mut(&id) {
            let back = seq.trace.resume_phase;
            seq.trace.transition(Instant::now(), back, "swap_in_demand");
        }
        self.metrics.prefetch_misses += 1;
        if let Some(cm) = &self.cost {
            // demand swap-in: the engine stalls on the transfer
            self.metrics.sim_swap_blocked_s +=
                cm.swap_transfer(blocks, self.backend.opt()).total_s;
        }
        Ok(true)
    }

    /// Cancel every sequence whose SLO deadline has passed, at the step
    /// boundary (never mid-pass).  Elapsed time is the *larger* of the
    /// wallclock and the simulated clock since arrival: real serving is
    /// wall-dominated, deterministic traces are sim-dominated, and taking
    /// the max means both regimes enforce the same budget.  Sequences
    /// parked for migration are skipped — the router owns them and the
    /// destination replica enforces the deadline after re-admission.
    /// Cancellation reuses the ordinary finish path, so device blocks and
    /// host slots free exactly as on any other finish (no leak path).
    fn enforce_deadlines(&mut self) {
        let now = Instant::now();
        let sim_now = self.sim_now();
        let expired: Vec<SeqId> = self
            .seqs
            .iter()
            .filter_map(|(&id, s)| {
                let deadline_ms = s.class.deadline_ms?;
                if s.finish.is_some() || s.trace.cur_phase() == Phase::Migration {
                    return None;
                }
                let wall_ms = now.duration_since(s.metrics.arrival).as_secs_f64() * 1e3;
                let sim_ms = (sim_now - s.arrival_sim_s).max(0.0) * 1e3;
                if wall_ms.max(sim_ms) > deadline_ms as f64 {
                    Some(id)
                } else {
                    None
                }
            })
            .collect();
        let mut expired = expired;
        expired.sort_unstable(); // HashMap order must not leak into results
        for id in expired {
            self.in_flight_prefetch.retain(|&p| p != id);
            self.metrics.deadline_cancellations += 1;
            self.finish_seq(id, FinishReason::DeadlineExceeded);
        }
    }

    fn check_finish(&mut self, id: SeqId, last_token: u32) {
        let geometry = *self.backend.geometry();
        let seq = &self.seqs[&id];
        let reason = if last_token == EOS_ID && !seq.ignore_eos {
            Some(FinishReason::Eos)
        } else if seq.generated() >= seq.max_new {
            Some(FinishReason::MaxNewTokens)
        } else if seq.tokens.len() >= geometry.max_context() {
            Some(FinishReason::MaxContext)
        } else {
            None
        };
        if let Some(r) = reason {
            self.finish_seq(id, r);
        }
    }

    fn finish_seq(&mut self, id: SeqId, reason: FinishReason) {
        // a sequence can finish while host-resident; its staging buffers
        // must be released or they leak (host slot ids are never reused)
        for slot in self.cache.free_seq(id) {
            if let Err(e) = self.backend.swap_discard(slot) {
                crate::log_warn!("swap_discard of host slot {slot} failed: {e}");
            }
        }
        self.sched.finish(id);
        // capture the lane's measured acceptance before the controller
        // forgets it — it seeds same-tenant cold starts via the forecast
        // plane's per-tenant acceptance EWMA
        let lane_acc = self.spec_ctl.as_ref().and_then(|c| c.lane_rate(id));
        if let Some(ctl) = self.spec_ctl.as_mut() {
            ctl.forget(id);
        }
        if let Some(mut seq) = self.seqs.remove(&id) {
            let now = Instant::now();
            seq.metrics.finished = Some(now);
            seq.finish = Some(reason);
            let breakdown = seq.trace.finish(now);
            if self.forecast.enabled() {
                // self-scoring: resolve the stamped predictions against
                // actuals (every stamp is scored, consumed or not)
                let actual_len = seq.generated() as u32;
                seq.trace.actual_len = Some(u64::from(actual_len));
                seq.trace.actual_wait_ms = Some(breakdown.queue_s * 1000.0);
                let tenant = seq.class.tenant.as_deref();
                match (seq.trace.predicted_len_p50, seq.trace.predicted_len_p90) {
                    (Some(p50), Some(p90)) => {
                        self.forecast.resolve_len(tenant, p50, p90, actual_len)
                    }
                    // unstamped finishes still teach the window (warm-up)
                    _ => self.forecast.observe_len(tenant, actual_len),
                }
                if let Some(rate) = lane_acc {
                    self.forecast.observe_acceptance(tenant, rate);
                }
            }
            self.metrics.record_request_class(&seq.metrics, seq.class.priority);
            self.metrics.record_phases_class(&breakdown, seq.class.priority);
            self.metrics.tokens_generated = self.metrics.tokens_generated.max(0);
            let gen_tokens: Vec<u32> = seq.tokens[seq.prompt_len..]
                .iter()
                .copied()
                .filter(|&t| t != EOS_ID)
                .collect();
            self.finished.push(GenResult {
                id,
                prompt: self.tokenizer.decode(&seq.tokens[..seq.prompt_len]),
                text: self.tokenizer.decode(&gen_tokens),
                tokens: seq.tokens.clone(),
                finish: reason,
                prompt_tokens: seq.prompt_len,
                generated_tokens: seq.generated(),
                latency_s: seq
                    .metrics
                    .latency()
                    .map(|d| d.as_secs_f64())
                    .unwrap_or(0.0),
                ttft_s: seq.metrics.ttft().map(|d| d.as_secs_f64()).unwrap_or(0.0),
                sim_time_s: seq.metrics.sim_time_s,
                corr_id: seq.trace.corr_id.clone(),
                phases: breakdown,
                class: seq.class.clone(),
            });
            if self.recorder.capacity() > 0 {
                self.recorder.push(seq.trace.to_json(&breakdown));
            }
        }
    }
}

/// Blocks the attention kernel would traverse on the baseline: every block
/// the prefill/decode path has populated (padded prefill writes make this
/// the padded span, Eq. 2), vs ceil(ctx/B) for Opt-Pa.
fn row_allocated(
    row: &[i32],
    ctx: usize,
    block_size: usize,
    opt: &crate::config::OptConfig,
    max_seq: usize,
) -> usize {
    let valid = ctx.div_ceil(block_size);
    if opt.skip_filter {
        valid
    } else {
        // baseline padded prefill populated ceil(max_seq/B) blocks
        let padded = max_seq.div_ceil(block_size);
        let _ = row;
        padded.max(valid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, COOPT, ORIGINAL};
    use crate::runtime::mock::MockBackend;

    fn engine(opt: crate::config::OptConfig) -> Engine<MockBackend> {
        let be = MockBackend::new().with_opt(opt);
        let cfg = EngineConfig::new("llama-7b-sim", opt);
        Engine::new(be, cfg)
    }

    #[test]
    fn deadline_cancellation_frees_resources_at_step_boundary() {
        use crate::config::Priority;
        let mut e = engine(COOPT);
        // deadline 0: already expired when the first step boundary checks,
        // so the cancel lands while the request is still waiting
        let doomed = e
            .submit(
                GenRequest::greedy("deadline victim prompt", 8)
                    .with_class(ReqClass::batch().with_deadline_ms(0).with_tenant("t0")),
            )
            .unwrap();
        let alive = e.submit(GenRequest::greedy("Q: 1+1=?", 4)).unwrap();
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 2);
        let d = results.iter().find(|r| r.id == doomed).unwrap();
        assert_eq!(d.finish, FinishReason::DeadlineExceeded);
        assert_eq!(d.generated_tokens, 0, "cancelled before admission");
        assert_eq!(d.class.priority, Priority::Batch);
        assert_eq!(d.class.deadline_ms, Some(0));
        assert_eq!(d.class.tenant.as_deref(), Some("t0"));
        let a = results.iter().find(|r| r.id == alive).unwrap();
        assert_eq!(a.finish, FinishReason::MaxNewTokens);
        assert_eq!(a.generated_tokens, 4, "undoomed request unaffected");
        assert_eq!(e.metrics.deadline_cancellations, 1);
        // the cancel leaked nothing: device pool and host tier drain to zero
        assert_eq!(e.cache_stats().blocks_used, 0);
        assert_eq!(e.tier_stats().host_used_blocks, 0);
    }

    #[test]
    fn deadline_cancels_mid_stream_and_frees_kv() {
        let mut e = engine(COOPT);
        e.submit(
            GenRequest::greedy("a long running request", 64)
                .with_class(ReqClass::interactive().with_deadline_ms(5)),
        )
        .unwrap();
        // step 1 runs within the budget (admission + prefill); then the
        // wallclock blows the 5 ms deadline and the next boundary cancels
        let mut out = e.step().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(10));
        out.extend(e.step().unwrap());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].finish, FinishReason::DeadlineExceeded);
        assert!(out[0].generated_tokens < 64, "never ran to completion");
        assert_eq!(e.metrics.deadline_cancellations, 1);
        assert_eq!(e.cache_stats().blocks_used, 0, "mid-stream KV freed");
        assert!(e.sched.is_idle());
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine(COOPT);
        e.submit(GenRequest::greedy("Q: 1+1=?", 4)).unwrap();
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.generated_tokens, 4);
        assert_eq!(r.finish, FinishReason::MaxNewTokens);
        assert_eq!(e.cache_stats().blocks_used, 0, "all blocks freed");
        assert!(e.metrics.decode_steps >= 3);
    }

    #[test]
    fn batch_requests_complete_deterministically() {
        let mut e = engine(COOPT);
        let reqs: Vec<GenRequest> = (0..12)
            .map(|i| GenRequest::greedy(format!("prompt number {i}"), 6))
            .collect();
        let results = e.generate(reqs.clone()).unwrap();
        assert_eq!(results.len(), 12);
        for r in &results {
            assert!(r.generated_tokens >= 1);
        }
        // determinism: same engine config -> same outputs
        let mut e2 = engine(COOPT);
        let results2 = e2.generate(reqs).unwrap();
        for (a, b) in results.iter().zip(&results2) {
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn original_config_also_serves() {
        let mut e = engine(ORIGINAL);
        let results = e
            .generate(vec![
                GenRequest::greedy("hello world", 5),
                GenRequest::greedy("second prompt", 5),
            ])
            .unwrap();
        assert_eq!(results.len(), 2);
        // baseline fragments the pool while running but frees at the end
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    #[test]
    fn sim_time_accumulates_and_favors_coopt() {
        let mut mk = |opt| {
            let mut e = engine(opt);
            let reqs: Vec<GenRequest> = (0..6)
                .map(|i| GenRequest::greedy(format!("prompt {i} {}", "x".repeat(40)), 16))
                .collect();
            e.generate(reqs).unwrap();
            (
                e.metrics.sim_prefill_s + e.metrics.sim_decode_s,
                e.metrics.tokens_generated,
            )
        };
        let (t_orig, n1) = mk(ORIGINAL);
        let (t_coopt, n2) = mk(COOPT);
        assert_eq!(n1, n2);
        assert!(t_coopt < t_orig, "coopt {t_coopt} < original {t_orig}");
    }

    #[test]
    fn preemption_recovers() {
        // tiny pool forces preemption under load
        let geometry = crate::config::CacheGeometry {
            block_size: 4,
            max_blocks: 16,
            num_pool_blocks: 12,
            max_batch: 4,
            max_seq: 32,
        };
        let be = MockBackend::with_geometry(geometry).with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT);
        let mut e = Engine::new(be, cfg);
        let reqs: Vec<GenRequest> = (0..6)
            .map(|i| GenRequest::greedy(format!("pp{i} {}", "y".repeat(16)), 12))
            .collect();
        let results = e.generate(reqs).unwrap();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert!(
                r.generated_tokens >= 1,
                "every request makes progress despite preemption"
            );
        }
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    #[test]
    fn score_returns_vocab_row_and_frees() {
        let mut e = engine(COOPT);
        let toks = Tokenizer::new().encode("Q: 2+2=? Answer:", true, false);
        let row = e.score_tokens(&toks).unwrap();
        assert_eq!(row.len(), e.backend.preset().vocab);
        assert_eq!(e.cache_stats().blocks_used, 0);
        // deterministic
        let row2 = e.score_tokens(&toks).unwrap();
        assert_eq!(row, row2);
    }

    #[test]
    fn rejects_oversized_prompt() {
        let mut e = engine(COOPT);
        let huge = "z".repeat(4000);
        assert!(e.submit(GenRequest::greedy(huge, 4)).is_err());
    }

    fn chunked_engine(chunk: usize, budget: usize) -> Engine<MockBackend> {
        let be = MockBackend::new().with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_chunked_prefill(chunk)
            .with_step_budget(budget);
        Engine::new(be, cfg)
    }

    #[test]
    fn chunked_prefill_spans_steps_and_defers_sampling() {
        // 40-token prompt, 16-token chunks (= block size): three windows
        let mut e = chunked_engine(16, 64);
        let toks: Vec<u32> = (1..=40).collect();
        let id = e
            .submit_tokens(toks, 4, SamplingParams::default(), false)
            .unwrap();
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].id, id);
        assert_eq!(results[0].generated_tokens, 4);
        assert_eq!(
            e.backend.chunk_trace,
            vec![(0, 16), (16, 16), (32, 8)],
            "windows resume from the committed offset"
        );
        assert_eq!(e.metrics.prefill_chunks, 3);
        assert!(e.metrics.chunk_stall_s >= 0.0);
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    #[test]
    fn chunked_greedy_output_matches_oneshot() {
        let reqs: Vec<GenRequest> = (0..4)
            .map(|i| GenRequest::greedy(format!("prompt {i} {}", "q".repeat(30 + i)), 6))
            .collect();
        let mut one = engine(COOPT);
        let base = one.generate(reqs.clone()).unwrap();
        let mut chk = chunked_engine(8, 24);
        let ours = chk.generate(reqs).unwrap();
        assert_eq!(base.len(), ours.len());
        for (a, b) in base.iter().zip(&ours) {
            assert_eq!(a.tokens, b.tokens, "chunked ≡ one-shot greedy (seq {})", a.id);
            assert_eq!(a.finish, b.finish);
        }
        assert!(chk.metrics.prefill_chunks > 4, "long prompts actually chunked");
        assert_eq!(chk.cache_stats().blocks_used, 0);
    }

    #[test]
    fn chunked_falls_back_on_backends_without_chunk_support() {
        // a backend that leaves the trait defaults in place (like the
        // one-shot PJRT graphs) must not be driven with mid-prompt
        // windows — the engine degrades to one-shot scheduling
        struct OneShotOnly(MockBackend);
        impl Backend for OneShotOnly {
            fn preset(&self) -> &crate::config::ModelPreset {
                self.0.preset()
            }
            fn geometry(&self) -> &crate::config::CacheGeometry {
                self.0.geometry()
            }
            fn opt(&self) -> &crate::config::OptConfig {
                self.0.opt()
            }
            fn prefill(&mut self, t: &[i32], l: i32, s: &[i32]) -> Result<Vec<f32>> {
                self.0.prefill(t, l, s)
            }
            fn decode(
                &mut self,
                t: &[i32],
                p: &[i32],
                b: &[i32],
                c: &[i32],
                s: &[i32],
            ) -> Result<Vec<f32>> {
                self.0.decode(t, p, b, c, s)
            }
            fn reset_cache(&mut self) -> Result<()> {
                self.0.reset_cache()
            }
            fn take_exec_time(&mut self) -> std::time::Duration {
                self.0.take_exec_time()
            }
        }
        let be = OneShotOnly(MockBackend::new().with_opt(COOPT));
        let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_chunked_prefill(8);
        let mut e = Engine::new(be, cfg);
        assert!(!e.cfg.chunked_prefill, "degraded to one-shot scheduling");
        let r = e
            .generate(vec![GenRequest::greedy("fallback still serves", 4)])
            .unwrap();
        assert_eq!(r[0].generated_tokens, 4);
        assert_eq!(e.metrics.prefill_chunks, 0);
    }

    #[test]
    fn chunked_mixes_prefill_windows_with_decode_batches() {
        let mut e = chunked_engine(16, 24);
        // two short streams keep decoding while a long prompt prefills
        e.submit(GenRequest::greedy("stream a", 20)).unwrap();
        e.submit(GenRequest::greedy("stream b", 20)).unwrap();
        let long: Vec<u32> = (1..=100).collect();
        e.submit_tokens(long, 3, SamplingParams::default(), false)
            .unwrap();
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 3);
        // the long prompt took several windows...
        let long_windows: Vec<(i32, i32)> = e
            .backend
            .chunk_trace
            .iter()
            .copied()
            .filter(|&(o, l)| o > 0 || l > 16)
            .collect();
        assert!(long_windows.len() >= 5, "windows: {:?}", e.backend.chunk_trace);
        // ...and the streams decoded in between (interleaving, not phases)
        assert!(e.metrics.decode_steps >= 19);
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    fn tiered_engine(pool: usize, host: usize, policy: SwapPolicy) -> Engine<MockBackend> {
        let geometry = crate::config::CacheGeometry {
            block_size: 4,
            max_blocks: 16,
            num_pool_blocks: pool,
            max_batch: 4,
            max_seq: 48,
        };
        let be = MockBackend::with_geometry(geometry).with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_host_pool(host)
            .with_swap_policy(policy);
        Engine::new(be, cfg)
    }

    fn pressure_reqs() -> Vec<GenRequest> {
        (0..6)
            .map(|i| GenRequest::greedy(format!("pp{i} {}", "y".repeat(16)), 12))
            .collect()
    }

    #[test]
    fn swap_preemption_is_semantically_invisible() {
        // unconstrained reference: a pool that never preempts
        let mut base = tiered_engine(96, 0, SwapPolicy::Never);
        let expected = base.generate(pressure_reqs()).unwrap();
        assert_eq!(base.metrics.preemptions, 0, "reference must not preempt");

        for policy in [SwapPolicy::Always, SwapPolicy::Auto] {
            let mut e = tiered_engine(12, 64, policy);
            let got = e.generate(pressure_reqs()).unwrap();
            assert_eq!(expected.len(), got.len());
            for (a, b) in expected.iter().zip(&got) {
                assert_eq!(a.tokens, b.tokens, "{policy:?}: swap must not change outputs");
                assert_eq!(a.finish, b.finish);
            }
            assert!(e.metrics.swap_outs > 0, "{policy:?}: pool pressure must swap");
            assert!(e.metrics.recompute_avoided_tokens > 0);
            assert_eq!(e.cache_stats().blocks_used, 0);
            assert_eq!(e.tier_stats().host_used_blocks, 0, "host tier drains");
            // every host-tier resume is a prefetch hit or a demand miss
            assert_eq!(
                e.metrics.prefetch_hits + e.metrics.prefetch_misses,
                e.metrics.swap_ins
            );
            // the mock's copy semantics saw matched out/in block traffic
            let outs = e.backend.swap_trace.iter().filter(|t| t.0 == 'O').count() as u64;
            assert_eq!(outs, e.metrics.blocks_swapped_out);
        }
    }

    #[test]
    fn swap_avoids_recompute_that_drop_pays() {
        let run = |host, policy| {
            let mut e = tiered_engine(12, host, policy);
            e.generate(pressure_reqs()).unwrap();
            (
                e.metrics.tokens_recomputed,
                e.metrics.recompute_avoided_tokens,
                e.metrics.preemptions,
            )
        };
        let (recomputed_drop, avoided_drop, pre_drop) = run(0, SwapPolicy::Never);
        assert!(pre_drop > 0, "workload must force preemption");
        assert!(recomputed_drop > 0, "drop-and-recompute pays in tokens");
        assert_eq!(avoided_drop, 0);
        let (recomputed_swap, avoided_swap, pre_swap) = run(64, SwapPolicy::Always);
        assert!(pre_swap > 0);
        assert!(avoided_swap > 0);
        assert!(
            recomputed_swap < recomputed_drop,
            "tiered path recomputes less: {recomputed_swap} vs {recomputed_drop}"
        );
    }

    #[test]
    fn swap_falls_back_to_drop_when_host_pool_tiny() {
        // host pool of 1 block cannot take any victim: every preemption
        // must fall back to recompute, and the run still completes
        let mut e = tiered_engine(12, 1, SwapPolicy::Always);
        let results = e.generate(pressure_reqs()).unwrap();
        assert_eq!(results.len(), 6);
        assert!(e.metrics.preemptions > 0);
        assert_eq!(e.cache_stats().blocks_used, 0);
        assert_eq!(e.tier_stats().host_used_blocks, 0);
    }

    #[test]
    fn host_tier_disabled_without_backend_swap_support() {
        struct NoSwap(MockBackend);
        impl Backend for NoSwap {
            fn preset(&self) -> &crate::config::ModelPreset {
                self.0.preset()
            }
            fn geometry(&self) -> &crate::config::CacheGeometry {
                self.0.geometry()
            }
            fn opt(&self) -> &crate::config::OptConfig {
                self.0.opt()
            }
            fn prefill(&mut self, t: &[i32], l: i32, s: &[i32]) -> Result<Vec<f32>> {
                self.0.prefill(t, l, s)
            }
            fn decode(
                &mut self,
                t: &[i32],
                p: &[i32],
                b: &[i32],
                c: &[i32],
                s: &[i32],
            ) -> Result<Vec<f32>> {
                self.0.decode(t, p, b, c, s)
            }
            fn reset_cache(&mut self) -> Result<()> {
                self.0.reset_cache()
            }
            fn take_exec_time(&mut self) -> std::time::Duration {
                self.0.take_exec_time()
            }
        }
        // swap defaults to unsupported: the engine degrades instead of
        // wedging the first time a preemption tries to swap
        let be = NoSwap(MockBackend::new().with_opt(COOPT));
        let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_host_pool(64);
        let mut e = Engine::new(be, cfg);
        assert_eq!(e.cfg.host_pool_blocks, 0, "degraded to single tier");
        let r = e
            .generate(vec![GenRequest::greedy("still serves", 4)])
            .unwrap();
        assert_eq!(r[0].generated_tokens, 4);
        assert_eq!(e.metrics.swap_outs, 0);
    }

    #[test]
    fn stats_json_surfaces_tier_state() {
        let mut e = tiered_engine(12, 64, SwapPolicy::Always);
        e.generate(pressure_reqs()).unwrap();
        let v = e.stats_json();
        assert_eq!(v.req_usize("host_pool_blocks").unwrap(), 64);
        assert_eq!(v.req_usize("host_blocks_used").unwrap(), 0);
        assert!(
            v.req_usize("host_blocks_peak").unwrap() > 0,
            "swaps ran, so the host tier high-water mark is nonzero"
        );
        assert!(v.req_usize("swap_outs").unwrap() > 0);
        assert!(v.req_f64("prefetch_hit_rate").unwrap() >= 0.0);
        assert_eq!(v.req_usize("cache_blocks_used").unwrap(), 0);
    }

    fn spec_engine(k: usize) -> Engine<MockBackend> {
        let be = MockBackend::new().with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_speculation(k);
        Engine::new(be, cfg)
    }

    #[test]
    fn greedy_speculation_matches_one_token_decode() {
        let reqs: Vec<GenRequest> = (0..5)
            .map(|i| GenRequest::greedy(format!("spec prompt {i} {}", "s".repeat(10 + i)), 12))
            .collect();
        let mut base = engine(COOPT);
        let expected = base.generate(reqs.clone()).unwrap();
        for k in [1usize, 2, 4] {
            let mut e = spec_engine(k);
            let got = e.generate(reqs.clone()).unwrap();
            assert_eq!(expected.len(), got.len());
            for (a, b) in expected.iter().zip(&got) {
                assert_eq!(a.tokens, b.tokens, "k={k}: speculation must not change outputs");
                assert_eq!(a.finish, b.finish);
            }
            assert!(e.metrics.spec_rounds > 0, "k={k}: verify passes ran");
            assert!(e.metrics.spec_drafted > 0);
            assert_eq!(e.cache_stats().blocks_used, 0, "k={k}: rollback leaks no blocks");
            // the whole point: more than one token per decode round
            assert!(
                e.metrics.tokens_per_step() > 1.0,
                "k={k}: tokens/step {}",
                e.metrics.tokens_per_step()
            );
            assert!(
                e.metrics.decode_steps + e.metrics.spec_rounds
                    < base.metrics.decode_steps,
                "k={k}: speculation takes fewer rounds"
            );
            // the mock's draft deliberately disagrees sometimes
            let rate = e.metrics.acceptance_rate();
            assert!(rate > 0.0 && rate < 1.0, "k={k}: acceptance {rate}");
        }
    }

    #[test]
    fn speculation_commits_through_finish_boundaries() {
        // max_new not a multiple of k+1: the cutoff must stop at exactly
        // max_new tokens, like sequential decode
        for max_new in [1usize, 2, 3, 5, 7] {
            let mut base = engine(COOPT);
            let expected = base
                .generate(vec![GenRequest::greedy("boundary test", max_new)])
                .unwrap();
            let mut e = spec_engine(4);
            let got = e
                .generate(vec![GenRequest::greedy("boundary test", max_new)])
                .unwrap();
            assert_eq!(expected[0].tokens, got[0].tokens, "max_new={max_new}");
            assert_eq!(got[0].generated_tokens, max_new);
            assert_eq!(expected[0].finish, got[0].finish);
            assert_eq!(e.cache_stats().blocks_used, 0);
        }
    }

    #[test]
    fn speculation_composes_with_chunked_prefill() {
        let reqs: Vec<GenRequest> = (0..3)
            .map(|i| GenRequest::greedy(format!("long {} {}", i, "c".repeat(40)), 10))
            .collect();
        let mut base = engine(COOPT);
        let expected = base.generate(reqs.clone()).unwrap();
        let be = MockBackend::new().with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_chunked_prefill(8)
            .with_step_budget(48)
            .with_speculation(3);
        let mut e = Engine::new(be, cfg);
        let got = e.generate(reqs).unwrap();
        for (a, b) in expected.iter().zip(&got) {
            assert_eq!(a.tokens, b.tokens);
        }
        assert!(e.metrics.prefill_chunks > 0, "prompts actually chunked");
        assert!(e.metrics.spec_rounds > 0, "and decode rounds speculated");
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    #[test]
    fn speculation_survives_pool_exhaustion_with_swap() {
        // unconstrained one-token reference vs a speculative engine on an
        // undersized pool with a host tier: preemption mid-speculation
        // must stay semantically invisible (reservations roll back before
        // the victim exits via swap)
        let mut base = tiered_engine(96, 0, SwapPolicy::Never);
        let expected = base.generate(pressure_reqs()).unwrap();
        assert_eq!(base.metrics.preemptions, 0);

        let geometry = crate::config::CacheGeometry {
            block_size: 4,
            max_blocks: 16,
            num_pool_blocks: 12,
            max_batch: 4,
            max_seq: 48,
        };
        let be = MockBackend::with_geometry(geometry).with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_host_pool(160)
            .with_swap_policy(SwapPolicy::Always)
            .with_speculation(3);
        let mut e = Engine::new(be, cfg);
        let got = e.generate(pressure_reqs()).unwrap();
        assert_eq!(expected.len(), got.len());
        for (a, b) in expected.iter().zip(&got) {
            assert_eq!(a.tokens, b.tokens, "speculation + swap must not change outputs");
            assert_eq!(a.finish, b.finish);
        }
        assert!(e.metrics.preemptions > 0, "pool pressure must preempt");
        assert!(e.metrics.spec_rounds > 0, "speculation actually ran");
        assert_eq!(e.cache_stats().blocks_used, 0);
        assert_eq!(e.tier_stats().host_used_blocks, 0, "host tier drains");
    }

    #[test]
    fn stochastic_speculation_serves_and_accounts() {
        let be = MockBackend::new().with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_speculation(3)
            .with_spec_policy(crate::config::SpecPolicy::Stochastic);
        let mut e = Engine::new(be, cfg);
        let mut req = GenRequest::greedy("stochastic spec", 16);
        req.sampling.temperature = 0.8;
        req.ignore_eos = true;
        let r = e.generate(vec![req]).unwrap();
        assert_eq!(r[0].generated_tokens, 16);
        assert!(e.metrics.spec_rounds > 0);
        assert_eq!(
            e.metrics.decode_tokens_committed + 1, // + the prefill-sampled token
            r[0].generated_tokens as u64
        );
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    #[test]
    fn speculation_disabled_without_backend_support() {
        // a backend that leaves the trait defaults in place (like the
        // one-shot PJRT graphs) must never be driven with draft/verify
        struct OneTokenOnly(MockBackend);
        impl Backend for OneTokenOnly {
            fn preset(&self) -> &crate::config::ModelPreset {
                self.0.preset()
            }
            fn geometry(&self) -> &crate::config::CacheGeometry {
                self.0.geometry()
            }
            fn opt(&self) -> &crate::config::OptConfig {
                self.0.opt()
            }
            fn prefill(&mut self, t: &[i32], l: i32, s: &[i32]) -> Result<Vec<f32>> {
                self.0.prefill(t, l, s)
            }
            fn decode(
                &mut self,
                t: &[i32],
                p: &[i32],
                b: &[i32],
                c: &[i32],
                s: &[i32],
            ) -> Result<Vec<f32>> {
                self.0.decode(t, p, b, c, s)
            }
            fn reset_cache(&mut self) -> Result<()> {
                self.0.reset_cache()
            }
            fn take_exec_time(&mut self) -> std::time::Duration {
                self.0.take_exec_time()
            }
        }
        let be = OneTokenOnly(MockBackend::new().with_opt(COOPT));
        let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_speculation(4);
        let mut e = Engine::new(be, cfg);
        assert_eq!(e.cfg.spec.draft_tokens, 0, "degraded to one-token decode");
        let r = e
            .generate(vec![GenRequest::greedy("fallback still serves", 6)])
            .unwrap();
        assert_eq!(r[0].generated_tokens, 6);
        assert_eq!(e.metrics.spec_rounds, 0);
        assert!((e.metrics.tokens_per_step() - 1.0).abs() < 1e-9);
        // the adaptive controller degrades identically: no draft graph,
        // no controller
        let be = OneTokenOnly(MockBackend::new().with_opt(COOPT));
        let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_adaptive_speculation(4);
        let mut e = Engine::new(be, cfg);
        assert!(!e.cfg.spec.enabled(), "adaptive degraded to one-token decode");
        let r = e
            .generate(vec![GenRequest::greedy("adaptive fallback serves", 5)])
            .unwrap();
        assert_eq!(r[0].generated_tokens, 5);
        assert_eq!(e.metrics.spec_rounds, 0);
        assert!(e.metrics.spec_k_hist.is_empty(), "no speculative accounting");
    }

    #[test]
    fn adaptive_speculation_matches_one_token_decode() {
        let reqs: Vec<GenRequest> = (0..4)
            .map(|i| GenRequest::greedy(format!("adaptive prompt {i} {}", "a".repeat(8 + i)), 14))
            .collect();
        let mut base = engine(COOPT);
        let expected = base.generate(reqs.clone()).unwrap();
        let be = MockBackend::new().with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_adaptive_speculation(4);
        let mut e = Engine::new(be, cfg);
        let got = e.generate(reqs).unwrap();
        assert_eq!(expected.len(), got.len());
        for (a, b) in expected.iter().zip(&got) {
            assert_eq!(a.tokens, b.tokens, "adaptive speculation must not change outputs");
            assert_eq!(a.finish, b.finish);
        }
        assert!(e.metrics.spec_rounds > 0, "the controller actually drafted");
        assert!(e.metrics.tokens_per_step() > 1.0);
        assert!(!e.metrics.spec_k_hist.is_empty());
        assert!(!e.spec_k_trace().is_empty(), "chosen-k trace recorded");
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    #[test]
    fn adaptive_controller_goes_plain_on_gemm_bound_batches() {
        // 8 concurrent lanes on the default geometry: the cost model
        // classifies decode as GEMM-bound, where speculation cannot win —
        // the controller must serve plain one-token rounds throughout.
        // Chunked prefill admits the whole batch in round one, so the
        // controller never sees a small warm-up batch.
        let be = MockBackend::new().with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_chunked_prefill(32)
            .with_adaptive_speculation(4);
        let mut e = Engine::new(be, cfg);
        let reqs: Vec<GenRequest> = (0..8)
            .map(|i| GenRequest::greedy(format!("batchy prompt number {i}"), 10))
            .collect();
        let results = e.generate(reqs).unwrap();
        assert_eq!(results.len(), 8);
        assert_eq!(e.metrics.spec_rounds, 0, "GEMM-bound: no verify pass ever pays");
        assert_eq!(e.metrics.spec_k_current, 0);
        assert_eq!(e.metrics.spec_regime, "gemm-bound");
        assert!(e.metrics.rounds_gemm_bound > 0);
        assert!((e.metrics.tokens_per_step_gemm() - 1.0).abs() < 1e-9);
        assert!(e.spec_k_trace().iter().all(|&k| k == 0));
        // the same batch at fixed k=4 wastefully drafts anyway — the
        // exact foot-gun the controller removes
        let be = MockBackend::new().with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_chunked_prefill(32)
            .with_speculation(4);
        let mut fixed = Engine::new(be, cfg);
        let reqs: Vec<GenRequest> = (0..8)
            .map(|i| GenRequest::greedy(format!("batchy prompt number {i}"), 10))
            .collect();
        fixed.generate(reqs).unwrap();
        assert!(fixed.metrics.spec_rounds > 0);
        assert_eq!(fixed.metrics.spec_regime, "gemm-bound");
    }

    #[test]
    fn adaptive_controller_state_reaches_stats_json() {
        let be = MockBackend::new().with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_adaptive_speculation(4);
        let mut e = Engine::new(be, cfg);
        e.generate(vec![GenRequest::greedy("controller gauges", 12)])
            .unwrap();
        let v = e.stats_json();
        let hist = v.get("spec_k_hist").expect("k histogram exposed");
        assert!(hist.req_usize("0").is_ok() || hist.req_usize("4").is_ok());
        assert!(v.req_f64("spec_acceptance_ewma").unwrap() > 0.0);
        assert_eq!(v.req_str("spec_regime").unwrap(), "weight-stream-bound");
        assert!(v.req_usize("rounds_weight_stream_bound").unwrap() > 0);
        assert!(v.req_f64("tokens_per_step_weight_stream").unwrap() > 1.0);
        assert!(v.get("spec_k_current").is_some());
    }

    #[test]
    fn speculation_degrades_near_max_context() {
        // tiny context: lanes whose remaining room is under k+1 finish on
        // the one-token path instead of wedging or overshooting
        let geometry = crate::config::CacheGeometry {
            block_size: 4,
            max_blocks: 4,
            num_pool_blocks: 16,
            max_batch: 2,
            max_seq: 12,
        };
        let run = |k: usize| {
            let be = MockBackend::with_geometry(geometry).with_opt(COOPT);
            let mut cfg = EngineConfig::new("llama-7b-sim", COOPT);
            if k > 0 {
                cfg = cfg.with_speculation(k);
            }
            let mut e = Engine::new(be, cfg);
            let toks: Vec<u32> = (40..46).collect();
            e.submit_tokens(toks, 32, SamplingParams::default(), true).unwrap();
            let r = e.run_to_completion().unwrap();
            (r[0].tokens.clone(), r[0].finish, e)
        };
        let (base_toks, base_fin, _) = run(0);
        let (spec_toks, spec_fin, e) = run(4);
        assert_eq!(base_toks, spec_toks, "max-context cutoff identical");
        assert_eq!(base_fin, spec_fin);
        assert_eq!(base_fin, FinishReason::MaxContext);
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    #[test]
    fn spec_metrics_reach_stats_json() {
        let mut e = spec_engine(3);
        e.generate(vec![
            GenRequest::greedy("metrics one", 10),
            GenRequest::greedy("metrics two", 10),
        ])
        .unwrap();
        let v = e.stats_json();
        assert!(v.req_usize("spec_rounds").unwrap() > 0);
        assert!(v.req_f64("tokens_per_step").unwrap() > 1.0);
        let occ = v.req_f64("decode_batch_occupancy").unwrap();
        assert!(occ > 0.0 && occ <= 1.0);
        assert!(v.req_f64("acceptance_rate").unwrap() > 0.0);
    }

    #[test]
    fn coordinator_overhead_measured() {
        let mut e = engine(COOPT);
        e.generate(vec![GenRequest::greedy("measure me", 8)]).unwrap();
        // mock's "backend" time is near zero, so the coordinator share of
        // wallclock must dominate
        assert!(e.metrics.wall_coordinator_s > 0.0);
        assert!(e.metrics.coordinator_overhead_frac() > 0.2);
    }

    fn pd_reqs() -> Vec<GenRequest> {
        (0..4)
            .map(|i| GenRequest::greedy(format!("pd prompt {i} {}", "h".repeat(20 + i)), 8))
            .collect()
    }

    /// Drive a prefill-role source until every request has been packaged,
    /// feeding each envelope into the destination as it surfaces.
    fn drain_handoffs(
        src: &mut Engine<MockBackend>,
        dst: &mut Engine<impl Backend>,
        expect: usize,
    ) {
        let mut moved = 0usize;
        let mut rounds = 0;
        while moved < expect {
            src.step().unwrap();
            for id in src.take_handoff_ready() {
                let h = src.make_handoff(id).unwrap();
                dst.migrate_in_seq(h).unwrap();
                moved += 1;
            }
            rounds += 1;
            assert!(rounds < 200, "hand-offs never surfaced ({moved}/{expect})");
        }
    }

    #[test]
    fn kv_handoff_between_replicas_is_token_identical() {
        // reference: one unconstrained mixed replica
        let mut base = engine(COOPT);
        let expected = base.generate(pd_reqs()).unwrap();

        // prefill replica (host tier = migration staging) + decode replica
        let be = MockBackend::new().with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_host_pool(64)
            .with_swap_policy(SwapPolicy::Always)
            .with_role(ReplicaRole::Prefill);
        let mut src = Engine::new(be, cfg);
        let be = MockBackend::new().with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_role(ReplicaRole::Decode);
        let mut dst = Engine::new(be, cfg);

        for r in pd_reqs() {
            src.submit(r).unwrap();
        }
        drain_handoffs(&mut src, &mut dst, 4);
        assert_eq!(src.num_pending(), 0, "source replica fully drained");
        let mut got = dst.run_to_completion().unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(expected.len(), got.len());
        for (a, b) in expected.iter().zip(&got) {
            assert_eq!(a.tokens, b.tokens, "hand-off must not change outputs");
            assert_eq!(a.finish, b.finish);
            assert_eq!(a.generated_tokens, b.generated_tokens);
        }
        // Always policy: every hand-off took the KV path
        assert_eq!(src.metrics.migrations_out, 4);
        assert!(src.metrics.migrated_blocks_out > 0);
        assert!(src.metrics.migration_bytes > 0);
        assert_eq!(src.metrics.migrations_token_fallback, 0);
        assert_eq!(dst.metrics.migrations_in, 4);
        assert_eq!(dst.metrics.tokens_recomputed, 0, "KV path recomputes nothing");
        // both pools drain; the transient staging slots were all released
        assert_eq!(src.cache_stats().blocks_used, 0);
        assert_eq!(src.tier_stats().host_used_blocks, 0);
        assert_eq!(dst.cache_stats().blocks_used, 0);
    }

    #[test]
    fn handoff_degrades_to_reprefill_without_backend_migration() {
        // a backend that leaves the migration defaults in place must get
        // the token-only envelope, and the destination re-prefills —
        // outputs still identical to the unconstrained reference
        struct NoMigrate(MockBackend);
        impl Backend for NoMigrate {
            fn preset(&self) -> &crate::config::ModelPreset {
                self.0.preset()
            }
            fn geometry(&self) -> &crate::config::CacheGeometry {
                self.0.geometry()
            }
            fn opt(&self) -> &crate::config::OptConfig {
                self.0.opt()
            }
            fn prefill(&mut self, t: &[i32], l: i32, s: &[i32]) -> Result<Vec<f32>> {
                self.0.prefill(t, l, s)
            }
            fn decode(
                &mut self,
                t: &[i32],
                p: &[i32],
                b: &[i32],
                c: &[i32],
                s: &[i32],
            ) -> Result<Vec<f32>> {
                self.0.decode(t, p, b, c, s)
            }
            fn reset_cache(&mut self) -> Result<()> {
                self.0.reset_cache()
            }
            fn take_exec_time(&mut self) -> std::time::Duration {
                self.0.take_exec_time()
            }
        }
        let mut base = engine(COOPT);
        let expected = base.generate(pd_reqs()).unwrap();

        let be = NoMigrate(MockBackend::new().with_opt(COOPT));
        let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_role(ReplicaRole::Prefill);
        let mut src = Engine::new(be, cfg);
        let mut dst = engine(COOPT);

        for r in pd_reqs() {
            src.submit(r).unwrap();
        }
        let mut moved = 0usize;
        let mut rounds = 0;
        while moved < 4 {
            src.step().unwrap();
            for id in src.take_handoff_ready() {
                let h = src.make_handoff(id).unwrap();
                assert!(h.blocks.is_empty(), "no migration support: token-only");
                dst.migrate_in_seq(h).unwrap();
                moved += 1;
            }
            rounds += 1;
            assert!(rounds < 200);
        }
        let mut got = dst.run_to_completion().unwrap();
        got.sort_by_key(|r| r.id);
        for (a, b) in expected.iter().zip(&got) {
            assert_eq!(a.tokens, b.tokens, "re-prefill hand-off must not change outputs");
            assert_eq!(a.finish, b.finish);
        }
        assert_eq!(src.metrics.migrations_out, 0);
        assert_eq!(src.metrics.migrations_token_fallback, 4);
        assert!(
            dst.metrics.tokens_recomputed > 0,
            "the destination paid the re-prefill"
        );
        // the sampled tail travelled in the envelope: exactly one prefill
        // sample per request, on the source
        assert_eq!(dst.metrics.migrations_in, 0);
        assert_eq!(src.cache_stats().blocks_used, 0);
        assert_eq!(dst.cache_stats().blocks_used, 0);
    }

    #[test]
    fn aborted_handoff_finishes_locally() {
        let be = MockBackend::new().with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT).with_role(ReplicaRole::Prefill);
        let mut e = Engine::new(be, cfg);
        e.submit(GenRequest::greedy("park and return", 6)).unwrap();
        let mut parked = Vec::new();
        let mut rounds = 0;
        while parked.is_empty() {
            e.step().unwrap();
            parked = e.take_handoff_ready();
            rounds += 1;
            assert!(rounds < 50, "prompt never parked");
        }
        assert_eq!(e.num_pending(), 1, "migrating still counts as pending");
        for id in parked {
            assert!(e.abort_handoff(id), "parked sequence must be abortable");
        }
        // re-roled by the autoscaler mid-flight: decodes locally now
        e.set_role(ReplicaRole::Mixed);
        let r = e.run_to_completion().unwrap();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].generated_tokens, 6);
        let mut base = engine(COOPT);
        let expected = base
            .generate(vec![GenRequest::greedy("park and return", 6)])
            .unwrap();
        assert_eq!(expected[0].tokens, r[0].tokens, "abort must not change outputs");
        assert_eq!(e.metrics.migrations_out, 0);
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    #[test]
    fn handoff_survives_pool_pressure_on_both_sides() {
        // tiny destination pool: migrate-in may fall back to re-prefill
        // and decode runs under preemption — outputs must stay identical
        let mut base = tiered_engine(96, 0, SwapPolicy::Never);
        let expected = base.generate(pressure_reqs()).unwrap();
        assert_eq!(base.metrics.preemptions, 0);

        let geometry = crate::config::CacheGeometry {
            block_size: 4,
            max_blocks: 16,
            num_pool_blocks: 24,
            max_batch: 4,
            max_seq: 48,
        };
        let be = MockBackend::with_geometry(geometry).with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_host_pool(64)
            .with_swap_policy(SwapPolicy::Always)
            .with_role(ReplicaRole::Prefill);
        let mut src = Engine::new(be, cfg);
        // destination under real pressure, with a host tier sized so
        // preemption exits via swap (drop-recompute would diverge only in
        // cost, not tokens, but swap exercises the racier path)
        let geometry = crate::config::CacheGeometry {
            block_size: 4,
            max_blocks: 16,
            num_pool_blocks: 12,
            max_batch: 4,
            max_seq: 48,
        };
        let be = MockBackend::with_geometry(geometry).with_opt(COOPT);
        let cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_host_pool(64)
            .with_swap_policy(SwapPolicy::Always)
            .with_role(ReplicaRole::Decode);
        let mut dst = Engine::new(be, cfg);

        for r in pressure_reqs() {
            src.submit(r).unwrap();
        }
        drain_handoffs(&mut src, &mut dst, 6);
        let mut got = dst.run_to_completion().unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(expected.len(), got.len());
        for (a, b) in expected.iter().zip(&got) {
            assert_eq!(a.tokens, b.tokens, "pressure must not change outputs");
            assert_eq!(a.finish, b.finish);
        }
        assert_eq!(
            src.metrics.migrations_out + src.metrics.migrations_token_fallback,
            6
        );
        assert_eq!(src.cache_stats().blocks_used, 0);
        assert_eq!(src.tier_stats().host_used_blocks, 0);
        assert_eq!(dst.cache_stats().blocks_used, 0);
        assert_eq!(dst.tier_stats().host_used_blocks, 0);
    }
}
