//! Adaptive speculation: the online draft-length controller.
//!
//! PR 3's `--spec-tokens K` is a foot-gun: the right k depends on the
//! draft's acceptance rate *and* on the batch's compute regime, both of
//! which move at runtime (`CostModel::spec_crossover_acceptance` proves
//! speculation is unwinnable in the GEMM-bound large-batch regime and
//! most profitable when decode is weight-stream-bound).  This module
//! closes the loop: every scheduling round the [`SpecController`] picks
//! `k_t` from the measured acceptance and the cost model's regime
//! detector ([`CostModel::best_draft_len`]).
//!
//! **Acceptance estimator.**  Each verified position is a Bernoulli
//! trial of the per-position acceptance rate α (the geometric model the
//! cost model prices rounds with): a round that accepts `a` drafts and
//! rejects at most one examined `a + rejected` positions.  The
//! controller keeps EWMAs of the *counts* (accepted, examined) and
//! estimates α as their ratio — unlike an EWMA of per-round ratios this
//! weights rounds by evidence (a 0-of-1 round barely moves a 4-of-4
//! history) and is unbiased for the geometric model.  The estimator is
//! seeded with [`PRIOR_ACCEPTANCE`] at weight [`PRIOR_WEIGHT`], so the
//! cold start is an optimistic probe that real measurements quickly
//! overwrite.  A global estimator drives `k_t`; per-sequence estimators
//! let one hard-to-draft lane be demoted to plain decode (per-lane
//! k = 0) while easy lanes keep long drafts.
//!
//! **Decision rule**, per round with a non-empty decode batch:
//!
//! 1. `k* = best_draft_len(batch, ctx_lens, α̂)` — the cost-model search
//!    over `1..=k_max` against one-token decode (0 when nothing wins);
//! 2. the first decision jumps straight to `k*` (the cold-start probe);
//!    afterwards k moves by at most ±1 per round toward `k*` so the
//!    controller cannot oscillate across the regime boundary;
//! 3. **instant demotion**: `k* == 0` (GEMM-bound batch) or
//!    `α̂ < demote_acceptance` (acceptance collapse) drops k to 0 in one
//!    round — a collapsing draft must not be ridden down one step at a
//!    time;
//! 4. **re-probing**: plain decode produces no acceptance measurements,
//!    so a k = 0 controller would be stuck forever.  When the demotion
//!    was acceptance-driven (the cost model would still pick k > 0 at
//!    the optimistic prior), one k = 1 probe round is scheduled every
//!    [`REPROBE_ROUNDS`] plain rounds; a genuinely bad draft re-demotes
//!    immediately, a recovered one ramps back up.  Regime-driven
//!    demotion never probes — no acceptance can rescue a GEMM-bound
//!    batch, and the regime is re-evaluated from batch shape alone every
//!    round.
//!
//! Without a platform cost model the controller falls back to a pure
//! acceptance rule: `k_max` while `α̂ ≥ demote_acceptance`, else 0.
//!
//! The controller only chooses *how many* tokens to draft; acceptance
//! itself stays [`crate::sampling::verify_token`] — greedy speculation
//! remains token-for-token identical to one-token decode at every k
//! (property-tested in `tests/prop_spec.rs` while k is actively
//! changing).

use std::collections::HashMap;

use crate::config::{OptConfig, SpecConfig};
use crate::kvcache::SeqId;
use crate::platform::{CostModel, SeqCostInput};

/// Optimistic per-position acceptance assumed before any measurement
/// (the cold-start probe operating point).
pub const PRIOR_ACCEPTANCE: f64 = 0.9;
/// Pseudo-observations backing the prior: large enough that one unlucky
/// first round cannot crater the estimate, small enough that a few real
/// rounds dominate it.
pub const PRIOR_WEIGHT: f64 = 2.0;
/// Plain rounds between probes while demoted for low acceptance.
pub const REPROBE_ROUNDS: u32 = 6;

/// What the engine does with this round's decode batch.
#[derive(Debug, Clone, Default)]
pub struct RoundPlan {
    /// global draft length for the round (0 = plain one-token decode)
    pub k: usize,
    /// lanes taking the plain path even when `k > 0` (per-lane k = 0:
    /// acceptance-demoted sequences)
    pub plain: Vec<SeqId>,
    /// cost-model regime of the planned batch (`None` without a model
    /// or without decode lanes)
    pub memory_bound: Option<bool>,
}

/// EWMA acceptance state of one sequence.
#[derive(Debug, Clone)]
struct LaneAcc {
    accepted: f64,
    examined: f64,
    /// consecutive rounds this lane spent demoted (drives its re-probe)
    plain_rounds: u32,
}

impl LaneAcc {
    fn new() -> Self {
        LaneAcc {
            accepted: PRIOR_ACCEPTANCE * PRIOR_WEIGHT,
            examined: PRIOR_WEIGHT,
            plain_rounds: 0,
        }
    }

    fn rate(&self) -> f64 {
        self.accepted / self.examined
    }
}

/// Online draft-length controller (one per engine; adaptive mode only).
#[derive(Debug)]
pub struct SpecController {
    /// current global draft length
    k: usize,
    /// false until the first decision over a non-empty batch (the
    /// cold-start jump to the cost model's k* happens exactly once)
    started: bool,
    /// EWMA of accepted draft counts (global)
    accepted: f64,
    /// EWMA of examined position counts (global)
    examined: f64,
    /// consecutive acceptance-demoted rounds (drives re-probing)
    plain_rounds: u32,
    per_seq: HashMap<SeqId, LaneAcc>,
    k_max: usize,
    alpha: f64,
    demote: f64,
    shrink: f64,
    /// draft-length changes made so far (mirrored into the metrics)
    pub transitions: u64,
    /// chosen k per decision round, capped at [`Self::TRACE_CAP`]
    /// entries (the bench's chosen-k trace)
    trace: Vec<u8>,
}

impl SpecController {
    const TRACE_CAP: usize = 4096;

    pub fn new(cfg: &SpecConfig) -> Self {
        SpecController {
            k: 0,
            started: false,
            accepted: PRIOR_ACCEPTANCE * PRIOR_WEIGHT,
            examined: PRIOR_WEIGHT,
            plain_rounds: 0,
            per_seq: HashMap::new(),
            k_max: cfg.k_max,
            alpha: cfg.ewma_alpha.clamp(0.01, 1.0),
            demote: cfg.demote_acceptance,
            shrink: cfg.shrink,
            transitions: 0,
            trace: Vec::new(),
        }
    }

    /// Current global draft length.
    pub fn current_k(&self) -> usize {
        self.k
    }

    /// The EWMA per-position acceptance estimate.
    pub fn acceptance(&self) -> f64 {
        self.accepted / self.examined
    }

    /// Chosen-k decision trace (bench evidence), oldest first.
    pub fn k_trace(&self) -> &[u8] {
        &self.trace
    }

    /// Decide this round's draft length and plain-lane set.  `inputs`
    /// and `ids` describe the decode-ready batch, aligned index-wise.
    pub fn decide(
        &mut self,
        cost: Option<&CostModel>,
        inputs: &[SeqCostInput],
        ids: &[SeqId],
        opt: &OptConfig,
    ) -> RoundPlan {
        debug_assert_eq!(inputs.len(), ids.len());
        if inputs.is_empty() {
            // nothing will decode: keep all state as-is (prefill-only
            // rounds must not consume the cold start or the probe clock)
            return RoundPlan {
                k: self.k,
                plain: Vec::new(),
                memory_bound: None,
            };
        }
        let memory_bound = cost.map(|cm| cm.decode_is_memory_bound(inputs, opt));
        let a_est = self.acceptance();
        let k_star = match cost {
            Some(cm) => cm.best_draft_len(inputs, opt, self.k_max, a_est, self.shrink),
            // no platform model: pure acceptance rule
            None => {
                if a_est >= self.demote {
                    self.k_max
                } else {
                    0
                }
            }
        };
        let mut next = if !self.started {
            self.started = true;
            k_star
        } else if k_star == 0 || a_est < self.demote {
            // instant demotion: GEMM-bound batch or acceptance collapse
            0
        } else {
            k_star.clamp(self.k.saturating_sub(1), self.k + 1).min(self.k_max)
        };
        let mut probing = false;
        if next == 0 {
            // re-probe only when acceptance (not the regime) demoted us:
            // at the optimistic prior the cost model would still draft
            let prior_k = match cost {
                Some(cm) => {
                    cm.best_draft_len(inputs, opt, self.k_max, PRIOR_ACCEPTANCE, self.shrink)
                }
                None => self.k_max,
            };
            if prior_k > 0 {
                self.plain_rounds += 1;
                if self.plain_rounds >= REPROBE_ROUNDS {
                    next = 1;
                    probing = true;
                    self.plain_rounds = 0;
                }
            } else {
                self.plain_rounds = 0;
            }
        } else {
            self.plain_rounds = 0;
        }
        if next != self.k {
            self.transitions += 1;
        }
        self.k = next;
        if self.trace.len() < Self::TRACE_CAP {
            self.trace.push(next.min(u8::MAX as usize) as u8);
        }

        // per-lane demotion: a sequence whose own acceptance collapsed
        // takes the plain path while the rest of the batch keeps
        // drafting; every REPROBE_ROUNDS plain rounds it gets one probe.
        // A *global* probe round bypasses per-lane demotion entirely —
        // after a global collapse every lane's estimate is down too, and
        // demoting them all would leave the probe with nothing to
        // measure (wasting the probe and stretching recovery from
        // REPROBE_ROUNDS to its square)
        let mut plain = Vec::new();
        if next > 0 && !probing {
            for &id in ids {
                let Some(lane) = self.per_seq.get_mut(&id) else {
                    continue; // never measured: speculate optimistically
                };
                if lane.rate() >= self.demote {
                    lane.plain_rounds = 0;
                    continue;
                }
                lane.plain_rounds += 1;
                if lane.plain_rounds >= REPROBE_ROUNDS {
                    lane.plain_rounds = 0; // probe round: let it draft
                } else {
                    plain.push(id);
                }
            }
        }
        RoundPlan {
            k: next,
            plain,
            memory_bound,
        }
    }

    /// Record one lane's verify outcome: `accepted` drafts accepted,
    /// `examined = accepted + 1` if a draft was rejected (the failed
    /// trial), else `accepted`.
    pub fn observe_lane(&mut self, id: SeqId, accepted: usize, examined: usize) {
        if examined == 0 {
            return;
        }
        let lane = self.per_seq.entry(id).or_insert_with(LaneAcc::new);
        lane.accepted = (1.0 - self.alpha) * lane.accepted + self.alpha * accepted as f64;
        lane.examined = (1.0 - self.alpha) * lane.examined + self.alpha * examined as f64;
    }

    /// Fold one verify round's pooled counts into the global estimator
    /// (one EWMA step per round, however many lanes it had).
    pub fn observe_round(&mut self, accepted: usize, examined: usize) {
        if examined == 0 {
            return;
        }
        self.accepted = (1.0 - self.alpha) * self.accepted + self.alpha * accepted as f64;
        self.examined = (1.0 - self.alpha) * self.examined + self.alpha * examined as f64;
    }

    /// Seed a lane's cold-start prior from an *observed* acceptance rate
    /// (the forecast plane's per-tenant EWMA) instead of the optimistic
    /// [`PRIOR_ACCEPTANCE`].  Same pseudo-observation weight as the
    /// default prior, so real rounds dominate it just as quickly; a
    /// no-op once the lane has state — measurements are never clobbered.
    pub fn seed_lane(&mut self, id: SeqId, acceptance: f64) {
        let a = acceptance.clamp(0.0, 1.0);
        self.per_seq.entry(id).or_insert(LaneAcc {
            accepted: a * PRIOR_WEIGHT,
            examined: PRIOR_WEIGHT,
            plain_rounds: 0,
        });
    }

    /// Current acceptance estimate of one lane (prior-weighted EWMA),
    /// `None` if the lane was never seeded or measured.  Read at finish
    /// to feed the tenant's observed-acceptance memory.
    pub fn lane_rate(&self, id: SeqId) -> Option<f64> {
        self.per_seq.get(&id).map(|l| l.rate())
    }

    /// Drop a finished sequence's per-lane state.
    pub fn forget(&mut self, id: SeqId) {
        self.per_seq.remove(&id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{builtin_preset, COOPT};

    fn cfg() -> SpecConfig {
        SpecConfig {
            mode: crate::config::SpecMode::Adaptive,
            ..SpecConfig::default()
        }
    }

    fn cost() -> CostModel {
        CostModel::for_preset(&builtin_preset("llama-7b-sim").unwrap(), 16).with_ctx_scale(8.0)
    }

    fn batch(n: usize) -> (Vec<SeqCostInput>, Vec<SeqId>) {
        (
            (0..n)
                .map(|_| SeqCostInput {
                    ctx_len: 24,
                    allocated_blocks: 2,
                })
                .collect(),
            (1..=n as u64).collect(),
        )
    }

    #[test]
    fn cold_start_jumps_to_cost_model_best_then_steps_by_one() {
        let cm = cost();
        let mut c = SpecController::new(&cfg());
        let (inp, ids) = batch(1);
        // first decision: straight to the optimistic-prior best (k=4 at
        // batch 1), no ramp
        let p = c.decide(Some(&cm), &inp, &ids, &COOPT);
        assert_eq!(p.k, 4);
        assert_eq!(p.memory_bound, Some(true));
        assert!(p.plain.is_empty());
        // a weak round drags the estimate but k moves at most one step
        c.observe_round(0, 1);
        c.observe_round(1, 2);
        let a = c.acceptance();
        assert!(a < PRIOR_ACCEPTANCE);
        let p = c.decide(Some(&cm), &inp, &ids, &COOPT);
        assert!(p.k >= 3, "bounded step: 4 -> {} (acceptance {a})", p.k);
        assert!(c.transitions >= 1);
        assert_eq!(c.k_trace().first(), Some(&4u8));
    }

    #[test]
    fn estimator_is_evidence_weighted() {
        let mut c = SpecController::new(&cfg());
        // four perfect 4-of-4 rounds pull the estimate up near 1
        for _ in 0..4 {
            c.observe_round(4, 4);
        }
        assert!(c.acceptance() > 0.95, "{}", c.acceptance());
        // one 0-of-1 round barely moves a 4-of-4 history (ratio of
        // count-EWMAs, not an EWMA of ratios)
        c.observe_round(0, 1);
        assert!(c.acceptance() > 0.8, "{}", c.acceptance());
        // sustained rejection eventually collapses it
        for _ in 0..12 {
            c.observe_round(0, 1);
        }
        assert!(c.acceptance() < 0.25, "{}", c.acceptance());
    }

    #[test]
    fn acceptance_collapse_demotes_instantly_and_reprobes() {
        let cm = cost();
        let mut c = SpecController::new(&cfg());
        let (inp, ids) = batch(1);
        assert_eq!(c.decide(Some(&cm), &inp, &ids, &COOPT).k, 4);
        // collapse: every draft rejected
        for _ in 0..16 {
            c.observe_round(0, 1);
        }
        let p = c.decide(Some(&cm), &inp, &ids, &COOPT);
        assert_eq!(p.k, 0, "instant demotion, not a ±1 walk down");
        // plain rounds give no measurements; after REPROBE_ROUNDS the
        // controller schedules exactly one k=1 probe
        let mut ks = Vec::new();
        for _ in 0..(2 * REPROBE_ROUNDS) {
            ks.push(c.decide(Some(&cm), &inp, &ids, &COOPT).k);
        }
        assert_eq!(ks.iter().filter(|&&k| k == 1).count(), 2, "{ks:?}");
        assert!(ks.iter().all(|&k| k <= 1));
        // a recovered draft ramps back up from the probes
        for _ in 0..40 {
            let p = c.decide(Some(&cm), &inp, &ids, &COOPT);
            if p.k > 0 {
                c.observe_round(p.k, p.k); // perfect acceptance now
            }
        }
        assert_eq!(c.current_k(), 4, "recovery reaches k_max");
    }

    #[test]
    fn seeded_lane_prior_sticks_until_measured() {
        let mut c = SpecController::new(&cfg());
        assert_eq!(c.lane_rate(7), None, "unknown lane has no estimate");
        // a pessimistic observed-acceptance seed replaces the 0.9 prior
        c.seed_lane(7, 0.3);
        let r = c.lane_rate(7).unwrap();
        assert!((r - 0.3).abs() < 1e-12, "seeded prior readable: {r}");
        // re-seeding never clobbers existing state...
        c.seed_lane(7, 0.99);
        assert!((c.lane_rate(7).unwrap() - 0.3).abs() < 1e-12);
        // ...and neither does it survive real measurements dominating it
        for _ in 0..8 {
            c.observe_lane(7, 4, 4);
        }
        assert!(c.lane_rate(7).unwrap() > 0.8, "evidence beats the seed");
        // forget drops the lane entirely
        c.forget(7);
        assert_eq!(c.lane_rate(7), None);
    }

    #[test]
    fn global_probe_bypasses_per_lane_demotion() {
        // a global collapse drags every lane's estimate down with it;
        // the global probe round must still draft on all lanes or it
        // measures nothing and recovery stalls
        let cm = cost();
        let mut c = SpecController::new(&cfg());
        let (inp, ids) = batch(2);
        assert!(c.decide(Some(&cm), &inp, &ids, &COOPT).k > 0);
        for _ in 0..16 {
            c.observe_lane(1, 0, 1);
            c.observe_lane(2, 0, 1);
            c.observe_round(0, 2);
        }
        assert_eq!(c.decide(Some(&cm), &inp, &ids, &COOPT).k, 0, "collapsed");
        // drive to the probe round: it must arrive with an empty plain
        // set so every lane actually drafts and gets measured
        let mut probed = false;
        for _ in 0..(2 * REPROBE_ROUNDS) {
            let p = c.decide(Some(&cm), &inp, &ids, &COOPT);
            if p.k > 0 {
                probed = true;
                assert!(
                    p.plain.is_empty(),
                    "probe round demoted its own lanes: {:?}",
                    p.plain
                );
                // the probe measured a recovered draft on both lanes
                c.observe_lane(1, 1, 1);
                c.observe_lane(2, 1, 1);
                c.observe_round(2, 2);
            }
        }
        assert!(probed, "the probe round must fire within REPROBE_ROUNDS");
    }

    #[test]
    fn gemm_bound_batch_is_plain_decode_and_never_probes() {
        let cm = cost();
        let mut c = SpecController::new(&cfg());
        let (inp, ids) = batch(8);
        for _ in 0..(3 * REPROBE_ROUNDS) {
            let p = c.decide(Some(&cm), &inp, &ids, &COOPT);
            assert_eq!(p.k, 0, "GEMM-bound: speculation unwinnable");
            assert_eq!(p.memory_bound, Some(false));
        }
        // the regime is re-evaluated from batch shape: shrinking the
        // batch back to 1 lifts k without any acceptance history
        let (inp1, ids1) = batch(1);
        let p = c.decide(Some(&cm), &inp1, &ids1, &COOPT);
        assert!(p.k > 0, "regime recovery needs no probe clock");
    }

    #[test]
    fn per_lane_demotion_isolates_a_bad_lane() {
        let cm = cost();
        let mut c = SpecController::new(&cfg());
        let (inp, ids) = batch(2);
        assert!(c.decide(Some(&cm), &inp, &ids, &COOPT).k > 0);
        // lane 1 drafts perfectly, lane 2 is hopeless; the pooled global
        // estimate stays healthy
        for _ in 0..16 {
            c.observe_lane(1, 4, 4);
            c.observe_lane(2, 0, 1);
            c.observe_round(4, 5);
        }
        let p = c.decide(Some(&cm), &inp, &ids, &COOPT);
        assert!(p.k > 0, "global k survives one bad lane");
        assert_eq!(p.plain, vec![2], "only the collapsed lane is demoted");
        // the demoted lane gets a probe round every REPROBE_ROUNDS
        let mut probed = 0;
        for _ in 0..(2 * REPROBE_ROUNDS) {
            if !c.decide(Some(&cm), &inp, &ids, &COOPT).plain.contains(&2) {
                probed += 1;
            }
        }
        assert_eq!(probed, 2);
        // finishing the lane clears its state
        c.forget(2);
        assert!(c.decide(Some(&cm), &inp, &ids, &COOPT).plain.is_empty());
    }

    #[test]
    fn empty_batch_keeps_state_and_no_cost_model_falls_back() {
        let mut c = SpecController::new(&cfg());
        let p = c.decide(None, &[], &[], &COOPT);
        assert_eq!(p.k, 0);
        assert_eq!(p.memory_bound, None);
        assert!(!c.started, "prefill-only rounds must not burn the cold start");
        // acceptance-only fallback without a platform model: k_max while
        // healthy, 0 on collapse
        let (inp, ids) = batch(1);
        assert_eq!(c.decide(None, &inp, &ids, &COOPT).k, 4);
        for _ in 0..16 {
            c.observe_round(0, 1);
        }
        assert_eq!(c.decide(None, &inp, &ids, &COOPT).k, 0);
        assert!(c.transitions >= 2);
    }
}
