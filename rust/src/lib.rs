//! # LLM-CoOpt
//!
//! Reproduction of *"LLM-CoOpt: A Co-Design and Optimization Framework for
//! Efficient LLM Inference on Heterogeneous Platforms"* (Kong et al., 2026).
//!
//! This crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (paged attention, KV write, FP8 codec), authored
//!   in `python/compile/kernels/`, lowered at build time;
//! * **L2** — the JAX LLaMA-family model (`python/compile/model.py`), AOT-
//!   lowered to HLO text under `artifacts/`;
//! * **L3** — this crate: request routing, continuous batching, paged
//!   KV-cache management (the Opt-KV write path / SkipSet), PJRT execution,
//!   sampling, serving, and the DCU-Z100 platform model that carries the
//!   paper's Fig. 6/7 performance analysis.
//!
//! Python never runs on the request path; after `make artifacts` the binary
//! is self-contained.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! | module | role |
//! |--------|------|
//! | [`util`] | offline substrates: JSON, RNG, FP8, CLI, thread pool, bench, property testing |
//! | [`config`] | model/opt/engine presets mirroring `python/compile/presets.py` |
//! | [`tokenizer`] | byte-level tokenizer shared with the python trainer |
//! | [`kvcache`] | paged block allocator, block tables, slot mapping + SkipSet (Eq. 5); incremental `prefill_chunk` (Opt-Pa step 1/2); two-tier host-offload residency ([`kvcache::tier`], Opt-KV tier manager) |
//! | [`scheduler`] | continuous-batching scheduler (waiting/running/swapped) with chunked prefill: per-step token budget shared by decode slots + prefill windows; swap-aware preemption exits |
//! | [`runtime`] | PJRT artifact loading + execution with persistent buffers; `Backend::prefill_chunk` + `Backend::{swap_out,swap_in}` contracts |
//! | [`platform`] | DCU Z100 memory-hierarchy/roofline cost model (Eqs. 2–4), per-window prefill-chunk costs, PCIe swap-vs-recompute costs |
//! | [`coordinator`] | the engine: drain prefetches → schedule → commit prefill windows → decode batch → sample → stream → stage swap-ins (async prefetch, one step ahead) |
//! | [`sampling`] | greedy / temperature / top-k / top-p / MCQ scoring |
//! | [`router`] | multi-replica front-end: round_robin / least_loaded / prefix_affinity placement over N engines, per-replica drain/health, cluster metrics aggregation |
//! | [`server`] | hand-rolled HTTP/1.1 front-end + client |
//! | [`workload`] | ShareGPT-like traces, ARC-sim loader, arrival processes |
//! | [`eval`] | ARC harness reproducing Tables 1–2 |
//! | [`metrics`] | counters/histograms; Eq. 11 latency, Eq. 12 throughput |
//! | [`obs`] | request-lifecycle tracing: per-phase latency attribution, mergeable latency histograms, flight recorder, Chrome trace + Prometheus export |

pub mod config;
pub mod coordinator;
pub mod eval;
pub mod kvcache;
pub mod metrics;
pub mod obs;
pub mod platform;
pub mod router;
pub mod runtime;
pub mod sampling;
pub mod scheduler;
pub mod server;
pub mod tokenizer;
pub mod util;
pub mod workload;

pub use anyhow::{anyhow, bail, Context, Result};
