//! Continuous-batching scheduler (the vLLM-baseline substrate the paper
//! builds on: dynamic batching + sequence merging, §2), extended with
//! **chunked prefill** (Opt-Pa step 1): long prompts are segmented into
//! bounded windows that share a per-step token budget with the decode
//! batch, so a long prefill can no longer monopolize a step and starve
//! decode latency.
//!
//! Policy, per scheduling round:
//!
//! 1. **Decode batching** — every running sequence whose prefill is
//!    complete steps together, padded to the graph batch.  Decodes are
//!    reserved *first* out of the step budget, so they are never starved
//!    by prefill work.
//! 2. **Prefill continuation** — partially-prefilled running sequences
//!    (tracked by per-sequence prefill progress) get their next
//!    window, oldest first, capped by the per-chunk token limit and the
//!    budget left after decodes.  Non-final windows are aligned down to a
//!    block boundary so full blocks stay shareable via the prefix index.
//! 3. **Prefill admission** — waiting sequences are admitted FCFS while
//!    there is batch headroom and budget, if the [`CacheManager`] can
//!    commit their first window (admission differs by opt-config: the
//!    baseline's padded writes need more blocks, so Opt-KV literally
//!    admits more load).  One-shot mode (chunking off) keeps the seed
//!    behaviour: whole-prompt admission, at most one prefill per round,
//!    and the admitted sequence joins the decode batch immediately.
//! 4. **Preemption** — if a step cannot get a block, the most-recently-
//!    admitted running sequence is evicted.  Two exits exist: *drop*
//!    (blocks freed, the sequence re-enters the waiting queue with its
//!    full token prefix and re-prefills from offset 0 — vLLM's recompute
//!    preemption) and *swap* (the Opt-KV tier manager moved its blocks to
//!    the host tier; the sequence enters the `Swapped` state keeping its
//!    prefill progress, and is re-admitted via prefetch completion at its
//!    exact decode offset instead of re-queuing as a fresh prefill).  The
//!    engine chooses per victim with a cost model.  Mid-prefill sequences
//!    that merely run out of *budget* are not preempted — they resume
//!    from their committed offset on the next round.

use std::collections::VecDeque;

use crate::config::{OptConfig, Priority};
use crate::kvcache::{CacheManager, SeqId};

/// Scheduler's view of a sequence.
#[derive(Debug, Clone)]
struct Entry {
    id: SeqId,
    /// tokens that must be prefilled into the cache on (re)admission
    prefix_len: usize,
    /// PrefillProgress: prompt tokens already committed to the cache
    prefill_done: usize,
    /// admission order stamp (for preemption: newest goes first)
    admitted_at: u64,
    /// SLO class: interactive outranks batch in the waiting/swapped
    /// orderings, and batch lanes are the preferred preemption victims
    class: Priority,
}

/// One prefill window planned for this round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillWork {
    pub id: SeqId,
    /// tokens already committed (the window starts here)
    pub offset: usize,
    /// tokens to commit this round
    pub tokens: usize,
    /// true when this window completes the prompt
    pub is_final: bool,
}

#[derive(Debug, Clone, Default)]
pub struct ScheduleDecision {
    /// prefill windows to commit this round (one-shot mode: at most one,
    /// covering a whole prompt; chunked mode: at most one per sequence)
    pub prefills: Vec<PrefillWork>,
    /// running sequences to decode-step together
    pub decodes: Vec<SeqId>,
    /// sequences preempted this round (already moved back to waiting)
    pub preempted: Vec<SeqId>,
    /// sequences admitted out of the waiting queue this round (their
    /// first prefill window is in `prefills`); the coordinator uses this
    /// to stamp the Queued→Prefill transition on the request trace
    pub admitted: Vec<SeqId>,
}

impl ScheduleDecision {
    /// Ids carrying prefill work this round, in plan order.
    pub fn prefill_ids(&self) -> Vec<SeqId> {
        self.prefills.iter().map(|w| w.id).collect()
    }

    /// Total prefill tokens planned this round.
    pub fn prefill_tokens(&self) -> usize {
        self.prefills.iter().map(|w| w.tokens).sum()
    }
}

#[derive(Debug)]
pub struct Scheduler {
    waiting: VecDeque<Entry>,
    running: Vec<Entry>,
    /// sequences preempted to the host tier (Opt-KV tier manager); they
    /// keep their prefill progress and resume via swap-in, not re-prefill
    swapped: Vec<Entry>,
    /// sequences mid-hand-off to another replica (PD disaggregation);
    /// invisible to scheduling and preemption — the hand-off either
    /// completes (the destination admits them) or aborts back to running
    migrating: Vec<Entry>,
    max_batch: usize,
    /// shared per-step token budget (decode slots + prefill tokens)
    step_token_budget: usize,
    /// budget tokens one decode lane may commit per round: 1, or
    /// 1 + draft length under speculative decoding (each verify pass can
    /// commit the accepted prefix plus one corrected token).  Adaptive
    /// speculation re-sets this *per round* ([`Self::set_spec_round`])
    /// so the shared budget always charges the k actually in flight.
    decode_tokens_per_seq: usize,
    /// lanes charged 1 token this round regardless of
    /// `decode_tokens_per_seq` (per-lane k = 0: controller-demoted or
    /// too close to max context to take a k+1 reservation)
    plain_lanes: Vec<SeqId>,
    /// chunked prefill on/off + per-chunk cap
    chunked: bool,
    chunk_tokens: usize,
    /// fraction of the post-decode prefill budget reserved for
    /// interactive sequences while any interactive prefill is pending
    /// (SLO overload control; 0 = no split)
    interactive_reserve: f64,
    stamp: u64,
    pub total_preemptions: u64,
    pub total_admissions: u64,
    /// prefill windows handed out (chunked mode accounting)
    pub total_chunks: u64,
    /// preemptions that exited via the host tier instead of recompute
    pub total_swap_preemptions: u64,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Self {
        Scheduler {
            waiting: VecDeque::new(),
            running: Vec::new(),
            swapped: Vec::new(),
            migrating: Vec::new(),
            max_batch,
            step_token_budget: usize::MAX,
            decode_tokens_per_seq: 1,
            plain_lanes: Vec::new(),
            chunked: false,
            chunk_tokens: 32,
            interactive_reserve: 0.0,
            stamp: 0,
            total_preemptions: 0,
            total_admissions: 0,
            total_chunks: 0,
            total_swap_preemptions: 0,
        }
    }

    /// Cap the shared per-step token budget (decode slots + prefill).
    pub fn with_step_budget(mut self, tokens: usize) -> Self {
        self.step_token_budget = tokens.max(1);
        self
    }

    /// Enable chunked prefill with a per-chunk token cap.
    pub fn with_chunked_prefill(mut self, chunk_tokens: usize) -> Self {
        self.chunked = true;
        self.chunk_tokens = chunk_tokens.max(1);
        self
    }

    /// Reserve a fraction of the post-decode prefill budget for
    /// interactive sequences while any interactive prefill is pending,
    /// so a batch prefill burst cannot starve interactive TTFT (clamped
    /// to `0.0..=0.9`; 0 disables the split).
    pub fn with_interactive_reserve(mut self, frac: f64) -> Self {
        self.interactive_reserve = frac.clamp(0.0, 0.9);
        self
    }

    /// Speculative decoding: each decode lane may commit up to
    /// `1 + draft_tokens` tokens per round, and is charged that many
    /// tokens of the shared step budget up front, so prefill windows
    /// shrink accordingly and the shared bound keeps holding.
    pub fn with_speculation(mut self, draft_tokens: usize) -> Self {
        self.decode_tokens_per_seq = 1 + draft_tokens;
        self
    }

    /// Adaptive speculation: set this round's draft length and the lanes
    /// taking the plain one-token path (per-lane k = 0).  The next
    /// [`Self::schedule`] charges each decode lane exactly `1 + k_lane`
    /// budget tokens — k shrinking immediately widens the prefill windows
    /// of the very next step, and k growing only re-slices the *fixed*
    /// step budget (a user's tight prefill bound is never inflated; when
    /// the speculative reserve eats the whole budget the one-token
    /// progress floor still advances prefill).
    pub fn set_spec_round(&mut self, draft_tokens: usize, plain_lanes: Vec<SeqId>) {
        self.decode_tokens_per_seq = 1 + draft_tokens;
        self.plain_lanes = plain_lanes;
    }

    /// Budget tokens one decode lane is charged this round.
    fn decode_charge(&self, id: SeqId) -> usize {
        if self.plain_lanes.contains(&id) {
            1
        } else {
            self.decode_tokens_per_seq
        }
    }

    /// Running sequences whose prefill is complete — the candidates for
    /// the next decode batch, in admission order (what the adaptive
    /// speculation controller sizes its cost-model batch from).
    pub fn decode_ready_ids(&self) -> Vec<SeqId> {
        self.running
            .iter()
            .filter(|e| e.prefill_done >= e.prefix_len)
            .map(|e| e.id)
            .collect()
    }

    pub fn is_chunked(&self) -> bool {
        self.chunked
    }

    /// Enqueue a new request (prompt not yet in cache) in the default
    /// (interactive) class.
    pub fn submit(&mut self, id: SeqId, prompt_len: usize) {
        self.submit_class(id, prompt_len, Priority::Interactive);
    }

    /// Enqueue a new request with an explicit SLO class.  Interactive
    /// entries outrank batch ones at admission time; FCFS holds within a
    /// class.
    pub fn submit_class(&mut self, id: SeqId, prompt_len: usize, class: Priority) {
        self.waiting.push_back(Entry {
            id,
            prefix_len: prompt_len,
            prefill_done: 0,
            admitted_at: 0,
            class,
        });
    }

    /// Next admission candidate: the oldest waiting interactive entry,
    /// else the queue head.  (Two-level ordering: class first, FCFS
    /// within a class; an all-one-class queue degenerates to plain FCFS.)
    fn next_waiting_idx(&self) -> Option<usize> {
        if self.waiting.is_empty() {
            return None;
        }
        self.waiting
            .iter()
            .position(|e| e.class.is_interactive())
            .or(Some(0))
    }

    /// SLO class of a tracked sequence (any state), if known.
    pub fn class_of(&self, id: SeqId) -> Option<Priority> {
        self.running
            .iter()
            .chain(self.waiting.iter())
            .chain(self.swapped.iter())
            .chain(self.migrating.iter())
            .find(|e| e.id == id)
            .map(|e| e.class)
    }

    pub fn num_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    pub fn num_swapped(&self) -> usize {
        self.swapped.len()
    }

    pub fn num_migrating(&self) -> usize {
        self.migrating.len()
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty()
            && self.running.is_empty()
            && self.swapped.is_empty()
            && self.migrating.is_empty()
    }

    pub fn running_ids(&self) -> Vec<SeqId> {
        self.running.iter().map(|e| e.id).collect()
    }

    /// Committed prefill tokens of a running sequence (its PrefillProgress).
    pub fn prefill_progress(&self, id: SeqId) -> Option<usize> {
        self.running.iter().find(|e| e.id == id).map(|e| e.prefill_done)
    }

    /// The engine reports a committed window; progress never exceeds the
    /// prefix (one-shot admission pre-marks the whole prompt, making the
    /// engine's report a no-op there).
    pub fn record_prefill_progress(&mut self, id: SeqId, tokens: usize) {
        if let Some(e) = self.running.iter_mut().find(|e| e.id == id) {
            e.prefill_done = (e.prefill_done + tokens).min(e.prefix_len);
        }
    }

    /// Remove a finished sequence from the running (or swapped/migrating)
    /// set.
    pub fn finish(&mut self, id: SeqId) {
        // waiting too: deadline enforcement can cancel a request that was
        // never admitted, and a ghost waiting entry would be re-admitted
        // with no sequence behind it
        self.waiting.retain(|e| e.id != id);
        self.running.retain(|e| e.id != id);
        self.swapped.retain(|e| e.id != id);
        self.migrating.retain(|e| e.id != id);
    }

    /// Plan the next round.  `cache` is consulted for admission headroom;
    /// nothing is allocated here (the coordinator commits the plan).
    pub fn schedule(&mut self, cache: &CacheManager, opt: &OptConfig) -> ScheduleDecision {
        if self.chunked {
            self.schedule_chunked(cache, opt)
        } else {
            self.schedule_oneshot(cache, opt)
        }
    }

    fn schedule_oneshot(&mut self, cache: &CacheManager, opt: &OptConfig) -> ScheduleDecision {
        let mut d = ScheduleDecision::default();

        // 1. admit one waiting sequence if there's room and it fits the
        // step budget in one shot.  Swapped sequences outrank waiting
        // ones (running > swapped > waiting): while any sequence sits in
        // the host tier, its resume gets the freed blocks, not a new
        // admission — otherwise sustained traffic starves it forever.
        if self.swapped.is_empty() && self.running.len() < self.max_batch {
            if let Some(idx) = self.next_waiting_idx() {
                let front = &self.waiting[idx];
                if front.prefix_len <= self.step_token_budget
                    && cache.can_admit(front.prefix_len, opt)
                {
                    let mut e = self.waiting.remove(idx).unwrap();
                    self.stamp += 1;
                    e.admitted_at = self.stamp;
                    // whole prompt lands this round
                    e.prefill_done = e.prefix_len;
                    d.prefills.push(PrefillWork {
                        id: e.id,
                        offset: 0,
                        tokens: e.prefix_len,
                        is_final: true,
                    });
                    self.total_admissions += 1;
                    d.admitted.push(e.id);
                    self.running.push(e);
                }
            }
        }

        // 2. decode everything running (including the fresh prefill's seq —
        // the coordinator prefills first, then decode-steps the batch)
        d.decodes = self
            .running
            .iter()
            .map(|e| e.id)
            .take(self.max_batch)
            .collect();
        d
    }

    fn schedule_chunked(&mut self, cache: &CacheManager, opt: &OptConfig) -> ScheduleDecision {
        let mut d = ScheduleDecision::default();
        let bs = cache.geometry.block_size.max(1);

        // 1. decode batch: sequences whose prefill is complete
        d.decodes = self
            .running
            .iter()
            .filter(|e| e.prefill_done >= e.prefix_len)
            .map(|e| e.id)
            .take(self.max_batch)
            .collect();

        // 2. shared budget: decode slots are reserved first — charged at
        // the full speculative commit width, so a verify pass never
        // overdraws the budget — and decodes are never starved by prefill
        // work.  If the decode batch alone meets the budget, one token is
        // still granted so prefill can never be starved either (the
        // engine sizes the budget above the decode reserve, making the
        // shared bound strict in practice).
        let budget = self.step_token_budget.max(1);
        let decode_charge: usize = d.decodes.iter().map(|&id| self.decode_charge(id)).sum();
        let mut remaining = budget.saturating_sub(decode_charge);
        if remaining == 0
            && (!self.waiting.is_empty()
                || self.running.iter().any(|e| e.prefill_done < e.prefix_len))
        {
            remaining = 1;
        }

        // SLO prefill split: while any interactive prefill is pending,
        // batch sequences may spend at most (1 - reserve) of the
        // post-decode budget, so a batch prefill burst cannot starve
        // interactive TTFT.  With no interactive work pending (or reserve
        // 0) batch gets the whole budget and nothing changes.
        let interactive_pending = self
            .waiting
            .iter()
            .any(|e| e.class.is_interactive())
            || self
                .running
                .iter()
                .any(|e| e.class.is_interactive() && e.prefill_done < e.prefix_len);
        let mut batch_remaining = if self.interactive_reserve > 0.0 && interactive_pending {
            ((remaining as f64) * (1.0 - self.interactive_reserve)).floor() as usize
        } else {
            remaining
        };

        // 3. continue partially-prefilled sequences: interactive first,
        // then oldest first within a class
        let mut mid: Vec<usize> = (0..self.running.len())
            .filter(|&i| self.running[i].prefill_done < self.running[i].prefix_len)
            .collect();
        mid.sort_by_key(|&i| {
            (
                !self.running[i].class.is_interactive(),
                self.running[i].admitted_at,
            )
        });
        for i in mid {
            if remaining == 0 {
                break;
            }
            let e = &self.running[i];
            let is_batch = !e.class.is_interactive();
            let cap = if is_batch {
                self.chunk_tokens.min(remaining).min(batch_remaining)
            } else {
                self.chunk_tokens.min(remaining)
            };
            let take = chunk_span(e.prefill_done, e.prefix_len, cap, bs);
            if take == 0 {
                continue;
            }
            d.prefills.push(PrefillWork {
                id: e.id,
                offset: e.prefill_done,
                tokens: take,
                is_final: e.prefill_done + take == e.prefix_len,
            });
            self.total_chunks += 1;
            remaining -= take;
            if is_batch {
                batch_remaining = batch_remaining.saturating_sub(take);
            }
        }

        // 4. admit waiting sequences (interactive outranking batch) while
        // batch headroom and budget remain — unless sequences sit in the
        // host tier: swapped outranks waiting (running > swapped >
        // waiting), so their prefetch gets the freed blocks first
        while self.swapped.is_empty() && remaining > 0 && self.running.len() < self.max_batch {
            let Some(idx) = self.next_waiting_idx() else { break };
            let front = &self.waiting[idx];
            let is_batch = !front.class.is_interactive();
            if is_batch && batch_remaining == 0 {
                break;
            }
            // the whole prompt must eventually fit the pool, and the first
            // window must fit right now
            let whole_blocks = cache.blocks_needed_prefill(front.prefix_len, opt) + 1;
            if whole_blocks > cache.geometry.num_pool_blocks {
                break;
            }
            let cap = if is_batch {
                self.chunk_tokens.min(remaining).min(batch_remaining)
            } else {
                self.chunk_tokens.min(remaining)
            };
            let take = chunk_span(0, front.prefix_len, cap, bs);
            if take == 0 || !cache.can_admit_tokens(take, opt) {
                break;
            }
            let mut e = self.waiting.remove(idx).unwrap();
            self.stamp += 1;
            e.admitted_at = self.stamp;
            e.prefill_done = 0;
            d.prefills.push(PrefillWork {
                id: e.id,
                offset: 0,
                tokens: take,
                is_final: take == e.prefix_len,
            });
            self.total_admissions += 1;
            self.total_chunks += 1;
            remaining -= take;
            if is_batch {
                batch_remaining = batch_remaining.saturating_sub(take);
            }
            d.admitted.push(e.id);
            self.running.push(e);
        }
        d
    }

    /// The sequence preemption would evict next, with nothing moved yet —
    /// the engine decides swap vs drop per victim.  Batch lanes are the
    /// preferred victims (newest batch admission first); only an
    /// all-interactive batch falls back to the classic newest-admission
    /// order, so interactive KV survives overload longest.
    pub fn peek_preempt_victim(&self) -> Option<SeqId> {
        self.peek_preempt_victim_by(|_| None)
    }

    /// [`Scheduler::peek_preempt_victim`] with a forecast hint: among
    /// the class-preferred candidates, evict the lane with the most
    /// predicted *remaining* tokens (furthest from finishing — its KV
    /// would occupy the device longest before paying off).  `remaining`
    /// returns `None` for lanes without an in-band length forecast;
    /// hinted lanes always outrank unhinted ones, ties and the all-
    /// `None` case fall back to newest-admission order exactly, so a
    /// cold or out-of-band estimator reproduces the reactive choice
    /// bit-for-bit.
    pub fn peek_preempt_victim_by<F>(&self, remaining: F) -> Option<SeqId>
    where
        F: Fn(SeqId) -> Option<u64>,
    {
        let pick = |it: &mut dyn Iterator<Item = &Entry>| {
            it.max_by_key(|e| (remaining(e.id).map(|r| (1u8, r)), e.admitted_at))
                .map(|e| e.id)
        };
        pick(&mut self.running.iter().filter(|e| !e.class.is_interactive()))
            .or_else(|| pick(&mut self.running.iter()))
    }

    fn take_running(&mut self, id: SeqId) -> Option<Entry> {
        let idx = self.running.iter().position(|e| e.id == id)?;
        Some(self.running.remove(idx))
    }

    /// Preempt `id` by recompute: back to the waiting queue with its full
    /// token count as the re-prefill prefix, progress reset.
    pub fn preempt_drop(&mut self, id: SeqId, current_len: usize) -> bool {
        let Some(mut e) = self.take_running(id) else {
            return false;
        };
        e.prefix_len = current_len;
        // recompute preemption drops the committed KV, so prefill restarts
        e.prefill_done = 0;
        self.waiting.push_front(e);
        self.total_preemptions += 1;
        true
    }

    /// Preempt `id` by swap: into the `Swapped` state with its prefill
    /// progress intact (the cache keeps the committed KV in the host
    /// tier; on resume the sequence continues at its exact offset).
    pub fn preempt_swap(&mut self, id: SeqId) -> bool {
        let Some(e) = self.take_running(id) else {
            return false;
        };
        self.swapped.push(e);
        self.total_preemptions += 1;
        self.total_swap_preemptions += 1;
        true
    }

    /// A swapped sequence's blocks are device-resident again: rejoin the
    /// running set (decode batch or prefill continuation, depending on
    /// its preserved progress).  The entry re-enters at its
    /// admission-stamp position, preserving the invariant that `running`
    /// is ordered oldest-first (the preemption victim is always the last,
    /// not-yet-stepped lane of a decode round).
    pub fn resume_swapped(&mut self, id: SeqId) -> bool {
        let Some(idx) = self.swapped.iter().position(|e| e.id == id) else {
            return false;
        };
        let e = self.swapped.remove(idx);
        let at = self
            .running
            .iter()
            .position(|r| r.admitted_at > e.admitted_at)
            .unwrap_or(self.running.len());
        self.running.insert(at, e);
        true
    }

    /// Abandon a swapped sequence's host copy: requeue it as a fresh
    /// recompute prefill (the tier manager could not bring it back).
    pub fn drop_swapped(&mut self, id: SeqId, current_len: usize) -> bool {
        let Some(idx) = self.swapped.iter().position(|e| e.id == id) else {
            return false;
        };
        let mut e = self.swapped.remove(idx);
        e.prefix_len = current_len;
        e.prefill_done = 0;
        self.waiting.push_front(e);
        true
    }

    /// Swapped sequence ids in prefetch order: interactive before batch,
    /// oldest admission first within a class — swapped-out interactive
    /// work resumes ahead of parked batch work.
    pub fn swapped_ids(&self) -> Vec<SeqId> {
        let mut v: Vec<(bool, u64, SeqId)> = self
            .swapped
            .iter()
            .map(|e| (!e.class.is_interactive(), e.admitted_at, e.id))
            .collect();
        v.sort_unstable();
        v.into_iter().map(|(_, _, id)| id).collect()
    }

    // --- PD disaggregation: the `Migrating` hand-off state -----------------

    /// Move a running sequence into the `Migrating` hand-off state: it
    /// leaves scheduling (and the preemption victim pool, which only
    /// scans `running`) while the engine packages its hand-off envelope.
    pub fn begin_migration(&mut self, id: SeqId) -> bool {
        let Some(e) = self.take_running(id) else {
            return false;
        };
        self.migrating.push(e);
        true
    }

    /// The hand-off left this replica (the destination owns the sequence
    /// now): drop the local entry.
    pub fn complete_migration(&mut self, id: SeqId) -> bool {
        let before = self.migrating.len();
        self.migrating.retain(|e| e.id != id);
        self.migrating.len() < before
    }

    /// The hand-off found no destination: the sequence returns to the
    /// running set at its admission-stamp position (same ordering
    /// invariant as [`Self::resume_swapped`]) and decodes here.
    pub fn abort_migration(&mut self, id: SeqId) -> bool {
        let Some(idx) = self.migrating.iter().position(|e| e.id == id) else {
            return false;
        };
        let e = self.migrating.remove(idx);
        let at = self
            .running
            .iter()
            .position(|r| r.admitted_at > e.admitted_at)
            .unwrap_or(self.running.len());
        self.running.insert(at, e);
        true
    }

    /// Admit a migrated-in sequence on the destination replica, already
    /// prefilled through `prefix_len` tokens: it joins `running`
    /// decode-ready at its exact committed offset (no re-prefill).  The
    /// hand-off envelope carries the SLO class across replicas.
    pub fn admit_migrated(&mut self, id: SeqId, prefix_len: usize, class: Priority) {
        self.stamp += 1;
        self.running.push(Entry {
            id,
            prefix_len,
            prefill_done: prefix_len,
            admitted_at: self.stamp,
            class,
        });
        self.total_admissions += 1;
    }
}

/// Size of the next prefill window: `cap`-bounded remainder, aligned down
/// to a block boundary when another window must follow (so full blocks
/// stay shareable through the prefix index).  Falls back to an unaligned
/// window when alignment would make no progress.
fn chunk_span(offset: usize, target: usize, cap: usize, bs: usize) -> usize {
    let rem = target.saturating_sub(offset);
    let take = rem.min(cap);
    if take < rem {
        let aligned_end = (offset + take) / bs * bs;
        if aligned_end > offset {
            return aligned_end - offset;
        }
    }
    take
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CacheGeometry, COOPT};

    fn cache() -> CacheManager {
        CacheManager::new(CacheGeometry {
            block_size: 4,
            max_blocks: 8,
            num_pool_blocks: 8,
            max_batch: 4,
            max_seq: 16,
        })
    }

    /// Big pool for chunked-policy tests that never touch the cache.
    fn roomy_cache() -> CacheManager {
        CacheManager::new(CacheGeometry {
            block_size: 4,
            max_blocks: 32,
            num_pool_blocks: 128,
            max_batch: 8,
            max_seq: 128,
        })
    }

    #[test]
    fn fcfs_admission() {
        let mut s = Scheduler::new(2);
        let c = cache();
        s.submit(1, 4);
        s.submit(2, 4);
        s.submit(3, 4);
        let d1 = s.schedule(&c, &COOPT);
        assert_eq!(d1.prefill_ids(), vec![1]);
        assert_eq!(d1.prefills[0], PrefillWork { id: 1, offset: 0, tokens: 4, is_final: true });
        assert_eq!(d1.decodes, vec![1]);
        let d2 = s.schedule(&c, &COOPT);
        assert_eq!(d2.prefill_ids(), vec![2]);
        assert_eq!(d2.decodes, vec![1, 2]);
        // batch full: seq 3 must wait
        let d3 = s.schedule(&c, &COOPT);
        assert!(d3.prefills.is_empty());
        assert_eq!(s.num_waiting(), 1);
    }

    #[test]
    fn admission_respects_cache() {
        let mut s = Scheduler::new(8);
        let mut c = cache(); // 8 blocks total
        // occupy 7 of 8 blocks so a 4-token prompt (1 block + 1 headroom)
        // cannot be admitted
        for id in 100..107u64 {
            c.prefill(id, &(0..4).map(|x| id as u32 + x).collect::<Vec<_>>(), &COOPT)
                .unwrap();
        }
        assert_eq!(c.num_free_blocks(), 1);
        s.submit(1, 4);
        let d = s.schedule(&c, &COOPT);
        assert!(d.prefills.is_empty(), "no admission without headroom");
        c.free_seq(100);
        c.free_seq(101);
        let d = s.schedule(&c, &COOPT);
        assert_eq!(d.prefill_ids(), vec![1]);
    }

    #[test]
    fn finish_frees_batch_slot() {
        let mut s = Scheduler::new(1);
        let c = cache();
        s.submit(1, 4);
        s.submit(2, 4);
        s.schedule(&c, &COOPT);
        assert_eq!(s.num_running(), 1);
        s.finish(1);
        let d = s.schedule(&c, &COOPT);
        assert_eq!(d.prefill_ids(), vec![2]);
    }

    #[test]
    fn preempts_newest_first() {
        let mut s = Scheduler::new(4);
        let c = cache();
        for id in 1..=3u64 {
            s.submit(id, 4);
            s.schedule(&c, &COOPT);
        }
        assert_eq!(s.num_running(), 3);
        let victim = s.peek_preempt_victim().unwrap();
        assert_eq!(victim, 3, "newest admitted preempted first");
        assert!(s.preempt_drop(victim, 7));
        assert_eq!(s.num_waiting(), 1);
        // re-admitted at front with its grown prefix
        let d = s.schedule(&c, &COOPT);
        assert_eq!(d.prefill_ids(), vec![3]);
        assert_eq!(d.prefills[0].tokens, 7);
        assert_eq!(s.total_preemptions, 1);
    }

    #[test]
    fn idle_detection() {
        let mut s = Scheduler::new(2);
        assert!(s.is_idle());
        s.submit(1, 4);
        assert!(!s.is_idle());
        let c = cache();
        s.schedule(&c, &COOPT);
        s.finish(1);
        assert!(s.is_idle());
    }

    #[test]
    fn oneshot_budget_blocks_oversized_prompts() {
        let mut s = Scheduler::new(4).with_step_budget(16);
        let c = roomy_cache();
        s.submit(1, 20); // exceeds the one-shot step budget
        let d = s.schedule(&c, &COOPT);
        assert!(d.prefills.is_empty());
        assert_eq!(s.num_waiting(), 1);
        // the same prompt is servable once chunking is on
        let mut s = Scheduler::new(4).with_step_budget(16).with_chunked_prefill(8);
        s.submit(1, 20);
        let d = s.schedule(&c, &COOPT);
        assert_eq!(d.prefill_ids(), vec![1]);
        assert!(d.prefills[0].tokens <= 16);
    }

    #[test]
    fn admissions_reported_once_per_sequence() {
        // both modes: `admitted` names each sequence exactly the round its
        // first window is planned, and never again (trace transitions
        // depend on this being exact)
        for chunked in [false, true] {
            let c = roomy_cache();
            let mut s = Scheduler::new(2).with_step_budget(64);
            if chunked {
                s = s.with_chunked_prefill(8);
            }
            for id in 1..=3u64 {
                s.submit(id, 10);
            }
            let mut admitted = Vec::new();
            for _ in 0..12 {
                let d = s.schedule(&c, &COOPT);
                for w in &d.prefills {
                    s.record_prefill_progress(w.id, w.tokens);
                }
                for &id in &d.admitted {
                    assert!(
                        d.prefills.iter().any(|w| w.id == id && w.offset == 0),
                        "admitted {id} without its first window (chunked={chunked})"
                    );
                }
                admitted.extend(d.admitted.iter().copied());
                if admitted.len() == 2 {
                    break;
                }
            }
            // batch cap 2: the third stays waiting; no duplicates
            admitted.sort_unstable();
            assert_eq!(admitted, vec![1, 2], "chunked={chunked}");
        }
    }

    /// Drive a chunked scheduler round and apply its prefill plan, the way
    /// the engine would.
    fn apply(s: &mut Scheduler, c: &CacheManager) -> ScheduleDecision {
        let d = s.schedule(c, &COOPT);
        for w in &d.prefills {
            s.record_prefill_progress(w.id, w.tokens);
        }
        d
    }

    #[test]
    fn chunked_long_prompt_progresses_in_aligned_windows() {
        let mut s = Scheduler::new(4).with_step_budget(64).with_chunked_prefill(8);
        let c = roomy_cache(); // block_size 4
        s.submit(1, 27);
        let mut offsets = Vec::new();
        for _ in 0..10 {
            let d = apply(&mut s, &c);
            if let Some(w) = d.prefills.first() {
                offsets.push((w.offset, w.tokens, w.is_final));
            }
            if s.prefill_progress(1) == Some(27) {
                break;
            }
        }
        // windows resume exactly where the previous one ended
        let mut expect = 0;
        for &(off, tok, _) in &offsets {
            assert_eq!(off, expect);
            expect += tok;
        }
        assert_eq!(expect, 27);
        // every non-final window ends on a block boundary
        for &(off, tok, fin) in &offsets {
            if !fin {
                assert_eq!((off + tok) % 4, 0, "window [{off}, {})", off + tok);
            }
            assert!(tok <= 8);
        }
        assert!(offsets.last().unwrap().2, "last window is final");
    }

    #[test]
    fn chunked_step_never_exceeds_token_budget() {
        let budget = 12;
        let mut s = Scheduler::new(8).with_step_budget(budget).with_chunked_prefill(8);
        let c = roomy_cache();
        for id in 1..=6u64 {
            s.submit(id, 10 + (id as usize * 3) % 17);
        }
        for _ in 0..40 {
            let d = apply(&mut s, &c);
            assert!(
                d.prefill_tokens() + d.decodes.len() <= budget,
                "prefill {} + decodes {} exceeds budget {budget}",
                d.prefill_tokens(),
                d.decodes.len()
            );
            // mid-prefill sequences never appear in the decode batch
            for id in &d.decodes {
                let done = s.prefill_progress(*id).unwrap();
                assert!(done > 0, "decoding sequence {id} with no committed prefill");
            }
            if s.running_ids().iter().all(|&id| s.prefill_progress(id).unwrap_or(0) > 0)
                && s.num_waiting() == 0
                && d.prefill_tokens() == 0
            {
                break;
            }
        }
    }

    #[test]
    fn chunked_decodes_are_never_starved() {
        // a fat queue of long prompts must not stall sequences that are
        // already decoding: every round schedules all completed sequences
        let mut s = Scheduler::new(4).with_step_budget(10).with_chunked_prefill(8);
        let c = roomy_cache();
        s.submit(1, 4);
        let d = apply(&mut s, &c);
        assert_eq!(d.prefills[0], PrefillWork { id: 1, offset: 0, tokens: 4, is_final: true });
        assert!(d.decodes.is_empty(), "prefill completes before first decode");
        for id in 2..=5u64 {
            s.submit(id, 40);
        }
        for _ in 0..30 {
            let d = apply(&mut s, &c);
            assert!(
                d.decodes.contains(&1),
                "completed sequence starved: decodes {:?}",
                d.decodes
            );
        }
        assert!(s.total_chunks > 0);
    }

    #[test]
    fn tiny_budget_still_grants_prefill_progress() {
        let mut s = Scheduler::new(4).with_step_budget(3).with_chunked_prefill(8);
        let c = roomy_cache();
        for id in 1..=3u64 {
            s.submit(id, 2);
        }
        // drive until all three short prompts are fully prefilled
        for _ in 0..10 {
            apply(&mut s, &c);
        }
        s.submit(9, 8);
        let d = apply(&mut s, &c);
        assert_eq!(d.decodes.len(), 3, "decode batch saturates the budget");
        assert_eq!(d.prefill_tokens(), 1, "progress floor grants one token");
        // the floor keeps the shared bound within one token of the budget
        assert!(d.prefill_tokens() + d.decodes.len() <= 3 + 1);
        // and the long prompt keeps progressing to completion
        for _ in 0..10 {
            apply(&mut s, &c);
        }
        assert_eq!(s.prefill_progress(9), Some(8));
    }

    #[test]
    fn speculative_tokens_charge_the_shared_budget() {
        // 3 decoding lanes at draft length 3 reserve 3 * (1+3) = 12 of a
        // 16-token budget; prefill windows get what is left
        let mut s = Scheduler::new(4)
            .with_step_budget(16)
            .with_chunked_prefill(8)
            .with_speculation(3);
        let c = roomy_cache();
        for id in 1..=3u64 {
            s.submit(id, 2);
        }
        for _ in 0..4 {
            apply(&mut s, &c); // short prompts complete their prefill
        }
        s.submit(9, 20);
        let d = apply(&mut s, &c);
        assert_eq!(d.decodes.len(), 3);
        assert!(
            d.prefill_tokens() <= 16 - 3 * 4,
            "prefill {} must fit the budget after the speculative reserve",
            d.prefill_tokens()
        );
        assert!(d.prefill_tokens() > 0, "and prefill still progresses");
        // without speculation the same round grants more prefill
        let mut s1 = Scheduler::new(4).with_step_budget(16).with_chunked_prefill(8);
        for id in 1..=3u64 {
            s1.submit(id, 2);
        }
        for _ in 0..4 {
            apply(&mut s1, &c);
        }
        s1.submit(9, 20);
        let d1 = apply(&mut s1, &c);
        assert!(d1.prefill_tokens() > d.prefill_tokens());
    }

    #[test]
    fn shrinking_k_immediately_widens_prefill_windows() {
        // 3 decoding lanes, 16-token budget: at k=3 the speculative
        // reserve is 12 tokens; dropping to k=1 the very next round must
        // free 6 of them for prefill — no lag, no hysteresis
        let mut s = Scheduler::new(4)
            .with_step_budget(16)
            .with_chunked_prefill(8)
            .with_speculation(3);
        let c = roomy_cache();
        for id in 1..=3u64 {
            s.submit(id, 2);
        }
        for _ in 0..4 {
            apply(&mut s, &c); // short prompts complete their prefill
        }
        s.submit(9, 40);
        let d_k3 = apply(&mut s, &c);
        assert_eq!(d_k3.decodes.len(), 3);
        assert!(d_k3.prefill_tokens() <= 16 - 3 * 4);
        s.set_spec_round(1, Vec::new());
        let d_k1 = apply(&mut s, &c);
        assert_eq!(d_k1.decodes.len(), 3);
        assert!(
            d_k1.prefill_tokens() > d_k3.prefill_tokens(),
            "k 3->1 must widen the next window: {} vs {}",
            d_k1.prefill_tokens(),
            d_k3.prefill_tokens()
        );
        assert!(d_k1.prefill_tokens() + 3 * 2 <= 16, "and stay in budget");
    }

    #[test]
    fn growing_k_never_inflates_a_tight_budget() {
        // regression on the PR 3 fix: a user's tight step budget stays
        // the bound no matter how large k grows — the speculative
        // reserve re-slices it, the one-token floor keeps prefill alive
        let budget = 5;
        let mut s = Scheduler::new(4)
            .with_step_budget(budget)
            .with_chunked_prefill(8);
        let c = roomy_cache();
        for id in 1..=3u64 {
            s.submit(id, 2);
        }
        for _ in 0..4 {
            apply(&mut s, &c);
        }
        s.submit(9, 24);
        for k in [0usize, 1, 3, 7] {
            s.set_spec_round(k, Vec::new());
            let d = apply(&mut s, &c);
            assert_eq!(d.decodes.len(), 3);
            let charge: usize = d.decodes.len() * (1 + k);
            if charge >= budget {
                assert_eq!(
                    d.prefill_tokens(),
                    1,
                    "k={k}: saturated budget still grants the progress floor"
                );
            } else {
                assert!(
                    d.prefill_tokens() + charge <= budget,
                    "k={k}: prefill {} + decode charge {charge} over budget {budget}",
                    d.prefill_tokens()
                );
            }
        }
    }

    #[test]
    fn mixed_batch_charges_each_lane_exactly_one_plus_k_lane() {
        // 4 decoding lanes at k=3, two of them demoted to plain decode:
        // the charge is 2*(1+3) + 2*1 = 10 of an 18-token budget, so the
        // admission window gets exactly the 8 left (block-aligned)
        let mut s = Scheduler::new(5)
            .with_step_budget(18)
            .with_chunked_prefill(16);
        let c = roomy_cache(); // block_size 4
        for id in 1..=4u64 {
            s.submit(id, 2);
        }
        for _ in 0..5 {
            apply(&mut s, &c);
        }
        s.submit(9, 40);
        s.set_spec_round(3, vec![2, 4]);
        let d = apply(&mut s, &c);
        assert_eq!(d.decodes.len(), 4);
        assert_eq!(
            d.prefill_tokens(),
            18 - (2 * 4 + 2 * 1),
            "per-lane charge must be exactly 1 + k_lane"
        );
        // demoting every lane frees the full reserve: 18 - 4 = 14,
        // aligned down to the 12-token block boundary
        s.set_spec_round(3, vec![1, 2, 3, 4]);
        let d = apply(&mut s, &c);
        assert_eq!(d.prefill_tokens(), 12, "all-plain batch charges 1 per lane");
    }

    #[test]
    fn chunked_admission_respects_pool_capacity() {
        let mut s = Scheduler::new(4).with_step_budget(64).with_chunked_prefill(8);
        let c = cache(); // 8 blocks x 4 tokens = 32-slot pool
        // a prompt that can never fit the pool is not admitted chunk-wise
        s.submit(1, 16 * 4); // needs 16 blocks + headroom > 8
        let d = s.schedule(&c, &COOPT);
        assert!(d.prefills.is_empty());
        assert_eq!(s.num_waiting(), 1);
    }

    #[test]
    fn swap_preemption_preserves_progress_and_resumes() {
        let mut s = Scheduler::new(4).with_step_budget(32).with_chunked_prefill(8);
        let c = roomy_cache();
        s.submit(1, 20);
        apply(&mut s, &c); // first 8-token window committed
        assert_eq!(s.prefill_progress(1), Some(8));

        // swap exit: progress survives, the seq leaves running
        assert_eq!(s.peek_preempt_victim(), Some(1));
        assert!(s.preempt_swap(1));
        assert_eq!(s.num_running(), 0);
        assert_eq!(s.num_swapped(), 1);
        assert!(!s.is_idle(), "swapped sequences keep the scheduler busy");
        assert_eq!(s.total_swap_preemptions, 1);
        assert_eq!(s.total_preemptions, 1);

        // resume: the next window continues from the committed offset,
        // never from zero
        assert!(s.resume_swapped(1));
        assert_eq!(s.prefill_progress(1), Some(8));
        let d = s.schedule(&c, &COOPT);
        assert_eq!(d.prefills[0].offset, 8);
    }

    #[test]
    fn drop_swapped_requeues_as_recompute() {
        let mut s = Scheduler::new(4);
        let c = cache();
        s.submit(1, 4);
        s.schedule(&c, &COOPT);
        assert!(s.preempt_swap(1));
        // the tier manager failed to bring it back: recompute fallback
        assert!(s.drop_swapped(1, 9));
        assert_eq!(s.num_swapped(), 0);
        let d = s.schedule(&c, &COOPT);
        assert_eq!(d.prefills[0], PrefillWork { id: 1, offset: 0, tokens: 9, is_final: true });
    }

    #[test]
    fn swapped_ids_ordered_oldest_first_and_finish_clears() {
        let mut s = Scheduler::new(4);
        let c = cache();
        for id in 1..=3u64 {
            s.submit(id, 4);
            s.schedule(&c, &COOPT);
        }
        assert!(s.preempt_swap(3));
        assert!(s.preempt_swap(1));
        assert_eq!(s.swapped_ids(), vec![1, 3], "oldest admission first");
        s.finish(3);
        assert_eq!(s.swapped_ids(), vec![1]);
        s.finish(1);
        s.finish(2);
        assert!(s.is_idle());
    }

    #[test]
    fn migrating_state_is_invisible_to_scheduling_and_preemption() {
        let mut s = Scheduler::new(4);
        let c = cache();
        for id in 1..=2u64 {
            s.submit(id, 4);
            s.schedule(&c, &COOPT);
        }
        assert_eq!(s.num_running(), 2);
        // seq 2 (newest) enters the hand-off state
        assert!(s.begin_migration(2));
        assert_eq!(s.num_migrating(), 1);
        assert_eq!(s.num_running(), 1);
        assert!(!s.is_idle(), "a mid-hand-off sequence keeps the engine busy");
        // it is neither scheduled nor a preemption victim while migrating
        let d = s.schedule(&c, &COOPT);
        assert_eq!(d.decodes, vec![1]);
        assert_eq!(s.peek_preempt_victim(), Some(1));
        // completion drops it; abort of a completed hand-off is a no-op
        assert!(s.complete_migration(2));
        assert!(!s.abort_migration(2));
        assert_eq!(s.num_migrating(), 0);
        s.finish(1);
        assert!(s.is_idle());
    }

    #[test]
    fn aborted_migration_rejoins_running_in_stamp_order() {
        let mut s = Scheduler::new(4);
        let c = cache();
        for id in 1..=3u64 {
            s.submit(id, 4);
            s.schedule(&c, &COOPT);
        }
        // the middle admission migrates, then aborts: it must re-enter
        // between its older and newer neighbours, keeping the newest
        // admission the preemption victim
        assert!(s.begin_migration(2));
        assert!(s.abort_migration(2));
        assert_eq!(s.running_ids(), vec![1, 2, 3]);
        assert_eq!(s.peek_preempt_victim(), Some(3));
        // migrating a non-running id fails cleanly
        assert!(!s.begin_migration(99));
    }

    #[test]
    fn admit_migrated_is_decode_ready_at_its_offset() {
        let mut s = Scheduler::new(4).with_step_budget(32).with_chunked_prefill(8);
        let c = roomy_cache();
        // a sequence arrives mid-stream from another replica, already
        // committed through 13 tokens
        s.admit_migrated(7, 13, Priority::Interactive);
        assert_eq!(s.num_running(), 1);
        assert_eq!(s.prefill_progress(7), Some(13));
        assert_eq!(s.decode_ready_ids(), vec![7]);
        let d = s.schedule(&c, &COOPT);
        assert!(d.prefills.is_empty(), "no re-prefill on the destination");
        assert_eq!(d.decodes, vec![7]);
        assert_eq!(s.total_admissions, 1);
        // finish clears the migrating set too
        assert!(s.begin_migration(7));
        s.finish(7);
        assert!(s.is_idle());
    }

    #[test]
    fn batch_lanes_are_preferred_preemption_victims() {
        let mut s = Scheduler::new(4);
        let c = cache();
        // admission order: interactive 1, batch 2, interactive 3 — the
        // victim must be the batch lane even though 3 is newer
        s.submit_class(1, 4, Priority::Interactive);
        s.schedule(&c, &COOPT);
        s.submit_class(2, 4, Priority::Batch);
        s.schedule(&c, &COOPT);
        s.submit_class(3, 4, Priority::Interactive);
        s.schedule(&c, &COOPT);
        assert_eq!(s.num_running(), 3);
        assert_eq!(s.peek_preempt_victim(), Some(2), "newest batch goes first");
        assert!(s.preempt_drop(2, 4));
        // all-interactive: classic newest-admission order
        assert_eq!(s.peek_preempt_victim(), Some(3));
        assert_eq!(s.class_of(2), Some(Priority::Batch), "class survives requeue");
        assert_eq!(s.class_of(3), Some(Priority::Interactive));
    }

    #[test]
    fn hinted_victim_prefers_most_remaining_and_falls_back_exactly() {
        let mut s = Scheduler::new(4);
        let c = cache();
        s.submit_class(1, 4, Priority::Batch);
        s.schedule(&c, &COOPT);
        s.submit_class(2, 4, Priority::Batch);
        s.schedule(&c, &COOPT);
        s.submit_class(3, 4, Priority::Interactive);
        s.schedule(&c, &COOPT);
        // all-None hints: exactly the reactive choice (newest batch)
        assert_eq!(s.peek_preempt_victim_by(|_| None), s.peek_preempt_victim());
        assert_eq!(s.peek_preempt_victim_by(|_| None), Some(2));
        // the length forecast says lane 1 is furthest from finishing:
        // it becomes the victim despite being the oldest admission
        let hints = |id: SeqId| match id {
            1 => Some(30u64),
            2 => Some(5),
            _ => None,
        };
        assert_eq!(s.peek_preempt_victim_by(hints), Some(1));
        // a hinted batch lane outranks an unhinted one...
        assert_eq!(s.peek_preempt_victim_by(|id| (id == 1).then_some(2u64)), Some(1));
        // ...but class preference still dominates: an interactive-only
        // hint never redirects the victim off the batch lanes
        assert_eq!(s.peek_preempt_victim_by(|id| (id == 3).then_some(99u64)), Some(2));
    }

    #[test]
    fn interactive_outranks_batch_at_admission() {
        // batch head-of-line: a waiting interactive request is admitted
        // past older batch arrivals, FCFS within each class
        for chunked in [false, true] {
            let c = roomy_cache();
            let mut s = Scheduler::new(1).with_step_budget(64);
            if chunked {
                s = s.with_chunked_prefill(8);
            }
            s.submit_class(1, 4, Priority::Batch);
            s.submit_class(2, 4, Priority::Batch);
            s.submit_class(3, 4, Priority::Interactive);
            s.submit_class(4, 4, Priority::Interactive);
            let mut order = Vec::new();
            for _ in 0..12 {
                let d = apply(&mut s, &c);
                order.extend(d.admitted.iter().copied());
                for &id in &d.admitted {
                    s.finish(id); // free the single batch slot
                }
                if order.len() == 4 {
                    break;
                }
            }
            assert_eq!(order, vec![3, 4, 1, 2], "chunked={chunked}");
        }
    }

    #[test]
    fn swapped_resume_order_is_interactive_first() {
        let mut s = Scheduler::new(4);
        let c = cache();
        s.submit_class(1, 4, Priority::Batch);
        s.schedule(&c, &COOPT);
        s.submit_class(2, 4, Priority::Interactive);
        s.schedule(&c, &COOPT);
        s.submit_class(3, 4, Priority::Interactive);
        s.schedule(&c, &COOPT);
        assert!(s.preempt_swap(3));
        assert!(s.preempt_swap(1));
        assert!(s.preempt_swap(2));
        // interactive (2, 3 by stamp) resume ahead of the older batch 1
        assert_eq!(s.swapped_ids(), vec![2, 3, 1]);
    }

    #[test]
    fn interactive_reserve_caps_batch_prefill_share() {
        // 20-token budget, reserve 0.5: while the interactive prompt is
        // mid-prefill, batch windows may take at most 10 tokens per round
        let mut s = Scheduler::new(4)
            .with_step_budget(20)
            .with_chunked_prefill(16)
            .with_interactive_reserve(0.5);
        let c = roomy_cache();
        s.submit_class(1, 40, Priority::Batch);
        s.submit_class(2, 40, Priority::Interactive);
        let d = apply(&mut s, &c);
        let batch_tokens: usize = d
            .prefills
            .iter()
            .filter(|w| w.id == 1)
            .map(|w| w.tokens)
            .sum();
        let inter_tokens: usize = d
            .prefills
            .iter()
            .filter(|w| w.id == 2)
            .map(|w| w.tokens)
            .sum();
        assert!(inter_tokens > 0, "interactive prefill progresses");
        assert!(
            batch_tokens <= 10,
            "batch took {batch_tokens} of a 20-token budget under a 0.5 reserve"
        );
        // interactive windows are planned before batch ones
        let first_ids: Vec<SeqId> = d.prefills.iter().map(|w| w.id).collect();
        assert_eq!(first_ids.first(), Some(&2));
        // once no interactive prefill is pending, batch gets the whole
        // budget again
        while s.prefill_progress(2) != Some(40) {
            apply(&mut s, &c);
        }
        let d = apply(&mut s, &c);
        let batch_tokens: usize = d
            .prefills
            .iter()
            .filter(|w| w.id == 1)
            .map(|w| w.tokens)
            .sum();
        // budget 20 minus the decode reserve for seq 2, batch uncapped
        assert!(
            batch_tokens > 10,
            "reserve must lift when no interactive prefill is pending \
             (batch got {batch_tokens})"
        );
    }

    #[test]
    fn record_progress_caps_at_prefix() {
        let mut s = Scheduler::new(2).with_step_budget(32).with_chunked_prefill(8);
        let c = roomy_cache();
        s.submit(1, 10);
        s.schedule(&c, &COOPT);
        s.record_prefill_progress(1, 8);
        assert_eq!(s.prefill_progress(1), Some(8));
        s.record_prefill_progress(1, 8);
        assert_eq!(s.prefill_progress(1), Some(10), "capped at the prefix");
        // preemption resets progress for recompute
        let v = s.peek_preempt_victim().unwrap();
        assert_eq!(v, 1);
        assert!(s.preempt_drop(v, 10));
        let d = s.schedule(&c, &COOPT);
        assert_eq!(d.prefills[0].offset, 0);
    }
}
