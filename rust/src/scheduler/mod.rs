//! Continuous-batching scheduler (the vLLM-baseline substrate the paper
//! builds on: dynamic batching + sequence merging, §2).
//!
//! Policy, per scheduling round:
//!
//! 1. **Prefill admission** — while there is batch headroom, waiting
//!    sequences are admitted FCFS if the [`CacheManager`] can allocate
//!    their blocks (admission differs by opt-config: the baseline's padded
//!    writes need more blocks, so Opt-KV literally admits more load).
//!    One prefill per round (the prefill graph is single-sequence).
//! 2. **Decode batching** — all running sequences step together, padded to
//!    the graph batch.
//! 3. **Preemption by recompute** — if a decode step cannot get a block,
//!    the most-recently-admitted running sequence is evicted: its blocks
//!    are freed and it re-enters the waiting queue with its full token
//!    prefix (re-prefilled on next admission), exactly vLLM's recompute
//!    preemption.

use std::collections::VecDeque;

use crate::config::OptConfig;
use crate::kvcache::{CacheManager, SeqId};

/// Scheduler's view of a sequence.
#[derive(Debug, Clone)]
struct Entry {
    id: SeqId,
    /// tokens that must be prefetched into the cache on (re)admission
    prefix_len: usize,
    /// admission order stamp (for preemption: newest goes first)
    admitted_at: u64,
}

#[derive(Debug, Clone, Default)]
pub struct ScheduleDecision {
    /// sequence to prefill this round (at most one)
    pub prefill: Option<SeqId>,
    /// running sequences to decode-step together
    pub decodes: Vec<SeqId>,
    /// sequences preempted this round (already moved back to waiting)
    pub preempted: Vec<SeqId>,
}

#[derive(Debug)]
pub struct Scheduler {
    waiting: VecDeque<Entry>,
    running: Vec<Entry>,
    max_batch: usize,
    stamp: u64,
    pub total_preemptions: u64,
    pub total_admissions: u64,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Self {
        Scheduler {
            waiting: VecDeque::new(),
            running: Vec::new(),
            max_batch,
            stamp: 0,
            total_preemptions: 0,
            total_admissions: 0,
        }
    }

    /// Enqueue a new request (prompt not yet in cache).
    pub fn submit(&mut self, id: SeqId, prompt_len: usize) {
        self.waiting.push_back(Entry {
            id,
            prefix_len: prompt_len,
            admitted_at: 0,
        });
    }

    pub fn num_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }

    pub fn running_ids(&self) -> Vec<SeqId> {
        self.running.iter().map(|e| e.id).collect()
    }

    /// Remove a finished sequence from the running set.
    pub fn finish(&mut self, id: SeqId) {
        self.running.retain(|e| e.id != id);
    }

    /// Plan the next round.  `cache` is consulted for admission headroom;
    /// nothing is allocated here (the coordinator commits the plan).
    pub fn schedule(&mut self, cache: &CacheManager, opt: &OptConfig) -> ScheduleDecision {
        let mut d = ScheduleDecision::default();

        // 1. admit one waiting sequence if there's room
        if self.running.len() < self.max_batch {
            if let Some(front) = self.waiting.front() {
                if cache.can_admit(front.prefix_len, opt) {
                    let mut e = self.waiting.pop_front().unwrap();
                    self.stamp += 1;
                    e.admitted_at = self.stamp;
                    d.prefill = Some(e.id);
                    self.total_admissions += 1;
                    self.running.push(e);
                }
            }
        }

        // 2. decode everything running (including the fresh prefill's seq —
        // the coordinator prefills first, then decode-steps the batch)
        d.decodes = self
            .running
            .iter()
            .map(|e| e.id)
            .take(self.max_batch)
            .collect();
        d
    }

    /// Preempt the most recently admitted running sequence (recompute
    /// policy).  `current_len` is its full token count (prompt+generated),
    /// which becomes its re-prefill prefix.  Returns the victim id.
    pub fn preempt_latest(&mut self, current_len: impl Fn(SeqId) -> usize) -> Option<SeqId> {
        let idx = self
            .running
            .iter()
            .enumerate()
            .max_by_key(|(_, e)| e.admitted_at)
            .map(|(i, _)| i)?;
        let mut e = self.running.remove(idx);
        e.prefix_len = current_len(e.id);
        let id = e.id;
        self.waiting.push_front(e);
        self.total_preemptions += 1;
        Some(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CacheGeometry, COOPT};

    fn cache() -> CacheManager {
        CacheManager::new(CacheGeometry {
            block_size: 4,
            max_blocks: 8,
            num_pool_blocks: 8,
            max_batch: 4,
            max_seq: 16,
        })
    }

    #[test]
    fn fcfs_admission() {
        let mut s = Scheduler::new(2);
        let c = cache();
        s.submit(1, 4);
        s.submit(2, 4);
        s.submit(3, 4);
        let d1 = s.schedule(&c, &COOPT);
        assert_eq!(d1.prefill, Some(1));
        assert_eq!(d1.decodes, vec![1]);
        let d2 = s.schedule(&c, &COOPT);
        assert_eq!(d2.prefill, Some(2));
        assert_eq!(d2.decodes, vec![1, 2]);
        // batch full: seq 3 must wait
        let d3 = s.schedule(&c, &COOPT);
        assert_eq!(d3.prefill, None);
        assert_eq!(s.num_waiting(), 1);
    }

    #[test]
    fn admission_respects_cache() {
        let mut s = Scheduler::new(8);
        let mut c = cache(); // 8 blocks total
        // occupy 7 of 8 blocks so a 4-token prompt (1 block + 1 headroom)
        // cannot be admitted
        for id in 100..107u64 {
            c.prefill(id, &(0..4).map(|x| id as u32 + x).collect::<Vec<_>>(), &COOPT)
                .unwrap();
        }
        assert_eq!(c.num_free_blocks(), 1);
        s.submit(1, 4);
        let d = s.schedule(&c, &COOPT);
        assert_eq!(d.prefill, None, "no admission without headroom");
        c.free_seq(100);
        c.free_seq(101);
        let d = s.schedule(&c, &COOPT);
        assert_eq!(d.prefill, Some(1));
    }

    #[test]
    fn finish_frees_batch_slot() {
        let mut s = Scheduler::new(1);
        let c = cache();
        s.submit(1, 4);
        s.submit(2, 4);
        s.schedule(&c, &COOPT);
        assert_eq!(s.num_running(), 1);
        s.finish(1);
        let d = s.schedule(&c, &COOPT);
        assert_eq!(d.prefill, Some(2));
    }

    #[test]
    fn preempts_newest_first() {
        let mut s = Scheduler::new(4);
        let c = cache();
        for id in 1..=3u64 {
            s.submit(id, 4);
            s.schedule(&c, &COOPT);
        }
        assert_eq!(s.num_running(), 3);
        let victim = s.preempt_latest(|_| 7).unwrap();
        assert_eq!(victim, 3, "newest admitted preempted first");
        assert_eq!(s.num_waiting(), 1);
        // re-admitted at front with its grown prefix
        let d = s.schedule(&c, &COOPT);
        assert_eq!(d.prefill, Some(3));
        assert_eq!(s.total_preemptions, 1);
    }

    #[test]
    fn idle_detection() {
        let mut s = Scheduler::new(2);
        assert!(s.is_idle());
        s.submit(1, 4);
        assert!(!s.is_idle());
        let c = cache();
        s.schedule(&c, &COOPT);
        s.finish(1);
        assert!(s.is_idle());
    }
}
