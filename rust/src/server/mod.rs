//! HTTP/1.1 serving front-end (hand-rolled; tokio/axum unavailable
//! offline) + a matching client.
//!
//! Architecture: each replica is one *engine thread* owning an
//! [`Engine`] and running the continuous-batching loop; a
//! [`crate::router::RouterHandle`] in front fans incoming requests out
//! across the N replicas with a pluggable placement policy
//! ([`crate::config::RouterPolicy`]).  HTTP connections are handled by a
//! [`ThreadPool`], each request is routed and then submitted over the
//! chosen replica's mpsc channel with a oneshot-style reply channel, so
//! concurrent HTTP requests batch together inside that engine — the
//! same structure as vLLM's AsyncLLMEngine front-end, replicated.  The
//! single-engine [`Server::bind`] path is the N = 1 special case.
//!
//! Each engine thread publishes its metrics as an atomically-replaced
//! [`MetricsSnapshot`] `Arc` stamped with a step sequence number, so the
//! router's cross-replica aggregation can never observe a torn
//! mid-update view of any replica.
//!
//! Endpoints:
//!   GET  /health            -> {"status":"ok", "replicas":[...], ...}
//!   GET  /metrics           -> cluster metrics JSON (Eq. 11/12 fields,
//!                              flat for N=1) + per-replica views
//!   GET  /metrics?format=prometheus -> text exposition of the same payload
//!   GET  /admin/trace       -> per-replica flight-recorder dump (recent
//!                              finished-request timelines); filter with
//!                              ?id=<engine id> or ?corr=<correlation id>
//!   GET  /admin/forecast    -> predictive-plane dump: the router's own
//!                              forecast plane + each replica's signal
//!                              ring and estimator states
//!   POST /v1/generate       -> {"text": ..., "finish": ..., ...}
//!       body: {"prompt": "...", "max_new_tokens": 16, "temperature": 0.0,
//!              "correlation_id": "optional client tag echoed in traces"}
//!   POST /admin/drain       -> stop routing new requests to a replica
//!       body: {"replica": 0}     (in-flight requests finish)
//!   POST /admin/undrain     -> put a drained replica back in rotation

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Priority, ReplicaRole, ReqClass};
use crate::coordinator::{Engine, GenRequest, GenResult, PrefixPull, SeqHandoff};
use crate::kvcache::PrefixDelta;
use crate::router::{RouterHandle, SHED_MARKER};
use crate::runtime::Backend;
use crate::sampling::SamplingParams;
use crate::util::json::{self, Object, Value};
use crate::util::logging::Level;
use crate::util::threadpool::ThreadPool;

// ---------------------------------------------------------------------------
// engine thread
// ---------------------------------------------------------------------------

enum Job {
    Generate {
        req: GenRequest,
        reply: Sender<Result<GenResult>>,
    },
    /// re-admit a sequence handed off from another replica (the reply
    /// channel is the original client's waiter, travelling with it)
    MigrateIn {
        handoff: Box<SeqHandoff>,
        reply: Sender<Result<GenResult>>,
    },
    /// re-role the engine (PD autoscaler / `/admin/role`); applied
    /// before its next step
    SetRole(ReplicaRole),
    /// dump the engine's flight-recorder ring (`GET /admin/trace`),
    /// optionally filtered by engine request id / correlation id
    DumpTrace {
        id: Option<u64>,
        corr: Option<String>,
        reply: Sender<Value>,
    },
    /// export a registered prefix chain's KV blocks through the host
    /// tier (cross-replica prefix pull, source side); best-effort — the
    /// reply carries however many leading blocks were exportable
    ExportPrefix {
        chain: Vec<u64>,
        reply: Sender<PrefixPull>,
    },
    /// commit pulled prefix blocks into this engine's device tier +
    /// prefix index (cross-replica prefix pull, destination side)
    PullCommit {
        pull: Box<PrefixPull>,
        reply: Sender<Result<()>>,
    },
    /// dump the engine's forecast plane — signal ring + estimator
    /// states (`GET /admin/forecast`)
    DumpForecast { reply: Sender<Value> },
}

/// Deliver a reply to a waiter; when the waiter is gone (client
/// disconnect, dispatcher shutdown) the result used to vanish silently —
/// now it leaves a structured one-line JSON event on stderr, gated by
/// the global log level (`--log-level`).
fn send_reply(
    reply: &Sender<Result<GenResult>>,
    ctx: &'static str,
    id: Option<u64>,
    res: Result<GenResult>,
) {
    let err_text = res.as_ref().err().map(|e| format!("{e:#}"));
    if reply.send(res).is_ok() {
        return;
    }
    let mut fields: Vec<(&str, Value)> = vec![("ctx", ctx.into())];
    if let Some(id) = id {
        fields.push(("request_id", (id as usize).into()));
    }
    if let Some(e) = err_text {
        fields.push(("error", e.into()));
    }
    crate::obs::log_json_event(Level::Warn, "reply_send_failed", &fields);
}

/// A sequence parked by a prefill-role engine at prefill completion,
/// packaged for re-admission elsewhere.  The engine thread publishes
/// these on the router's hand-off bus; `reply` is the waiting client,
/// which travels to whichever replica finishes the sequence.
pub struct HandoffEnvelope {
    pub from: usize,
    pub handoff: SeqHandoff,
    pub reply: Sender<Result<GenResult>>,
}

/// A KV hand-off that reached its destination engine while the batch
/// was full, waiting engine-side for a slot (see the spawn loop).
type ParkedHandoff = (Box<SeqHandoff>, Sender<Result<GenResult>>);

/// One atomically-published view of a replica's metrics.  The engine
/// thread replaces the whole `Arc<MetricsSnapshot>` after each step, so
/// a reader either sees the previous step's snapshot or this one —
/// never a torn mix — and `seq` records which step produced it (the
/// router stamps it into the per-replica `/metrics` views).  The typed
/// gauges are the router's live load signals, extracted engine-side so
/// routing never has to parse JSON.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// engine steps completed when this snapshot was taken (0 = the
    /// pre-first-step publish)
    pub seq: u64,
    /// the full `GET /metrics` payload (engine metrics + cache/tier stats)
    pub json: String,
    /// requests submitted and not yet finished (waiting+running+swapped)
    pub pending: usize,
    pub free_device_blocks: usize,
    pub total_device_blocks: usize,
    pub free_host_blocks: usize,
    /// tokens committed per decode/verify round (≥ 1 under speculation)
    pub tokens_per_step: f64,
    /// cost-model regime of the last planned decode batch
    pub gemm_bound: bool,
    /// batch slots not occupied by running sequences (`max_batch -
    /// num_running`); the hand-off dispatcher defers migrations to
    /// destinations showing zero so they don't burn on token fallback
    pub batch_slots_free: usize,
    /// run-cumulative prompt tokens through prefill graphs (the router
    /// plane's prefill-rate signal; consumers diff between snapshots)
    pub prefill_tokens_committed: u64,
    /// run-cumulative tokens committed by decode/verify rounds
    pub decode_tokens_committed: u64,
    /// prefix-index deltas since the previous snapshot — each delta
    /// appears in exactly one snapshot, so a reader that skips a
    /// snapshot loses (stale-safe) rather than double-applies
    pub prefix_deltas: Vec<PrefixDelta>,
}

impl MetricsSnapshot {
    fn empty() -> Self {
        MetricsSnapshot {
            seq: 0,
            json: "{}".to_string(),
            pending: 0,
            free_device_blocks: 0,
            total_device_blocks: 0,
            free_host_blocks: 0,
            tokens_per_step: 0.0,
            gemm_bound: false,
            batch_slots_free: 0,
            prefill_tokens_committed: 0,
            decode_tokens_committed: 0,
            prefix_deltas: Vec::new(),
        }
    }
}

/// How many engine steps a replica has run past its last published
/// snapshot — 0 while publishing keeps pace with the step loop, growing
/// only when the snapshot writer falls behind (signal freshness: a
/// router placing on a stale snapshot should be able to see the lag).
pub fn snapshot_age_steps(current_step: u64, snapshot_seq: u64) -> u64 {
    current_step.saturating_sub(snapshot_seq)
}

fn snapshot_engine<B: Backend>(engine: &mut Engine<B>, seq: u64) -> MetricsSnapshot {
    let s = engine.load_signals();
    MetricsSnapshot {
        seq,
        json: engine.stats_json().to_string(),
        pending: s.pending,
        free_device_blocks: s.free_device_blocks,
        total_device_blocks: s.total_device_blocks,
        free_host_blocks: s.free_host_blocks,
        tokens_per_step: s.tokens_per_step,
        gemm_bound: s.gemm_bound,
        batch_slots_free: s.batch_slots_free,
        prefill_tokens_committed: engine.metrics.prefill_tokens_committed,
        decode_tokens_committed: engine.metrics.decode_tokens_committed,
        prefix_deltas: engine.take_prefix_deltas(),
    }
}

/// Handle to the background engine loop.
pub struct EngineHandle {
    tx: Sender<Job>,
    snapshot: Arc<Mutex<Arc<MetricsSnapshot>>>,
    /// step counter mirrored out of the engine loop (same series as the
    /// snapshot `seq`); `current_step - snapshot.seq` is the snapshot's
    /// staleness in steps
    steps: Arc<AtomicU64>,
    /// when the engine thread was spawned (replica uptime for `/metrics`)
    started: std::time::Instant,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl EngineHandle {
    /// Take ownership of the engine and run it on a dedicated thread.
    pub fn spawn<B: Backend + Send + 'static>(engine: Engine<B>) -> Self {
        Self::spawn_inner(engine, None)
    }

    /// Like [`EngineHandle::spawn`], wired to the cluster's hand-off
    /// bus: when this (prefill-role) engine parks a sequence at prefill
    /// completion, the loop packages it ([`Engine::make_handoff`]) and
    /// ships it — waiter attached — as a [`HandoffEnvelope`] for the
    /// router's dispatcher to re-admit on a decode-capable replica.
    pub fn spawn_routed<B: Backend + Send + 'static>(
        engine: Engine<B>,
        replica: usize,
        handoff_tx: Sender<HandoffEnvelope>,
    ) -> Self {
        Self::spawn_inner(engine, Some((replica, handoff_tx)))
    }

    fn spawn_inner<B: Backend + Send + 'static>(
        mut engine: Engine<B>,
        handoff: Option<(usize, Sender<HandoffEnvelope>)>,
    ) -> Self {
        let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
        let snapshot = Arc::new(Mutex::new(Arc::new(MetricsSnapshot::empty())));
        let stop = Arc::new(AtomicBool::new(false));
        let steps = Arc::new(AtomicU64::new(0));
        let started = std::time::Instant::now();
        let mj = Arc::clone(&snapshot);
        let st = Arc::clone(&stop);
        let sc = Arc::clone(&steps);
        let thread = std::thread::Builder::new()
            .name("coopt-engine".into())
            .spawn(move || {
                let mut waiters: Vec<(u64, Sender<Result<GenResult>>)> = Vec::new();
                // KV hand-offs that arrived while the batch was full:
                // admitting one then would burn its staged KV on the
                // token fallback, and the waiting queue it would join
                // needs the same free slot anyway — so it parks here and
                // admits the moment a slot frees (exact engine-side
                // knowledge; the dispatcher's snapshot-based slot filter
                // can lag a step and cannot close this race)
                let mut parked: VecDeque<ParkedHandoff> = VecDeque::new();
                let submit = |engine: &mut Engine<B>,
                              job: Job,
                              waiters: &mut Vec<(u64, Sender<Result<GenResult>>)>,
                              parked: &mut VecDeque<ParkedHandoff>| {
                    match job {
                        Job::Generate { req, reply } => match engine.submit(req) {
                            Ok(id) => waiters.push((id, reply)),
                            Err(e) => send_reply(&reply, "submit", None, Err(e)),
                        },
                        Job::MigrateIn { handoff, reply } => {
                            if !handoff.blocks.is_empty()
                                && engine.backend.supports_kv_migration()
                                && !engine.has_batch_slot()
                            {
                                parked.push_back((handoff, reply));
                                return;
                            }
                            let hid = handoff.trace.id;
                            match engine.migrate_in_seq(*handoff) {
                                Ok(id) => waiters.push((id, reply)),
                                Err(e) => send_reply(
                                    &reply,
                                    "migrate_in",
                                    Some(hid),
                                    Err(anyhow!("engine error: migrate-in failed: {e}")),
                                ),
                            }
                        }
                        Job::SetRole(role) => engine.set_role(role),
                        Job::DumpTrace { id, corr, reply } => {
                            let _ = reply.send(engine.trace_json(id, corr.as_deref()));
                        }
                        Job::ExportPrefix { chain, reply } => {
                            let _ = reply.send(engine.export_prefix(&chain));
                        }
                        Job::PullCommit { pull, reply } => {
                            let _ = reply.send(engine.pull_commit(*pull));
                        }
                        Job::DumpForecast { reply } => {
                            let _ = reply.send(engine.forecast_json());
                        }
                    }
                };
                engine.metrics.start_run();
                let mut seq = 0u64;
                // publish a pre-first-step snapshot so /metrics (and the
                // router's load gauges) are valid before any traffic
                if let Ok(mut m) = mj.lock() {
                    *m = Arc::new(snapshot_engine(&mut engine, seq));
                }
                loop {
                    if st.load(Ordering::Relaxed) {
                        return;
                    }
                    // parked hand-offs admit as soon as a slot frees —
                    // on the KV path, never the token fallback
                    while engine.has_batch_slot() {
                        let Some((h, reply)) = parked.pop_front() else {
                            break;
                        };
                        let hid = h.trace.id;
                        match engine.migrate_in_seq(*h) {
                            Ok(id) => waiters.push((id, reply)),
                            Err(e) => send_reply(
                                &reply,
                                "migrate_in",
                                Some(hid),
                                Err(anyhow!("engine error: migrate-in failed: {e}")),
                            ),
                        }
                    }
                    // idle: block on the job channel instead of polling —
                    // the timeout only exists to honor the stop flag
                    if engine.num_pending() == 0 {
                        match rx.recv_timeout(Duration::from_millis(100)) {
                            Ok(job) => submit(&mut engine, job, &mut waiters, &mut parked),
                            Err(RecvTimeoutError::Timeout) => continue,
                            Err(RecvTimeoutError::Disconnected) => return,
                        }
                    }
                    // busy: opportunistically drain whatever else queued so
                    // concurrent requests batch into the same round
                    loop {
                        match rx.try_recv() {
                            Ok(job) => submit(&mut engine, job, &mut waiters, &mut parked),
                            Err(TryRecvError::Empty) => break,
                            Err(TryRecvError::Disconnected) => return,
                        }
                    }
                    match engine.step() {
                        Ok(results) => {
                            for r in results {
                                if let Some(pos) = waiters.iter().position(|(id, _)| *id == r.id)
                                {
                                    let (_, reply) = waiters.swap_remove(pos);
                                    let id = r.id;
                                    send_reply(&reply, "result", Some(id), Ok(r));
                                }
                            }
                        }
                        Err(e) => {
                            // engine error: fail everything in flight,
                            // parked hand-offs included
                            for (id, reply) in waiters.drain(..) {
                                send_reply(
                                    &reply,
                                    "engine_failed",
                                    Some(id),
                                    Err(anyhow!("engine error: {e}")),
                                );
                            }
                            for (h, reply) in parked.drain(..) {
                                send_reply(
                                    &reply,
                                    "engine_failed",
                                    Some(h.trace.id),
                                    Err(anyhow!("engine error: {e}")),
                                );
                            }
                        }
                    }
                    // ship parked hand-offs to the bus with their
                    // waiters; no bus (or no waiter left after an
                    // engine error) aborts back to local decode
                    for id in engine.take_handoff_ready() {
                        let pos = waiters.iter().position(|(w, _)| *w == id);
                        let (Some(pos), Some((replica, htx))) = (pos, handoff.as_ref()) else {
                            engine.abort_handoff(id);
                            continue;
                        };
                        match engine.make_handoff(id) {
                            Ok(h) => {
                                let (_, reply) = waiters.swap_remove(pos);
                                let env = HandoffEnvelope {
                                    from: *replica,
                                    handoff: h,
                                    reply,
                                };
                                if let Err(e) = htx.send(env) {
                                    // dispatcher gone; the sequence is
                                    // already detached from this engine
                                    send_reply(
                                        &e.0.reply,
                                        "handoff_dispatcher_gone",
                                        Some(id),
                                        Err(anyhow!("engine error: hand-off dispatcher gone")),
                                    );
                                }
                            }
                            Err(e) => {
                                // unrecoverable mid-export; fail the waiter
                                let (_, reply) = waiters.swap_remove(pos);
                                send_reply(
                                    &reply,
                                    "handoff_export",
                                    Some(id),
                                    Err(anyhow!("engine error: hand-off failed: {e}")),
                                );
                            }
                        }
                    }
                    // metrics + cache-tier stats for GET /metrics: swap the
                    // Arc so readers never see a half-written snapshot
                    seq += 1;
                    sc.store(seq, Ordering::Relaxed);
                    if let Ok(mut m) = mj.lock() {
                        *m = Arc::new(snapshot_engine(&mut engine, seq));
                    }
                }
            })
            .expect("spawn engine thread");
        EngineHandle {
            tx,
            snapshot,
            steps,
            started,
            stop,
            thread: Some(thread),
        }
    }

    /// Blocking generate through the engine thread.
    pub fn generate(&self, req: GenRequest) -> Result<GenResult> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Job::Generate {
                req,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("engine dropped the request"))?
    }

    /// Queue a handed-off sequence for re-admission on this engine;
    /// `reply` is the travelling waiter.  On a dead engine thread the
    /// payload comes back so the caller can redirect it.
    #[allow(clippy::result_large_err)]
    pub fn migrate_in(
        &self,
        handoff: SeqHandoff,
        reply: Sender<Result<GenResult>>,
    ) -> std::result::Result<(), (SeqHandoff, Sender<Result<GenResult>>)> {
        self.tx
            .send(Job::MigrateIn {
                handoff: Box::new(handoff),
                reply,
            })
            .map_err(|e| match e.0 {
                Job::MigrateIn { handoff, reply } => (*handoff, reply),
                _ => unreachable!("send returns the job it was given"),
            })
    }

    /// Tell the engine thread to change its PD role; applied before its
    /// next step.
    pub fn set_role(&self, role: ReplicaRole) -> Result<()> {
        self.tx
            .send(Job::SetRole(role))
            .map_err(|_| anyhow!("engine thread gone"))
    }

    /// Dump this replica's flight-recorder ring (recent finished-request
    /// timelines), optionally filtered by engine request id or client
    /// correlation id.  Round-trips through the engine thread, so the
    /// dump is always a consistent post-step view.
    pub fn trace_json(&self, id: Option<u64>, corr: Option<&str>) -> Result<Value> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Job::DumpTrace {
                id,
                corr: corr.map(str::to_string),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("engine dropped the request"))
    }

    /// Export a registered prefix chain's KV blocks through the host
    /// tier (source side of a cross-replica prefix pull).  Round-trips
    /// through the engine thread; best-effort — the returned
    /// [`PrefixPull`] carries however many leading blocks were still
    /// exportable when the job ran.
    pub fn export_prefix(&self, chain: Vec<u64>) -> Result<PrefixPull> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Job::ExportPrefix {
                chain,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("engine dropped the request"))
    }

    /// Commit pulled prefix blocks into this engine's device tier +
    /// prefix index (destination side of a cross-replica prefix pull).
    pub fn pull_commit(&self, pull: PrefixPull) -> Result<()> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Job::PullCommit {
                pull: Box::new(pull),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("engine dropped the request"))?
    }

    /// Dump this replica's forecast plane (signal ring + estimator
    /// states); round-trips through the engine thread so the view is a
    /// consistent post-step one.
    pub fn forecast_json(&self) -> Result<Value> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Job::DumpForecast { reply: reply_tx })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("engine dropped the request"))
    }

    /// The engine loop's step counter (same series the snapshot `seq`
    /// is stamped from; see [`snapshot_age_steps`]).
    pub fn current_step(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }

    /// Seconds since the engine thread was spawned.
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// The latest atomically-published metrics snapshot.
    pub fn snapshot(&self) -> Arc<MetricsSnapshot> {
        Arc::clone(&self.snapshot.lock().unwrap())
    }

    pub fn metrics_json(&self) -> String {
        self.snapshot().json.clone()
    }

    /// Whether the engine thread is still running (replica health).
    pub fn is_alive(&self) -> bool {
        self.thread.as_ref().map(|t| !t.is_finished()).unwrap_or(false)
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// HTTP server
// ---------------------------------------------------------------------------

pub struct Server {
    pub addr: std::net::SocketAddr,
    listener: TcpListener,
    router: Arc<RouterHandle>,
    pool: ThreadPool,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port) over a
    /// single engine — the N = 1 special case of [`Server::bind_router`].
    pub fn bind(addr: &str, handle: EngineHandle, workers: usize) -> Result<Self> {
        Self::bind_router(addr, RouterHandle::single(handle), workers)
    }

    /// Bind over a multi-replica router (`--replicas N`).
    pub fn bind_router(addr: &str, router: RouterHandle, workers: usize) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true)?;
        Ok(Server {
            addr: listener.local_addr()?,
            listener,
            router: Arc::new(router),
            pool: ThreadPool::new(workers),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// The router behind this server (autoscaler wiring, tests).
    pub fn router(&self) -> Arc<RouterHandle> {
        Arc::clone(&self.router)
    }

    /// Accept loop; returns when the stop flag is set.
    pub fn serve(&self) -> Result<()> {
        crate::log_info!(
            "serving on http://{} ({} replica(s), {} routing)",
            self.addr,
            self.router.num_replicas(),
            self.router.policy_name()
        );
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let router = Arc::clone(&self.router);
                    self.pool.execute(move || {
                        let _ = handle_connection(stream, &router);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

fn handle_connection(mut stream: TcpStream, handle: &RouterHandle) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);

    // request line
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    // headers
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length.min(1 << 20)];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    let body = String::from_utf8_lossy(&body).into_owned();

    let (status, content_type, payload, retry_after_ms) = route(&method, &path, &body, handle);
    // overload responses (429 shed, 503 unavailable) tell clients when
    // to come back; HTTP Retry-After is whole seconds, rounded up
    let retry_header = match retry_after_ms {
        Some(ms) => format!("Retry-After: {}\r\n", ms.div_ceil(1000).max(1)),
        None => String::new(),
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n{retry_header}Connection: close\r\n\r\n{payload}",
        payload.len()
    );
    stream.write_all(resp.as_bytes())?;
    Ok(())
}

const CT_JSON: &str = "application/json";
/// Prometheus text exposition format version (the scraper contract).
const CT_PROM: &str = "text/plain; version=0.0.4";

/// Value of `key` in a raw query string (`a=1&b=2`).  No percent-
/// decoding: engine ids are numeric and correlation ids are expected to
/// be URL-safe tokens.
fn query_param(query: &str, key: &str) -> Option<String> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == key).then(|| v.to_string())
    })
}

/// How long clients should wait before retrying when the cluster has no
/// routable replica (all drained / dead) — drains are operator actions
/// measured in seconds, not the sub-second admission-control horizon.
const UNAVAILABLE_RETRY_MS: u64 = 1000;

fn route(
    method: &str,
    raw_path: &str,
    body: &str,
    handle: &RouterHandle,
) -> (&'static str, &'static str, String, Option<u64>) {
    // the request line carries the query string; endpoints match on the
    // bare path and read parameters out of `query`
    let (path, query) = raw_path.split_once('?').unwrap_or((raw_path, ""));
    match (method, path) {
        ("GET", "/health") => {
            let mut o = Object::new();
            o.insert("status", "ok");
            o.insert("service", "llm-coopt");
            o.insert("num_replicas", handle.num_replicas());
            o.insert("router_policy", handle.policy_name());
            let reps: Vec<Value> = handle
                .status()
                .into_iter()
                .map(|s| {
                    let mut r = Object::new();
                    r.insert("replica", s.replica);
                    r.insert("healthy", s.healthy);
                    r.insert("draining", s.draining);
                    r.insert("in_flight", s.in_flight);
                    r.insert("role", s.role.name());
                    Value::Object(r)
                })
                .collect();
            o.insert("replicas", Value::Array(reps));
            ("200 OK", CT_JSON, Value::Object(o).to_string(), None)
        }
        ("GET", "/metrics") if query_param(query, "format").as_deref() == Some("prometheus") => {
            let v = json::parse(&handle.metrics_json()).unwrap_or(Value::Null);
            ("200 OK", CT_PROM, crate::obs::prometheus_text(&v), None)
        }
        ("GET", "/metrics") => ("200 OK", CT_JSON, handle.metrics_json(), None),
        ("GET", "/admin/trace") => match trace_route(query, handle) {
            Ok(p) => ("200 OK", CT_JSON, p, None),
            Err(e) => ("400 Bad Request", CT_JSON, error_json(&e), None),
        },
        ("GET", "/admin/forecast") => ("200 OK", CT_JSON, handle.forecast_json(), None),
        ("POST", "/v1/generate") => match generate_route(body, handle) {
            Ok(p) => ("200 OK", CT_JSON, p, None),
            Err(e) if is_shed(&e) => {
                // admission-controller refusal: 429 with the shed
                // decision's own retry horizon, parsed back out of the
                // string-encoded error (the vendored anyhow has no
                // downcast)
                let retry = msg_field(&e.to_string(), "retry_after_ms")
                    .and_then(|s| s.parse::<u64>().ok())
                    .unwrap_or(UNAVAILABLE_RETRY_MS);
                let class = msg_field(&e.to_string(), "class")
                    .unwrap_or_else(|| "batch".to_string());
                (
                    "429 Too Many Requests",
                    CT_JSON,
                    overload_json(&e, &class, retry),
                    Some(retry),
                )
            }
            Err(e) if is_unavailable(&e) => {
                // nothing routable / replica died: 503, class echoed
                // from the request so clients can tell whose traffic
                // was turned away
                let class = json::parse(body)
                    .ok()
                    .and_then(|v| v.get("class").and_then(|c| c.as_str().map(String::from)))
                    .unwrap_or_else(|| Priority::default().name().to_string());
                (
                    "503 Service Unavailable",
                    CT_JSON,
                    overload_json(&e, &class, UNAVAILABLE_RETRY_MS),
                    Some(UNAVAILABLE_RETRY_MS),
                )
            }
            Err(e) => ("400 Bad Request", CT_JSON, error_json(&e), None),
        },
        ("POST", "/admin/drain") => match drain_route(body, handle, true) {
            Ok(p) => ("200 OK", CT_JSON, p, None),
            Err(e) => ("400 Bad Request", CT_JSON, error_json(&e), None),
        },
        ("POST", "/admin/undrain") => match drain_route(body, handle, false) {
            Ok(p) => ("200 OK", CT_JSON, p, None),
            Err(e) => ("400 Bad Request", CT_JSON, error_json(&e), None),
        },
        ("POST", "/admin/role") => match role_route(body, handle) {
            Ok(p) => ("200 OK", CT_JSON, p, None),
            Err(e) => ("400 Bad Request", CT_JSON, error_json(&e), None),
        },
        _ => (
            "404 Not Found",
            CT_JSON,
            error_json(&anyhow!("no route {method} {path}")),
            None,
        ),
    }
}

/// `GET /admin/trace[?id=<engine id>][&corr=<correlation id>]`: the
/// cluster's flight-recorder dump — each replica's ring of recent
/// finished-request timelines (phase breakdowns + lifecycle events).
fn trace_route(query: &str, handle: &RouterHandle) -> Result<String> {
    let id = match query_param(query, "id") {
        Some(s) => Some(
            s.parse::<u64>()
                .map_err(|_| anyhow!("\"id\" must be a non-negative integer"))?,
        ),
        None => None,
    };
    let corr = query_param(query, "corr");
    Ok(handle.trace_json(id, corr.as_deref()))
}

/// Mark a replica drained (no new requests routed to it; in-flight ones
/// finish) or put it back in rotation.  `replica` defaults to 0 — the
/// only replica — when absent; a present-but-malformed value is an
/// error, never a silent drain of replica 0.
fn drain_route(body: &str, handle: &RouterHandle, draining: bool) -> Result<String> {
    let replica = if body.trim().is_empty() {
        0
    } else {
        let v = json::parse(body).context("invalid JSON body")?;
        match v.get("replica") {
            None => 0,
            Some(r) => r
                .as_usize()
                .ok_or_else(|| anyhow!("\"replica\" must be a non-negative integer"))?,
        }
    };
    handle.set_draining(replica, draining)?;
    let mut o = Object::new();
    o.insert("replica", replica);
    o.insert("draining", draining);
    Ok(Value::Object(o).to_string())
}

/// Re-role a replica: `{"replica": 0, "role": "prefill"|"decode"|"mixed"}`.
/// The router's placement table updates immediately; the engine thread
/// applies the role before its next step.  Like `/admin/drain`,
/// `replica` defaults to 0 when absent.
fn role_route(body: &str, handle: &RouterHandle) -> Result<String> {
    let v = json::parse(body).context("invalid JSON body")?;
    let replica = match v.get("replica") {
        None => 0,
        Some(r) => r
            .as_usize()
            .ok_or_else(|| anyhow!("\"replica\" must be a non-negative integer"))?,
    };
    let role = ReplicaRole::parse(v.req_str("role")?)?;
    handle.set_role(replica, role)?;
    let mut o = Object::new();
    o.insert("replica", replica);
    o.insert("role", role.name());
    Ok(Value::Object(o).to_string())
}

fn generate_route(body: &str, handle: &RouterHandle) -> Result<String> {
    let v = json::parse(body).context("invalid JSON body")?;
    let prompt = v.req_str("prompt")?.to_string();
    if prompt.is_empty() {
        bail!("prompt must be non-empty");
    }
    let max_new = v
        .get("max_new_tokens")
        .and_then(|x| x.as_usize())
        .unwrap_or(16);
    let sampling = SamplingParams {
        temperature: v.get("temperature").and_then(|x| x.as_f64()).unwrap_or(0.0),
        top_k: v.get("top_k").and_then(|x| x.as_usize()).unwrap_or(0),
        top_p: v.get("top_p").and_then(|x| x.as_f64()).unwrap_or(1.0),
    };
    // optional client-supplied correlation id, echoed in the response
    // and stamped into the request's trace for `/admin/trace?corr=...`
    let corr_id = match v.get("correlation_id") {
        None | Some(Value::Null) => None,
        Some(c) => Some(
            c.as_str()
                .ok_or_else(|| anyhow!("\"correlation_id\" must be a string"))?
                .to_string(),
        ),
    };
    // SLO class: `class` (interactive|batch, default interactive so
    // untagged traffic keeps pre-SLO behaviour), optional `deadline_ms`
    // wall budget, optional `tenant` for per-tenant admission shares
    let priority = match v.get("class") {
        None | Some(Value::Null) => Priority::default(),
        Some(c) => Priority::parse(
            c.as_str().ok_or_else(|| anyhow!("\"class\" must be a string"))?,
        )?,
    };
    let deadline_ms = match v.get("deadline_ms") {
        None | Some(Value::Null) => None,
        Some(d) => Some(
            d.as_usize()
                .ok_or_else(|| anyhow!("\"deadline_ms\" must be a non-negative integer"))?
                as u64,
        ),
    };
    let tenant = match v.get("tenant") {
        None | Some(Value::Null) => None,
        Some(t) => Some(
            t.as_str()
                .ok_or_else(|| anyhow!("\"tenant\" must be a string"))?
                .to_string(),
        ),
    };
    let class = ReqClass { priority, deadline_ms, tenant };
    let result = handle.generate(GenRequest {
        prompt,
        max_new_tokens: max_new,
        sampling,
        ignore_eos: v.get("ignore_eos").and_then(|x| x.as_bool()).unwrap_or(false),
        corr_id,
        class,
    })?;
    let mut o = Object::new();
    o.insert("id", result.id as usize);
    if let Some(c) = &result.corr_id {
        o.insert("correlation_id", c.as_str());
    }
    o.insert("text", result.text.as_str());
    o.insert("finish", format!("{:?}", result.finish));
    o.insert("class", result.class.priority.name());
    if let Some(d) = result.class.deadline_ms {
        o.insert("deadline_ms", d as usize);
    }
    if let Some(t) = &result.class.tenant {
        o.insert("tenant", t.as_str());
    }
    o.insert("prompt_tokens", result.prompt_tokens);
    o.insert("generated_tokens", result.generated_tokens);
    o.insert("latency_s", result.latency_s);
    o.insert("ttft_s", result.ttft_s);
    o.insert("sim_time_s", result.sim_time_s);
    // where the latency went (wall phases partition latency_s exactly)
    o.insert("phases", result.phases.to_json());
    Ok(Value::Object(o).to_string())
}

/// Server-side failures on the generate path — nothing routable, or the
/// chosen replica's engine thread died under the request — are 503 so
/// clients retry; everything else (bad JSON, empty prompt, oversized
/// prompt) stays a client error.
fn is_unavailable(e: &anyhow::Error) -> bool {
    let s = e.to_string();
    s.contains("no routable replica")
        || s.contains("engine thread gone")
        || s.contains("engine dropped the request")
        || s.contains("engine error")
}

/// Admission-controller refusals are 429 (the client did nothing wrong;
/// the cluster is protecting its interactive SLO) and carry their own
/// retry horizon.  The router string-encodes the decision — see
/// [`crate::router::SHED_MARKER`].
fn is_shed(e: &anyhow::Error) -> bool {
    e.to_string().starts_with(SHED_MARKER)
}

/// Extract `key=value` out of a whitespace-separated message — how shed
/// errors carry their class and retry horizon without error downcasting.
fn msg_field(s: &str, key: &str) -> Option<String> {
    s.split_whitespace()
        .find_map(|tok| tok.strip_prefix(key)?.strip_prefix('=').map(String::from))
}

fn error_json(e: &anyhow::Error) -> String {
    let mut o = Object::new();
    o.insert("error", format!("{e:#}"));
    Value::Object(o).to_string()
}

/// Structured overload body: keeps the `error` key every client already
/// reads, adds the priority class whose traffic was refused and the
/// machine-readable retry horizon (milliseconds; the `Retry-After`
/// header carries the same value rounded up to whole seconds).
fn overload_json(e: &anyhow::Error, class: &str, retry_after_ms: u64) -> String {
    let mut o = Object::new();
    o.insert("error", format!("{e:#}"));
    o.insert("class", class);
    o.insert("retry_after_ms", retry_after_ms as usize);
    Value::Object(o).to_string()
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// Minimal blocking HTTP client matched to the server above.
pub struct Client {
    pub addr: String,
}

impl Client {
    pub fn new(addr: impl Into<String>) -> Self {
        Client { addr: addr.into() }
    }

    pub fn get(&self, path: &str) -> Result<(u16, Value)> {
        self.request("GET", path, None)
    }

    /// GET returning the raw body — for non-JSON endpoints like the
    /// Prometheus text exposition (`/metrics?format=prometheus`).
    pub fn get_text(&self, path: &str) -> Result<(u16, String)> {
        self.request_raw("GET", path, None)
    }

    pub fn post(&self, path: &str, body: &Value) -> Result<(u16, Value)> {
        self.request("POST", path, Some(body.to_string()))
    }

    pub fn generate(&self, prompt: &str, max_new: usize) -> Result<Value> {
        let mut o = Object::new();
        o.insert("prompt", prompt);
        o.insert("max_new_tokens", max_new);
        let (status, v) = self.post("/v1/generate", &Value::Object(o))?;
        if status != 200 {
            bail!("generate failed ({status}): {v}");
        }
        Ok(v)
    }

    /// POST capturing the `Retry-After` response header (seconds) next
    /// to the parsed body — how overload tests and well-behaved clients
    /// read the 429/503 backoff contract.
    pub fn post_for_retry(
        &self,
        path: &str,
        body: &Value,
    ) -> Result<(u16, Option<u64>, Value)> {
        let (status, retry_after, body) =
            self.request_full("POST", path, Some(body.to_string()))?;
        Ok((status, retry_after, json::parse(&body)?))
    }

    fn request(&self, method: &str, path: &str, body: Option<String>) -> Result<(u16, Value)> {
        let (status, body) = self.request_raw(method, path, body)?;
        Ok((status, json::parse(&body)?))
    }

    fn request_raw(
        &self,
        method: &str,
        path: &str,
        body: Option<String>,
    ) -> Result<(u16, String)> {
        let (status, _, body) = self.request_full(method, path, body)?;
        Ok((status, body))
    }

    fn request_full(
        &self,
        method: &str,
        path: &str,
        body: Option<String>,
    ) -> Result<(u16, Option<u64>, String)> {
        let mut stream = TcpStream::connect(&self.addr)
            .with_context(|| format!("connecting {}", self.addr))?;
        stream.set_read_timeout(Some(Duration::from_secs(120)))?;
        let body = body.unwrap_or_default();
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            self.addr,
            body.len()
        );
        stream.write_all(req.as_bytes())?;
        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line)?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad status line '{status_line}'"))?;
        let mut content_length = 0usize;
        let mut retry_after = None;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h)?;
            if h.trim().is_empty() {
                break;
            }
            let lower = h.to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("content-length:") {
                content_length = v.trim().parse().unwrap_or(0);
            }
            if let Some(v) = lower.strip_prefix("retry-after:") {
                retry_after = v.trim().parse::<u64>().ok();
            }
        }
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body)?;
        Ok((status, retry_after, String::from_utf8_lossy(&body).into_owned()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, COOPT};
    use crate::runtime::mock::MockBackend;

    fn spawn_server() -> (Server, Client) {
        let engine = Engine::new(MockBackend::new(), EngineConfig::new("llama-7b-sim", COOPT));
        let handle = EngineHandle::spawn(engine);
        let server = Server::bind("127.0.0.1:0", handle, 4).unwrap();
        let client = Client::new(server.addr.to_string());
        (server, client)
    }

    #[test]
    fn snapshot_age_arithmetic() {
        // publishing keeps pace: age 0
        assert_eq!(snapshot_age_steps(7, 7), 0);
        // writer lags by 3 steps
        assert_eq!(snapshot_age_steps(10, 7), 3);
        // pre-first-step snapshot (seq 0) against a running loop
        assert_eq!(snapshot_age_steps(5, 0), 5);
        // a reader that races the step-counter store can see the
        // snapshot seq ahead of the mirrored counter; saturate, never
        // wrap to u64::MAX
        assert_eq!(snapshot_age_steps(7, 8), 0);
    }

    #[test]
    fn health_metrics_generate_roundtrip() {
        let (server, client) = spawn_server();
        let stop = server.stop_flag();
        let srv = std::thread::spawn(move || server.serve().unwrap());

        let (code, v) = client.get("/health").unwrap();
        assert_eq!(code, 200);
        assert_eq!(v.req_str("status").unwrap(), "ok");

        let v = client.generate("hello over http", 4).unwrap();
        assert_eq!(v.req_usize("generated_tokens").unwrap(), 4);

        // cache-tier stats ride along in /metrics (published after the
        // engine's next step; poll briefly to avoid racing it)
        let mut m = Value::Null;
        for _ in 0..100 {
            let (code, v) = client.get("/metrics").unwrap();
            assert_eq!(code, 200);
            if v.get("swap_outs").is_some() {
                m = v;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(m.req_usize("swap_outs").unwrap(), 0);
        assert_eq!(m.req_usize("host_pool_blocks").unwrap(), 0);
        assert!(m.req_usize("cache_blocks_total").unwrap() > 0);
        // batch-efficiency gauges ride along: tokens committed per decode
        // round and decode-batch occupancy (1 token/step, one lane of 8,
        // on this single-request one-token engine)
        assert!((m.req_f64("tokens_per_step").unwrap() - 1.0).abs() < 1e-9);
        let occ = m.req_f64("decode_batch_occupancy").unwrap();
        assert!(occ > 0.0 && occ <= 1.0, "occupancy {occ}");
        assert_eq!(m.req_usize("spec_rounds").unwrap(), 0);
        // adaptive-speculation gauges ride along even when speculation
        // is off (k 0, regime unknown, histogram omitted)
        assert_eq!(m.req_usize("spec_k_current").unwrap(), 0);
        assert_eq!(m.req_str("spec_regime").unwrap(), "");
        assert!(m.get("spec_k_hist").is_none());

        let (code, _e) = client.get("/nope").unwrap();
        assert_eq!(code, 404);

        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn metrics_expose_live_adaptive_controller_state() {
        let engine = Engine::new(
            MockBackend::new(),
            EngineConfig::new("llama-7b-sim", COOPT).with_adaptive_speculation(4),
        );
        let handle = EngineHandle::spawn(engine);
        let server = Server::bind("127.0.0.1:0", handle, 2).unwrap();
        let client = Client::new(server.addr.to_string());
        let stop = server.stop_flag();
        let srv = std::thread::spawn(move || server.serve().unwrap());

        let v = client.generate("adaptive over http", 8).unwrap();
        assert_eq!(v.req_usize("generated_tokens").unwrap(), 8);
        // the controller's state publishes after the engine's next step
        let mut m = Value::Null;
        for _ in 0..100 {
            let (code, v) = client.get("/metrics").unwrap();
            assert_eq!(code, 200);
            if v.get("spec_k_hist").is_some() {
                m = v;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let hist = m.get("spec_k_hist").expect("live k histogram");
        assert!(hist.as_object().is_some());
        assert!(m.req_f64("spec_acceptance_ewma").unwrap() > 0.0);
        assert_eq!(m.req_str("spec_regime").unwrap(), "weight-stream-bound");
        assert!(m.req_f64("tokens_per_step_weight_stream").unwrap() > 1.0);
        assert!(m.get("spec_k_current").is_some());

        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn concurrent_requests_batch() {
        let (server, client) = spawn_server();
        let stop = server.stop_flag();
        let addr = client.addr.clone();
        let srv = std::thread::spawn(move || server.serve().unwrap());

        let pool = ThreadPool::new(6);
        let results = pool.map((0..6).collect::<Vec<u32>>(), move |i| {
            let c = Client::new(addr.clone());
            c.generate(&format!("concurrent prompt {i}"), 5)
                .map(|v| v.req_usize("generated_tokens").unwrap())
        });
        for r in results {
            assert_eq!(r.unwrap(), 5);
        }
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn multi_replica_metrics_drain_and_unavailable() {
        use crate::config::RouterPolicy;
        let engines = vec![
            Engine::new(MockBackend::new(), EngineConfig::new("llama-7b-sim", COOPT)),
            Engine::new(MockBackend::new(), EngineConfig::new("llama-7b-sim", COOPT)),
        ];
        let router = RouterHandle::spawn(engines, RouterPolicy::RoundRobin);
        let server = Server::bind_router("127.0.0.1:0", router, 4).unwrap();
        let client = Client::new(server.addr.to_string());
        let stop = server.stop_flag();
        let srv = std::thread::spawn(move || server.serve().unwrap());

        // health reports per-replica status
        let (code, h) = client.get("/health").unwrap();
        assert_eq!(code, 200);
        assert_eq!(h.req_usize("num_replicas").unwrap(), 2);
        let reps = h.req_array("replicas").unwrap();
        assert_eq!(reps.len(), 2);
        assert!(reps[0].req_bool("healthy").unwrap());

        // two sequential requests round-robin across both replicas
        for i in 0..2 {
            let v = client.generate(&format!("replica tour {i}"), 3).unwrap();
            assert_eq!(v.req_usize("generated_tokens").unwrap(), 3);
        }

        // drain replica 0; the next requests all land on replica 1
        let mut body = Object::new();
        body.insert("replica", 0usize);
        let (code, d) = client
            .post("/admin/drain", &Value::Object(body.clone()))
            .unwrap();
        assert_eq!(code, 200);
        assert!(d.req_bool("draining").unwrap());
        let (_, h) = client.get("/health").unwrap();
        assert!(h.req_array("replicas").unwrap()[0].req_bool("draining").unwrap());
        for i in 0..2 {
            client.generate(&format!("drained era {i}"), 3).unwrap();
        }

        // aggregated /metrics: cluster sums + seq-stamped replica views
        // (snapshots publish after each engine's next step; poll briefly)
        let mut split = (0usize, 0usize);
        for _ in 0..200 {
            let (code, m) = client.get("/metrics").unwrap();
            assert_eq!(code, 200);
            let reps = m.req_array("replicas").unwrap();
            let tok = |i: usize| {
                reps[i]
                    .req("metrics")
                    .and_then(|x| x.req_usize("tokens_generated"))
                    .unwrap_or(0)
            };
            split = (tok(0), tok(1));
            if split.0 + split.1 >= 12 {
                assert_eq!(m.req_usize("tokens_generated").unwrap(), 12);
                assert_eq!(m.req_usize("num_replicas").unwrap(), 2);
                assert_eq!(m.req_str("router_policy").unwrap(), "round_robin");
                assert!(reps[0].req_usize("seq").unwrap() > 0);
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(split, (3, 9), "drain steered traffic to replica 1");

        // drain the last replica: generate must 503, not wedge
        let mut body1 = Object::new();
        body1.insert("replica", 1usize);
        client.post("/admin/drain", &Value::Object(body1)).unwrap();
        let mut req = Object::new();
        req.insert("prompt", "nowhere to go");
        let (code, e) = client.post("/v1/generate", &Value::Object(req)).unwrap();
        assert_eq!(code, 503);
        assert!(e.req_str("error").unwrap().contains("no routable replica"));

        // undrain restores service
        let (code, _) = client
            .post("/admin/undrain", &Value::Object(body))
            .unwrap();
        assert_eq!(code, 200);
        let v = client.generate("back online", 2).unwrap();
        assert_eq!(v.req_usize("generated_tokens").unwrap(), 2);

        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn unavailable_classification_tracks_engine_error_strings() {
        // these messages originate in EngineHandle::generate, the engine
        // thread's error fan-out, and RouterHandle::generate; this test
        // is the link that fails if any of them is reworded without
        // updating is_unavailable (a 503 regressing to 400 would stop
        // clients from retrying a server-side failure)
        for msg in [
            "no routable replica (all draining or dead)",
            "engine thread gone",
            "engine dropped the request",
            "engine error: stuck: 3 waiting requests",
        ] {
            assert!(is_unavailable(&anyhow!("{msg}")), "{msg} must be 503");
        }
        for msg in ["invalid JSON body", "prompt must be non-empty", "empty prompt"] {
            assert!(!is_unavailable(&anyhow!("{msg}")), "{msg} must stay 400");
        }
    }

    #[test]
    fn overload_responses_carry_retry_after() {
        use crate::config::SloConfig;
        // max_batch_queue 0: every batch-class request is shed at
        // admission while interactive traffic still serves
        let engine = Engine::new(MockBackend::new(), EngineConfig::new("llama-7b-sim", COOPT));
        let router = RouterHandle::single(EngineHandle::spawn(engine)).with_slo(SloConfig {
            admission: true,
            max_batch_queue: 0,
            ..SloConfig::default()
        });
        let server = Server::bind_router("127.0.0.1:0", router, 4).unwrap();
        let client = Client::new(server.addr.to_string());
        let stop = server.stop_flag();
        let srv = std::thread::spawn(move || server.serve().unwrap());

        // interactive request with the full class triple: served, and
        // the response echoes class / deadline_ms / tenant back
        let mut req = Object::new();
        req.insert("prompt", "interactive under slo");
        req.insert("max_new_tokens", 3usize);
        req.insert("class", "interactive");
        req.insert("deadline_ms", 60_000usize);
        req.insert("tenant", "acme");
        let (code, retry, v) = client.post_for_retry("/v1/generate", &Value::Object(req)).unwrap();
        assert_eq!(code, 200);
        assert!(retry.is_none(), "success responses carry no Retry-After");
        assert_eq!(v.req_str("class").unwrap(), "interactive");
        assert_eq!(v.req_usize("deadline_ms").unwrap(), 60_000);
        assert_eq!(v.req_str("tenant").unwrap(), "acme");
        assert_eq!(v.req_usize("generated_tokens").unwrap(), 3);

        // batch request: shed with 429, Retry-After header, and the
        // structured {"error","class","retry_after_ms"} body
        let mut req = Object::new();
        req.insert("prompt", "batch refused");
        req.insert("class", "batch");
        let (code, retry, e) = client.post_for_retry("/v1/generate", &Value::Object(req)).unwrap();
        assert_eq!(code, 429);
        assert!(retry.unwrap() >= 1, "Retry-After rounds up to whole seconds");
        assert!(e.req_str("error").unwrap().starts_with(SHED_MARKER));
        assert_eq!(e.req_str("class").unwrap(), "batch");
        assert!(e.req_usize("retry_after_ms").unwrap() > 0);

        // unknown class name is the client's mistake: 400, no header
        let mut req = Object::new();
        req.insert("prompt", "mislabeled");
        req.insert("class", "urgent");
        let (code, retry, e) = client.post_for_retry("/v1/generate", &Value::Object(req)).unwrap();
        assert_eq!(code, 400);
        assert!(retry.is_none());
        assert!(e.req_str("error").unwrap().contains("unknown priority class"));

        // drain the only replica: 503 keeps the legacy error text and
        // gains the same structured overload contract
        let mut body = Object::new();
        body.insert("replica", 0usize);
        client.post("/admin/drain", &Value::Object(body)).unwrap();
        let mut req = Object::new();
        req.insert("prompt", "nowhere to go");
        req.insert("class", "interactive");
        let (code, retry, e) = client.post_for_retry("/v1/generate", &Value::Object(req)).unwrap();
        assert_eq!(code, 503);
        assert_eq!(retry, Some(1));
        assert!(e.req_str("error").unwrap().contains("no routable replica"));
        assert_eq!(e.req_str("class").unwrap(), "interactive");
        assert_eq!(e.req_usize("retry_after_ms").unwrap(), UNAVAILABLE_RETRY_MS as usize);

        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn single_replica_metrics_snapshot_is_seq_stamped() {
        // the N = 1 path keeps the flat payload and gains the replicas
        // array with a monotone snapshot sequence number
        let (server, client) = spawn_server();
        let stop = server.stop_flag();
        let srv = std::thread::spawn(move || server.serve().unwrap());
        client.generate("seq stamp", 3).unwrap();
        let mut last_seq = 0usize;
        for _ in 0..100 {
            let (_, m) = client.get("/metrics").unwrap();
            let reps = m.req_array("replicas").unwrap();
            assert_eq!(reps.len(), 1);
            let seq = reps[0].req_usize("seq").unwrap();
            assert!(seq >= last_seq, "snapshot seq went backwards");
            last_seq = seq;
            if m.req_usize("tokens_generated").unwrap_or(0) >= 3 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(last_seq > 0, "engine never published a post-step snapshot");
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn rejects_bad_body() {
        let (server, client) = spawn_server();
        let stop = server.stop_flag();
        let srv = std::thread::spawn(move || server.serve().unwrap());
        let (code, v) = client
            .post("/v1/generate", &json::parse("{\"nope\": 1}").unwrap())
            .unwrap();
        assert_eq!(code, 400);
        assert!(v.req_str("error").unwrap().contains("prompt"));
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn pd_roles_hand_off_and_admin_role_over_http() {
        use crate::config::{RouterPolicy, SwapPolicy};
        let pd = |role| {
            Engine::new(
                MockBackend::new(),
                EngineConfig::new("llama-7b-sim", COOPT)
                    .with_host_pool(64)
                    .with_swap_policy(SwapPolicy::Always)
                    .with_role(role),
            )
        };
        let router = RouterHandle::spawn(
            vec![pd(ReplicaRole::Prefill), pd(ReplicaRole::Decode)],
            RouterPolicy::LeastLoaded,
        )
        .with_unpriced_handoff();
        let server = Server::bind_router("127.0.0.1:0", router, 4).unwrap();
        let client = Client::new(server.addr.to_string());
        let stop = server.stop_flag();
        let srv = std::thread::spawn(move || server.serve().unwrap());

        // roles surface in /health
        let (code, h) = client.get("/health").unwrap();
        assert_eq!(code, 200);
        let reps = h.req_array("replicas").unwrap();
        assert_eq!(reps[0].req_str("role").unwrap(), "prefill");
        assert_eq!(reps[1].req_str("role").unwrap(), "decode");

        // a prefill-heavy request starts on the prefill replica, hands
        // its KV off through the host tier, and decodes on the decode
        // replica — the reply travels with it
        let long_prompt = format!("pd over http {}", "h".repeat(48));
        let v = client.generate(&long_prompt, 4).unwrap();
        assert_eq!(v.req_usize("generated_tokens").unwrap(), 4);
        let mut migrated = false;
        for _ in 0..200 {
            let (_, m) = client.get("/metrics").unwrap();
            if m.req_usize("migrations_out").unwrap_or(0) >= 1
                && m.req_usize("migrations_in").unwrap_or(0) >= 1
            {
                assert_eq!(m.req_array("replica_roles").unwrap().len(), 2);
                migrated = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(migrated, "hand-off never reached /metrics");

        // /admin/role re-roles a replica at runtime
        let mut body = Object::new();
        body.insert("replica", 0usize);
        body.insert("role", "mixed");
        let (code, r) = client.post("/admin/role", &Value::Object(body)).unwrap();
        assert_eq!(code, 200);
        assert_eq!(r.req_str("role").unwrap(), "mixed");
        let (_, h) = client.get("/health").unwrap();
        assert_eq!(
            h.req_array("replicas").unwrap()[0].req_str("role").unwrap(),
            "mixed"
        );
        // a bad role is a client error, not a 500
        let mut bad = Object::new();
        bad.insert("replica", 0usize);
        bad.insert("role", "turbo");
        let (code, _) = client.post("/admin/role", &Value::Object(bad)).unwrap();
        assert_eq!(code, 400);

        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }
}
