//! HTTP/1.1 serving front-end (hand-rolled; tokio/axum unavailable
//! offline) + a matching client.
//!
//! Architecture: one *engine thread* owns the [`Engine`] and runs the
//! continuous-batching loop; HTTP connections are handled by a
//! [`ThreadPool`], each request is submitted over an mpsc channel with a
//! oneshot-style reply channel, so concurrent HTTP requests batch
//! together inside the engine — the same structure as vLLM's
//! AsyncLLMEngine front-end.
//!
//! Endpoints:
//!   GET  /health            -> {"status":"ok", ...}
//!   GET  /metrics           -> engine metrics JSON (Eq. 11/12 fields)
//!   POST /v1/generate       -> {"text": ..., "finish": ..., ...}
//!       body: {"prompt": "...", "max_new_tokens": 16, "temperature": 0.0}

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{Engine, GenRequest, GenResult};
use crate::runtime::Backend;
use crate::sampling::SamplingParams;
use crate::util::json::{self, Object, Value};
use crate::util::threadpool::ThreadPool;

// ---------------------------------------------------------------------------
// engine thread
// ---------------------------------------------------------------------------

struct Job {
    req: GenRequest,
    reply: Sender<Result<GenResult>>,
}

/// Handle to the background engine loop.
pub struct EngineHandle {
    tx: Sender<Job>,
    metrics_json: Arc<Mutex<String>>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl EngineHandle {
    /// Take ownership of the engine and run it on a dedicated thread.
    pub fn spawn<B: Backend + Send + 'static>(mut engine: Engine<B>) -> Self {
        let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
        let metrics_json = Arc::new(Mutex::new("{}".to_string()));
        let stop = Arc::new(AtomicBool::new(false));
        let mj = Arc::clone(&metrics_json);
        let st = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("coopt-engine".into())
            .spawn(move || {
                let mut waiters: Vec<(u64, Sender<Result<GenResult>>)> = Vec::new();
                let submit =
                    |engine: &mut Engine<B>,
                     job: Job,
                     waiters: &mut Vec<(u64, Sender<Result<GenResult>>)>| {
                        match engine.submit(job.req) {
                            Ok(id) => waiters.push((id, job.reply)),
                            Err(e) => {
                                let _ = job.reply.send(Err(e));
                            }
                        }
                    };
                engine.metrics.start_run();
                loop {
                    if st.load(Ordering::Relaxed) {
                        return;
                    }
                    // idle: block on the job channel instead of polling —
                    // the timeout only exists to honor the stop flag
                    if engine.num_pending() == 0 {
                        match rx.recv_timeout(Duration::from_millis(100)) {
                            Ok(job) => submit(&mut engine, job, &mut waiters),
                            Err(RecvTimeoutError::Timeout) => continue,
                            Err(RecvTimeoutError::Disconnected) => return,
                        }
                    }
                    // busy: opportunistically drain whatever else queued so
                    // concurrent requests batch into the same round
                    loop {
                        match rx.try_recv() {
                            Ok(job) => submit(&mut engine, job, &mut waiters),
                            Err(TryRecvError::Empty) => break,
                            Err(TryRecvError::Disconnected) => return,
                        }
                    }
                    match engine.step() {
                        Ok(results) => {
                            for r in results {
                                if let Some(pos) = waiters.iter().position(|(id, _)| *id == r.id)
                                {
                                    let (_, reply) = waiters.swap_remove(pos);
                                    let _ = reply.send(Ok(r));
                                }
                            }
                        }
                        Err(e) => {
                            // engine error: fail everything in flight
                            for (_, reply) in waiters.drain(..) {
                                let _ = reply.send(Err(anyhow!("engine error: {e}")));
                            }
                        }
                    }
                    if let Ok(mut m) = mj.lock() {
                        // metrics + cache-tier stats (swap/prefetch counters,
                        // host pool occupancy) for GET /metrics
                        *m = engine.stats_json().to_string();
                    }
                }
            })
            .expect("spawn engine thread");
        EngineHandle {
            tx,
            metrics_json,
            stop,
            thread: Some(thread),
        }
    }

    /// Blocking generate through the engine thread.
    pub fn generate(&self, req: GenRequest) -> Result<GenResult> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Job {
                req,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("engine dropped the request"))?
    }

    pub fn metrics_json(&self) -> String {
        self.metrics_json.lock().unwrap().clone()
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// HTTP server
// ---------------------------------------------------------------------------

pub struct Server {
    pub addr: std::net::SocketAddr,
    listener: TcpListener,
    handle: Arc<EngineHandle>,
    pool: ThreadPool,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str, handle: EngineHandle, workers: usize) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true)?;
        Ok(Server {
            addr: listener.local_addr()?,
            listener,
            handle: Arc::new(handle),
            pool: ThreadPool::new(workers),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Accept loop; returns when the stop flag is set.
    pub fn serve(&self) -> Result<()> {
        crate::log_info!("serving on http://{}", self.addr);
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let handle = Arc::clone(&self.handle);
                    self.pool.execute(move || {
                        let _ = handle_connection(stream, &handle);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

fn handle_connection(mut stream: TcpStream, handle: &EngineHandle) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);

    // request line
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    // headers
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length.min(1 << 20)];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    let body = String::from_utf8_lossy(&body).into_owned();

    let (status, payload) = route(&method, &path, &body, handle);
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{payload}",
        payload.len()
    );
    stream.write_all(resp.as_bytes())?;
    Ok(())
}

fn route(method: &str, path: &str, body: &str, handle: &EngineHandle) -> (&'static str, String) {
    match (method, path) {
        ("GET", "/health") => {
            let mut o = Object::new();
            o.insert("status", "ok");
            o.insert("service", "llm-coopt");
            ("200 OK", Value::Object(o).to_string())
        }
        ("GET", "/metrics") => ("200 OK", handle.metrics_json()),
        ("POST", "/v1/generate") => match generate_route(body, handle) {
            Ok(p) => ("200 OK", p),
            Err(e) => ("400 Bad Request", error_json(&e)),
        },
        _ => ("404 Not Found", error_json(&anyhow!("no route {method} {path}"))),
    }
}

fn generate_route(body: &str, handle: &EngineHandle) -> Result<String> {
    let v = json::parse(body).context("invalid JSON body")?;
    let prompt = v.req_str("prompt")?.to_string();
    if prompt.is_empty() {
        bail!("prompt must be non-empty");
    }
    let max_new = v
        .get("max_new_tokens")
        .and_then(|x| x.as_usize())
        .unwrap_or(16);
    let sampling = SamplingParams {
        temperature: v.get("temperature").and_then(|x| x.as_f64()).unwrap_or(0.0),
        top_k: v.get("top_k").and_then(|x| x.as_usize()).unwrap_or(0),
        top_p: v.get("top_p").and_then(|x| x.as_f64()).unwrap_or(1.0),
    };
    let result = handle.generate(GenRequest {
        prompt,
        max_new_tokens: max_new,
        sampling,
        ignore_eos: v.get("ignore_eos").and_then(|x| x.as_bool()).unwrap_or(false),
    })?;
    let mut o = Object::new();
    o.insert("id", result.id as usize);
    o.insert("text", result.text.as_str());
    o.insert("finish", format!("{:?}", result.finish));
    o.insert("prompt_tokens", result.prompt_tokens);
    o.insert("generated_tokens", result.generated_tokens);
    o.insert("latency_s", result.latency_s);
    o.insert("ttft_s", result.ttft_s);
    o.insert("sim_time_s", result.sim_time_s);
    Ok(Value::Object(o).to_string())
}

fn error_json(e: &anyhow::Error) -> String {
    let mut o = Object::new();
    o.insert("error", format!("{e:#}"));
    Value::Object(o).to_string()
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// Minimal blocking HTTP client matched to the server above.
pub struct Client {
    pub addr: String,
}

impl Client {
    pub fn new(addr: impl Into<String>) -> Self {
        Client { addr: addr.into() }
    }

    pub fn get(&self, path: &str) -> Result<(u16, Value)> {
        self.request("GET", path, None)
    }

    pub fn post(&self, path: &str, body: &Value) -> Result<(u16, Value)> {
        self.request("POST", path, Some(body.to_string()))
    }

    pub fn generate(&self, prompt: &str, max_new: usize) -> Result<Value> {
        let mut o = Object::new();
        o.insert("prompt", prompt);
        o.insert("max_new_tokens", max_new);
        let (status, v) = self.post("/v1/generate", &Value::Object(o))?;
        if status != 200 {
            bail!("generate failed ({status}): {v}");
        }
        Ok(v)
    }

    fn request(&self, method: &str, path: &str, body: Option<String>) -> Result<(u16, Value)> {
        let mut stream = TcpStream::connect(&self.addr)
            .with_context(|| format!("connecting {}", self.addr))?;
        stream.set_read_timeout(Some(Duration::from_secs(120)))?;
        let body = body.unwrap_or_default();
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            self.addr,
            body.len()
        );
        stream.write_all(req.as_bytes())?;
        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line)?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad status line '{status_line}'"))?;
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h)?;
            if h.trim().is_empty() {
                break;
            }
            if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body)?;
        let v = json::parse(&String::from_utf8_lossy(&body))?;
        Ok((status, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, COOPT};
    use crate::runtime::mock::MockBackend;

    fn spawn_server() -> (Server, Client) {
        let engine = Engine::new(MockBackend::new(), EngineConfig::new("llama-7b-sim", COOPT));
        let handle = EngineHandle::spawn(engine);
        let server = Server::bind("127.0.0.1:0", handle, 4).unwrap();
        let client = Client::new(server.addr.to_string());
        (server, client)
    }

    #[test]
    fn health_metrics_generate_roundtrip() {
        let (server, client) = spawn_server();
        let stop = server.stop_flag();
        let srv = std::thread::spawn(move || server.serve().unwrap());

        let (code, v) = client.get("/health").unwrap();
        assert_eq!(code, 200);
        assert_eq!(v.req_str("status").unwrap(), "ok");

        let v = client.generate("hello over http", 4).unwrap();
        assert_eq!(v.req_usize("generated_tokens").unwrap(), 4);

        // cache-tier stats ride along in /metrics (published after the
        // engine's next step; poll briefly to avoid racing it)
        let mut m = Value::Null;
        for _ in 0..100 {
            let (code, v) = client.get("/metrics").unwrap();
            assert_eq!(code, 200);
            if v.get("swap_outs").is_some() {
                m = v;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(m.req_usize("swap_outs").unwrap(), 0);
        assert_eq!(m.req_usize("host_pool_blocks").unwrap(), 0);
        assert!(m.req_usize("cache_blocks_total").unwrap() > 0);
        // batch-efficiency gauges ride along: tokens committed per decode
        // round and decode-batch occupancy (1 token/step, one lane of 8,
        // on this single-request one-token engine)
        assert!((m.req_f64("tokens_per_step").unwrap() - 1.0).abs() < 1e-9);
        let occ = m.req_f64("decode_batch_occupancy").unwrap();
        assert!(occ > 0.0 && occ <= 1.0, "occupancy {occ}");
        assert_eq!(m.req_usize("spec_rounds").unwrap(), 0);
        // adaptive-speculation gauges ride along even when speculation
        // is off (k 0, regime unknown, histogram omitted)
        assert_eq!(m.req_usize("spec_k_current").unwrap(), 0);
        assert_eq!(m.req_str("spec_regime").unwrap(), "");
        assert!(m.get("spec_k_hist").is_none());

        let (code, _e) = client.get("/nope").unwrap();
        assert_eq!(code, 404);

        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn metrics_expose_live_adaptive_controller_state() {
        let engine = Engine::new(
            MockBackend::new(),
            EngineConfig::new("llama-7b-sim", COOPT).with_adaptive_speculation(4),
        );
        let handle = EngineHandle::spawn(engine);
        let server = Server::bind("127.0.0.1:0", handle, 2).unwrap();
        let client = Client::new(server.addr.to_string());
        let stop = server.stop_flag();
        let srv = std::thread::spawn(move || server.serve().unwrap());

        let v = client.generate("adaptive over http", 8).unwrap();
        assert_eq!(v.req_usize("generated_tokens").unwrap(), 8);
        // the controller's state publishes after the engine's next step
        let mut m = Value::Null;
        for _ in 0..100 {
            let (code, v) = client.get("/metrics").unwrap();
            assert_eq!(code, 200);
            if v.get("spec_k_hist").is_some() {
                m = v;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let hist = m.get("spec_k_hist").expect("live k histogram");
        assert!(hist.as_object().is_some());
        assert!(m.req_f64("spec_acceptance_ewma").unwrap() > 0.0);
        assert_eq!(m.req_str("spec_regime").unwrap(), "weight-stream-bound");
        assert!(m.req_f64("tokens_per_step_weight_stream").unwrap() > 1.0);
        assert!(m.get("spec_k_current").is_some());

        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn concurrent_requests_batch() {
        let (server, client) = spawn_server();
        let stop = server.stop_flag();
        let addr = client.addr.clone();
        let srv = std::thread::spawn(move || server.serve().unwrap());

        let pool = ThreadPool::new(6);
        let results = pool.map((0..6).collect::<Vec<u32>>(), move |i| {
            let c = Client::new(addr.clone());
            c.generate(&format!("concurrent prompt {i}"), 5)
                .map(|v| v.req_usize("generated_tokens").unwrap())
        });
        for r in results {
            assert_eq!(r.unwrap(), 5);
        }
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn rejects_bad_body() {
        let (server, client) = spawn_server();
        let stop = server.stop_flag();
        let srv = std::thread::spawn(move || server.serve().unwrap());
        let (code, v) = client
            .post("/v1/generate", &json::parse("{\"nope\": 1}").unwrap())
            .unwrap();
        assert_eq!(code, 400);
        assert!(v.req_str("error").unwrap().contains("prompt"));
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }
}
