//! Cluster-wide prefix directory: which replica holds which prefix
//! chain, and in which KV tier.
//!
//! PR 5's affinity map remembered one leading block per prompt in a
//! 65,536-entry `HashMap` that *reset wholesale* at capacity — every
//! remembered affinity lost at once, and nothing about how *much* of a
//! prompt a replica holds or where (device vs host).  This module
//! replaces it with a directory over the full prefix chain
//! ([`crate::kvcache::prefix_chain_hashes`] — the prefix index's own
//! content+position hashes, one per full KV block):
//!
//! * an approximate-membership **front**: a counting-Bloom
//!   [`MembershipSketch`] (4 rows, power-of-two width, saturating `u8`
//!   counters — pure Rust, no deps) answers "definitely absent" in four
//!   array reads, so probing a 32-block chain against a directory of
//!   millions costs almost nothing on the common miss path;
//! * an exact **entry table** behind it: hash → ([`DirEntry`]) owning
//!   replica, KV tier ([`Tier::Device`] > [`Tier::Host`] — a device hit
//!   serves immediately, a host hit still crosses PCIe), and per-entry
//!   hit accounting;
//! * **admission-ordered eviction**: at capacity the oldest admitted
//!   entry is evicted — never a wholesale reset, so a long-lived serve
//!   process degrades smoothly instead of cliff-dropping all affinity
//!   (the sketch is kept in sync by removing evicted hashes).
//!
//! Replicas publish [`crate::kvcache::PrefixDelta`]s (block
//! committed/swapped/evicted, observed at the `CacheManager`'s
//! index/unindex seams) through the metrics snapshot channel; the
//! router [`PrefixDirectory::apply`]s them, making the directory
//! *eventually consistent*.  Staleness is safe by construction: a stale
//! entry at worst routes a pull that exports fewer blocks than asked
//! (or none), and the destination simply prefills the uncovered tail —
//! outputs are exact either way, only the saved work shrinks.

use std::collections::{HashMap, VecDeque};

use crate::kvcache::{PrefixDelta, PrefixDeltaKind};

/// Which KV tier the owning replica holds a prefix block in.  Probes
/// report it so pricing can distinguish a device hit (one PCIe export
/// away) from a host hit (already staged host-side).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    Device,
    Host,
}

/// Counting-Bloom approximate-membership front.  `maybe_contains`
/// returning `false` is definitive; `true` may be a false positive
/// (bounded by the 4-row, quarter-load geometry at well under 5% — see
/// the tests), which only costs one exact `HashMap` probe.  Counters
/// saturate at 255; with the directory's bounded entry count the
/// expected per-cell load is ≤ 1/4, so saturation is unreachable in
/// practice and a saturated cell merely degrades to a sticky "maybe".
#[derive(Debug, Clone)]
pub struct MembershipSketch {
    /// `SKETCH_ROWS` rows of `width` counters each, flattened
    counters: Vec<u8>,
    width_mask: u64,
    width: usize,
}

const SKETCH_ROWS: usize = 4;
/// Per-row seeds (odd constants from splitmix64's own stream).
const SKETCH_SEEDS: [u64; SKETCH_ROWS] = [
    0x9e37_79b9_7f4a_7c15,
    0xbf58_476d_1ce4_e5b9,
    0x94d0_49bb_1331_11eb,
    0xd6e8_feb8_6659_fd93,
];

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

impl MembershipSketch {
    /// Sized for `cap` resident keys at ≤ 1/4 per-row load.
    pub fn new(cap: usize) -> Self {
        let width = (4 * cap.max(1)).next_power_of_two().max(1024);
        MembershipSketch {
            counters: vec![0; SKETCH_ROWS * width],
            width_mask: width as u64 - 1,
            width,
        }
    }

    fn cell(&self, row: usize, h: u64) -> usize {
        row * self.width + (splitmix64(h ^ SKETCH_SEEDS[row]) & self.width_mask) as usize
    }

    pub fn insert(&mut self, h: u64) {
        for row in 0..SKETCH_ROWS {
            let c = self.cell(row, h);
            self.counters[c] = self.counters[c].saturating_add(1);
        }
    }

    pub fn remove(&mut self, h: u64) {
        for row in 0..SKETCH_ROWS {
            let c = self.cell(row, h);
            self.counters[c] = self.counters[c].saturating_sub(1);
        }
    }

    /// `false` is definitive absence; `true` warrants the exact probe.
    pub fn maybe_contains(&self, h: u64) -> bool {
        (0..SKETCH_ROWS).all(|row| self.counters[self.cell(row, h)] > 0)
    }
}

/// One directory entry: where a prefix-chain hash's KV block lives.
#[derive(Debug, Clone)]
pub struct DirEntry {
    pub replica: usize,
    pub tier: Tier,
    /// probe hits on this entry (per-entry accounting for the hit-tier
    /// gauges and for observability dumps)
    pub hits: u64,
}

/// The cluster-level prefix directory (see the module docs).
pub struct PrefixDirectory {
    sketch: MembershipSketch,
    entries: HashMap<u64, DirEntry>,
    /// admission order; eviction pops the front, skipping keys whose
    /// entry was already removed by an `Evict` delta (a re-admitted key
    /// may appear twice — the stale occurrence is skipped the same way)
    order: VecDeque<u64>,
    cap: usize,
    pub device_hits: u64,
    pub host_hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// Default capacity: same order as the map it replaces, but eviction is
/// now incremental (admission-ordered) instead of a wholesale reset.
pub const DIRECTORY_CAP: usize = 65_536;

impl PrefixDirectory {
    pub fn new(cap: usize) -> Self {
        PrefixDirectory {
            sketch: MembershipSketch::new(cap),
            entries: HashMap::new(),
            order: VecDeque::new(),
            cap: cap.max(1),
            device_hits: 0,
            host_hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entry(&self, hash: u64) -> Option<&DirEntry> {
        self.entries.get(&hash)
    }

    /// The owning replica of a single hash without hit accounting (the
    /// routing path's affinity lookup).
    pub fn owner_of(&self, hash: u64) -> Option<usize> {
        if !self.sketch.maybe_contains(hash) {
            return None;
        }
        self.entries.get(&hash).map(|e| e.replica)
    }

    fn admit(&mut self, hash: u64, entry: DirEntry) {
        while self.entries.len() >= self.cap {
            match self.order.pop_front() {
                Some(old) => {
                    if self.entries.remove(&old).is_some() {
                        self.sketch.remove(old);
                        self.evictions += 1;
                    }
                }
                None => break,
            }
        }
        self.sketch.insert(hash);
        self.entries.insert(hash, entry);
        self.order.push_back(hash);
    }

    /// Routing-time ownership registration (the successor of PR 5's
    /// `record_prefix_owner`, same semantics): a *live* owner keeps its
    /// prefix even when another replica served this request — fallback
    /// and drain are temporary and the owner's cache is still warm — but
    /// a dead replica's cache is gone, so its prefixes transfer to
    /// wherever traffic lands.  New hashes admit in admission order.
    pub fn register(&mut self, hash: u64, replica: usize, alive: &[bool]) {
        if let Some(e) = self.entries.get_mut(&hash) {
            if e.replica < alive.len() && alive[e.replica] {
                return;
            }
            e.replica = replica;
            e.tier = Tier::Device;
            return;
        }
        self.admit(
            hash,
            DirEntry {
                replica,
                tier: Tier::Device,
                hits: 0,
            },
        );
    }

    /// Apply one replica-published delta.  Idempotent (re-applying a
    /// delta is a no-op or an identical overwrite) and commutative
    /// across distinct hashes, so out-of-order snapshot drains converge.
    /// An `Evict` only removes the entry when `replica` still owns it —
    /// a replica cannot evict another's registration.
    pub fn apply(&mut self, replica: usize, d: PrefixDelta) {
        match d.kind {
            PrefixDeltaKind::CommitDevice | PrefixDeltaKind::CommitHost => {
                let tier = if d.kind == PrefixDeltaKind::CommitDevice {
                    Tier::Device
                } else {
                    Tier::Host
                };
                if let Some(e) = self.entries.get_mut(&d.hash) {
                    e.replica = replica;
                    e.tier = tier;
                } else {
                    self.admit(
                        d.hash,
                        DirEntry {
                            replica,
                            tier,
                            hits: 0,
                        },
                    );
                }
            }
            PrefixDeltaKind::Evict => {
                if self.entries.get(&d.hash).is_some_and(|e| e.replica == replica) {
                    self.entries.remove(&d.hash);
                    self.sketch.remove(d.hash);
                    // `order` keeps the stale key; admit() skips it
                }
            }
        }
    }

    /// Drop every entry owned by a replica (it died: its cache is gone).
    pub fn forget_replica(&mut self, replica: usize) {
        let dead: Vec<u64> = self
            .entries
            .iter()
            .filter(|(_, e)| e.replica == replica)
            .map(|(&h, _)| h)
            .collect();
        for h in dead {
            self.entries.remove(&h);
            self.sketch.remove(h);
        }
    }

    /// Probe for the request's *longest* registered prefix chain:
    /// deepest hash first (the whole point — a deep hit saves more
    /// prefill), sketch-gated so absent depths cost four array reads.
    /// Returns `(depth_in_blocks, replica, tier)` of the deepest hit.
    /// The chain property (block k's hash commits to all tokens before
    /// it) means a hit at depth k implies the owner held the full chain
    /// through k when it committed that block.
    pub fn probe_longest(&mut self, chain: &[u64]) -> Option<(usize, usize, Tier)> {
        for (i, &h) in chain.iter().enumerate().rev() {
            if !self.sketch.maybe_contains(h) {
                continue;
            }
            if let Some(e) = self.entries.get_mut(&h) {
                e.hits += 1;
                match e.tier {
                    Tier::Device => self.device_hits += 1,
                    Tier::Host => self.host_hits += 1,
                }
                return Some((i + 1, e.replica, e.tier));
            }
        }
        if !chain.is_empty() {
            self.misses += 1;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(hash: u64, kind: PrefixDeltaKind) -> PrefixDelta {
        PrefixDelta { hash, kind }
    }

    #[test]
    fn sketch_false_positive_rate_is_bounded() {
        let mut s = MembershipSketch::new(2048);
        for i in 0..2048u64 {
            s.insert(splitmix64(i));
        }
        for i in 0..2048u64 {
            assert!(s.maybe_contains(splitmix64(i)), "no false negatives");
        }
        let probes = 10_000u64;
        let fps = (0..probes)
            .filter(|&i| s.maybe_contains(splitmix64(0xdead_0000 + i)))
            .count();
        let rate = fps as f64 / probes as f64;
        assert!(rate < 0.05, "false-positive rate {rate:.4} out of bound");
        // removal restores definitive absence
        for i in 0..2048u64 {
            s.remove(splitmix64(i));
        }
        let stuck = (0..2048u64).filter(|&i| s.maybe_contains(splitmix64(i))).count();
        assert_eq!(stuck, 0, "counting rows must fully unwind");
    }

    #[test]
    fn probe_finds_deepest_hit_and_accounts_tiers() {
        let mut d = PrefixDirectory::new(64);
        d.apply(1, delta(10, PrefixDeltaKind::CommitDevice));
        d.apply(1, delta(11, PrefixDeltaKind::CommitHost));
        // chain [10, 11, 12]: depth-3 hash 12 unknown, depth 2 wins
        assert_eq!(d.probe_longest(&[10, 11, 12]), Some((2, 1, Tier::Host)));
        assert_eq!(d.probe_longest(&[10]), Some((1, 1, Tier::Device)));
        assert_eq!(d.probe_longest(&[99, 98]), None);
        assert_eq!((d.device_hits, d.host_hits, d.misses), (1, 1, 1));
        assert_eq!(d.entry(11).unwrap().hits, 1);
    }

    #[test]
    fn delta_apply_is_idempotent_and_commutative() {
        // idempotence: re-applying any delta leaves the same state
        let mut d = PrefixDirectory::new(64);
        d.apply(0, delta(7, PrefixDeltaKind::CommitDevice));
        d.apply(0, delta(7, PrefixDeltaKind::CommitDevice));
        assert_eq!(d.len(), 1);
        assert_eq!(d.probe_longest(&[7]), Some((1, 0, Tier::Device)));
        d.apply(0, delta(7, PrefixDeltaKind::Evict));
        d.apply(0, delta(7, PrefixDeltaKind::Evict));
        assert_eq!(d.len(), 0);
        assert_eq!(d.probe_longest(&[7]), None, "sketch unwound with the entry");
        // commutativity across distinct hashes: both orders converge
        let mut a = PrefixDirectory::new(64);
        let mut b = PrefixDirectory::new(64);
        let ops = [
            (0usize, delta(1, PrefixDeltaKind::CommitDevice)),
            (1usize, delta(2, PrefixDeltaKind::CommitHost)),
            (0usize, delta(3, PrefixDeltaKind::CommitDevice)),
            (0usize, delta(3, PrefixDeltaKind::Evict)),
        ];
        for &(r, dl) in &ops {
            a.apply(r, dl);
        }
        for &(r, dl) in ops.iter().rev() {
            b.apply(r, dl);
        }
        for h in 1..=3u64 {
            assert_eq!(
                a.entries.get(&h).map(|e| (e.replica, e.tier)),
                b.entries.get(&h).map(|e| (e.replica, e.tier)),
                "hash {h} diverged across apply orders"
            );
        }
        // a foreign replica's evict cannot remove the owner's entry
        let mut d = PrefixDirectory::new(64);
        d.apply(2, delta(5, PrefixDeltaKind::CommitDevice));
        d.apply(3, delta(5, PrefixDeltaKind::Evict));
        assert_eq!(d.probe_longest(&[5]), Some((1, 2, Tier::Device)));
    }

    #[test]
    fn eviction_is_admission_ordered_without_a_cliff() {
        let cap = 32;
        let mut d = PrefixDirectory::new(cap);
        for h in 0..cap as u64 {
            d.register(h, 0, &[true]);
        }
        assert_eq!(d.len(), cap);
        // each admission past capacity evicts exactly the oldest entry —
        // the map never resets, so occupancy stays pinned at cap
        for h in cap as u64..(2 * cap) as u64 {
            d.register(h, 0, &[true]);
            assert_eq!(d.len(), cap, "no reset-at-cap cliff");
            assert!(d.entries.contains_key(&h), "fresh admission present");
            let oldest_surviving = h - cap as u64 + 1;
            assert!(
                !d.entries.contains_key(&(oldest_surviving - 1)),
                "oldest admission evicted first"
            );
            assert!(
                !d.sketch.maybe_contains(oldest_surviving - 1)
                    || d.entries.contains_key(&(oldest_surviving - 1)),
                "sketch stays in sync modulo false positives"
            );
        }
        assert_eq!(d.evictions, cap as u64);
    }

    #[test]
    fn register_keeps_live_owner_and_transfers_from_dead() {
        let mut d = PrefixDirectory::new(64);
        d.register(7, 0, &[true, true]);
        // a live owner keeps its prefix even when another replica served
        // this request (fallback/drain are temporary, its cache is warm)
        d.register(7, 1, &[true, true]);
        assert_eq!(d.owner_of(7), Some(0));
        // a dead owner's cache is gone: ownership transfers
        d.register(7, 1, &[false, true]);
        assert_eq!(d.owner_of(7), Some(1));
        // new prefixes insert normally
        d.register(9, 0, &[false, true]);
        assert_eq!(d.owner_of(9), Some(0));
        // forgetting a dead replica drops all of its entries
        d.forget_replica(1);
        assert_eq!(d.owner_of(7), None);
        assert_eq!(d.owner_of(9), Some(0));
    }
}
