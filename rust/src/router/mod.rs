//! Multi-replica serving: a load- and prefix-aware router in front of N
//! engines — the first subsystem *above* the engine, and the step from
//! one engine thread toward the million-user north star.
//!
//! Every optimization below this layer (Opt-KV tiering, Opt-Pa chunked
//! prefill, adaptive speculation) is per-engine; the next order of
//! magnitude is horizontal: N replicas, each with its own scheduler, KV
//! cache, and tier manager, behind one front-end.  Where multi-instance
//! throughput is won or lost is *placement* (arXiv:2603.20397,
//! arXiv:2604.05012): cache-oblivious replication scatters reusable
//! prefixes and stacks heavy requests, so the router routes on the
//! per-replica signals the engines already export.
//!
//! Three policies ([`RouterPolicy`]):
//!
//! * `round_robin` — the load-blind baseline;
//! * `least_loaded` — lowest [`load_score`]: estimated outstanding
//!   tokens + queue depth, discounted by the replica's measured service
//!   speed (`tokens_per_step`, `spec_regime` gauges) and inflated by KV
//!   pressure (free device/host blocks from the tier stats);
//! * `prefix_affinity` — hash the prompt's leading full KV block with
//!   the prefix-sharing index's own hash
//!   ([`crate::kvcache::leading_prefix_hash`]) and prefer the replica
//!   that already holds it (its paged cache will serve the shared
//!   system-prompt blocks as prefix hits instead of re-prefilling
//!   them).  When following affinity would push the cross-replica load
//!   imbalance ([`crate::platform::replica_imbalance`]) above the cost
//!   model's threshold
//!   ([`crate::platform::CostModel::affinity_imbalance_threshold`]),
//!   the request falls back to least-loaded — one hot prefix cannot
//!   wedge a replica.  Ownership stays with the original replica (the
//!   fallback copy is a one-off), so affinity re-forms once the skew
//!   drains.
//!
//! Two drivers share the policy code: [`Router`] owns N [`Engine`]s
//! directly and runs them synchronously (benches/tests — fully
//! deterministic), and [`RouterHandle`] owns N
//! [`EngineHandle`] threads for the HTTP server, reading each replica's
//! atomically-published [`MetricsSnapshot`] for live load signals and
//! aggregating `GET /metrics` into cluster + per-replica views.
//! Per-replica drain (`/admin/drain`) takes a replica out of rotation
//! without killing in-flight work; health is the engine thread's
//! liveness.  N = 1 degenerates to the single-engine path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::config::RouterPolicy;
use crate::coordinator::{Engine, GenRequest, GenResult};
use crate::kvcache::{leading_prefix_hash, SeqId};
use crate::platform::{replica_imbalance, CostModel};
use crate::runtime::Backend;
use crate::server::{EngineHandle, MetricsSnapshot};
use crate::tokenizer::Tokenizer;
use crate::util::json::{self, Object, Value};

// ---------------------------------------------------------------------------
// policy core (shared by the sync and threaded drivers)
// ---------------------------------------------------------------------------

/// A replica's load signals at routing time, assembled from the router's
/// own accounting (queue depth, outstanding-token estimates) and the
/// engine's exported gauges (`/metrics` tier stats, `tokens_per_step`,
/// `spec_regime`).
#[derive(Debug, Clone)]
pub struct ReplicaLoad {
    /// requests routed here and not yet finished
    pub queue_depth: usize,
    /// estimated tokens still to serve ([`request_cost_estimate`] sums)
    pub outstanding_tokens: f64,
    pub free_device_blocks: usize,
    pub total_device_blocks: usize,
    pub free_host_blocks: usize,
    /// tokens committed per decode/verify round (0 while idle)
    pub tokens_per_step: f64,
    /// the replica's last decode batch was GEMM-bound (no speculation
    /// credit: extra load will not be amortized away)
    pub gemm_bound: bool,
    pub draining: bool,
    pub healthy: bool,
}

impl ReplicaLoad {
    /// An idle, healthy replica (unit-test scaffolding).
    pub fn idle() -> Self {
        ReplicaLoad {
            queue_depth: 0,
            outstanding_tokens: 0.0,
            free_device_blocks: 0,
            total_device_blocks: 0,
            free_host_blocks: 0,
            tokens_per_step: 0.0,
            gemm_bound: false,
            draining: false,
            healthy: true,
        }
    }
}

/// Estimated serving cost of a request, in decode-token equivalents.
/// Decode dominates: each generated token costs roughly one shared
/// weight-stream round divided by the batch width, while a prefill token
/// amortizes the same stream across the whole window — the 5x factor is
/// that ratio at the default geometry's operating point.
pub fn request_cost_estimate(prompt_tokens: usize, max_new_tokens: usize) -> f64 {
    prompt_tokens as f64 + 5.0 * max_new_tokens as f64
}

/// The least-loaded policy's score (lower = preferred).  Backlog in
/// token-equivalents, discounted by measured service speed, inflated by
/// KV pressure: a nearly-full device pool will preempt or swap on
/// admission, and host-tier headroom only half-relieves that (the blocks
/// still round-trip over PCIe).
pub fn load_score(l: &ReplicaLoad) -> f64 {
    let backlog = l.outstanding_tokens + 4.0 * l.queue_depth as f64;
    // service-speed discount: a replica whose verify rounds commit s
    // tokens/round drains its backlog s× faster.  tokens_per_step is a
    // run-cumulative average, so the credit is capped at 2x — a stale
    // speculation-era high cannot indefinitely hide a since-demoted
    // replica's true 1x service rate
    let speed = if l.gemm_bound {
        1.0
    } else {
        l.tokens_per_step.clamp(1.0, 2.0)
    };
    let pressure = if l.total_device_blocks > 0 {
        let free = l.free_device_blocks as f64 + 0.5 * l.free_host_blocks as f64;
        (1.0 - (free / l.total_device_blocks as f64).min(1.0)).max(0.0)
    } else {
        0.0
    };
    backlog / speed * (1.0 + pressure)
}

fn least_loaded_of(eligible: &[usize], loads: &[ReplicaLoad]) -> usize {
    let mut best = eligible[0];
    let mut best_score = load_score(&loads[best]);
    for &i in &eligible[1..] {
        let s = load_score(&loads[i]);
        if s < best_score {
            best = i;
            best_score = s;
        }
    }
    best
}

/// Upper bound on remembered prefix owners: at capacity the map resets
/// (affinity re-forms from live traffic) rather than growing without
/// bound across a long-lived serve process, where every distinct
/// block-length prompt would otherwise add an entry forever.
const PREFIX_OWNER_CAP: usize = 65_536;

/// Record `replica` as the prefix owner when the prefix is new, or take
/// ownership over from a *dead* replica.  A live owner keeps the prefix
/// even when it lost this request to the imbalance fallback or a drain
/// (both are temporary and its cache is still warm); a crashed replica's
/// cache is gone, so its prefixes transfer to wherever traffic lands.
fn record_prefix_owner(
    owners: &mut HashMap<u64, usize>,
    hash: u64,
    replica: usize,
    loads: &[ReplicaLoad],
) {
    if let Some(&o) = owners.get(&hash) {
        if o < loads.len() && loads[o].healthy {
            return;
        }
    }
    if owners.len() >= PREFIX_OWNER_CAP && !owners.contains_key(&hash) {
        owners.clear();
    }
    owners.insert(hash, replica);
}

/// Shared by both drivers so the bench/test [`Router`] and the serving
/// [`RouterHandle`] always derive the affinity fallback threshold the
/// same way (same ShareGPT ctx-scale operating point as the engine's
/// own cost model).
fn affinity_threshold_for<B: Backend>(backend: &B) -> f64 {
    CostModel::for_preset(backend.preset(), backend.geometry().block_size)
        .with_ctx_scale(8.0)
        .affinity_imbalance_threshold(backend.opt())
}

/// Pick the replica for one request.  `prefix` is the prompt's affinity
/// key ([`leading_prefix_hash`]), `incoming_cost` its
/// [`request_cost_estimate`]; `rr_next` is the round-robin cursor.
/// Returns `None` when no replica is routable (all draining/dead).
pub fn pick_replica(
    policy: RouterPolicy,
    loads: &[ReplicaLoad],
    prefix: Option<u64>,
    prefix_owner: &HashMap<u64, usize>,
    rr_next: &mut usize,
    incoming_cost: f64,
    affinity_threshold: f64,
) -> Option<usize> {
    let eligible: Vec<usize> = (0..loads.len())
        .filter(|&i| loads[i].healthy && !loads[i].draining)
        .collect();
    if eligible.is_empty() {
        return None;
    }
    match policy {
        RouterPolicy::RoundRobin => {
            for _ in 0..loads.len() {
                let i = *rr_next % loads.len();
                *rr_next = rr_next.wrapping_add(1);
                if loads[i].healthy && !loads[i].draining {
                    return Some(i);
                }
            }
            Some(eligible[0])
        }
        RouterPolicy::LeastLoaded => Some(least_loaded_of(&eligible, loads)),
        RouterPolicy::PrefixAffinity => {
            if let Some(h) = prefix {
                if let Some(&owner) = prefix_owner.get(&h) {
                    if owner < loads.len() && loads[owner].healthy && !loads[owner].draining {
                        // would honoring affinity skew the cluster past
                        // the cost model's break-even?  Project the
                        // owner's score with the incoming request's
                        // tokens added to its backlog — through the same
                        // speed/pressure model as everyone else's score,
                        // so a fast (speculating) owner is not penalized
                        // by raw token units
                        let mut projected = loads[owner].clone();
                        projected.outstanding_tokens += incoming_cost;
                        let backlog: Vec<f64> = eligible
                            .iter()
                            .map(|&i| {
                                if i == owner {
                                    load_score(&projected)
                                } else {
                                    load_score(&loads[i])
                                }
                            })
                            .collect();
                        if replica_imbalance(&backlog) <= affinity_threshold {
                            return Some(owner);
                        }
                    }
                }
            }
            Some(least_loaded_of(&eligible, loads))
        }
    }
}

// ---------------------------------------------------------------------------
// synchronous driver (benches/tests)
// ---------------------------------------------------------------------------

/// One routed request's outcome.
#[derive(Debug, Clone)]
pub struct RoutedResult {
    pub replica: usize,
    pub result: GenResult,
}

/// Synchronous N-replica cluster: owns the engines, routes at submit
/// time, runs each replica to completion.  Fully deterministic — the
/// bench/test driver (the HTTP path uses [`RouterHandle`]).
pub struct Router<B: Backend> {
    replicas: Vec<Engine<B>>,
    policy: RouterPolicy,
    tokenizer: Tokenizer,
    block_size: usize,
    affinity_threshold: f64,
    rr_next: usize,
    prefix_owner: HashMap<u64, usize>,
    outstanding: Vec<f64>,
    draining: Vec<bool>,
    /// (replica, seq id) per submission, in submission order
    routed: Vec<(usize, SeqId)>,
}

impl<B: Backend> Router<B> {
    pub fn new(replicas: Vec<Engine<B>>, policy: RouterPolicy) -> Self {
        assert!(!replicas.is_empty(), "router needs at least one replica");
        let geometry = *replicas[0].backend.geometry();
        let affinity_threshold = affinity_threshold_for(&replicas[0].backend);
        let n = replicas.len();
        Router {
            replicas,
            policy,
            tokenizer: Tokenizer::new(),
            block_size: geometry.block_size,
            affinity_threshold,
            rr_next: 0,
            prefix_owner: HashMap::new(),
            outstanding: vec![0.0; n],
            draining: vec![false; n],
            routed: Vec::new(),
        }
    }

    /// Override the prefix-affinity fallback threshold (tests).
    pub fn with_affinity_threshold(mut self, t: f64) -> Self {
        self.affinity_threshold = t;
        self
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    pub fn replicas(&self) -> &[Engine<B>] {
        &self.replicas
    }

    pub fn set_draining(&mut self, replica: usize, draining: bool) {
        self.draining[replica] = draining;
    }

    /// Live load view of every replica (engine state + router estimates).
    pub fn loads(&self) -> Vec<ReplicaLoad> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let s = e.load_signals();
                ReplicaLoad {
                    queue_depth: s.pending,
                    outstanding_tokens: self.outstanding[i],
                    free_device_blocks: s.free_device_blocks,
                    total_device_blocks: s.total_device_blocks,
                    free_host_blocks: s.free_host_blocks,
                    tokens_per_step: s.tokens_per_step,
                    gemm_bound: s.gemm_bound,
                    draining: self.draining[i],
                    healthy: true,
                }
            })
            .collect()
    }

    /// Route and submit one request; returns (replica, sequence id).
    pub fn submit(&mut self, req: GenRequest) -> Result<(usize, SeqId)> {
        // round-robin reads neither the cost estimate nor the prefix
        // key, so it skips the router-side tokenization entirely
        let (cost, prefix) = match self.policy {
            RouterPolicy::RoundRobin => (0.0, None),
            _ => {
                let tokens = self.tokenizer.encode(&req.prompt, true, false);
                let prefix = if self.policy == RouterPolicy::PrefixAffinity {
                    leading_prefix_hash(&tokens, self.block_size)
                } else {
                    None
                };
                (
                    request_cost_estimate(tokens.len(), req.max_new_tokens),
                    prefix,
                )
            }
        };
        let loads = self.loads();
        let choice = pick_replica(
            self.policy,
            &loads,
            prefix,
            &self.prefix_owner,
            &mut self.rr_next,
            cost,
            self.affinity_threshold,
        )
        .ok_or_else(|| anyhow!("no routable replica (all draining)"))?;
        if let Some(h) = prefix {
            record_prefix_owner(&mut self.prefix_owner, h, choice, &loads);
        }
        let id = self.replicas[choice].submit(req)?;
        self.outstanding[choice] += cost;
        self.routed.push((choice, id));
        Ok((choice, id))
    }

    /// Drive every replica to completion; results come back in
    /// submission order (replicas are independent, so running them in
    /// sequence leaves each one's simulated-clock metrics untouched).
    pub fn run_to_completion(&mut self) -> Result<Vec<RoutedResult>> {
        let mut by_key: HashMap<(usize, SeqId), GenResult> = HashMap::new();
        for (i, engine) in self.replicas.iter_mut().enumerate() {
            for r in engine.run_to_completion()? {
                by_key.insert((i, r.id), r);
            }
            self.outstanding[i] = 0.0;
        }
        std::mem::take(&mut self.routed)
            .into_iter()
            .map(|(replica, id)| {
                by_key
                    .remove(&(replica, id))
                    .map(|result| RoutedResult { replica, result })
                    .ok_or_else(|| anyhow!("replica {replica} lost sequence {id}"))
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// threaded driver (HTTP serving)
// ---------------------------------------------------------------------------

/// A replica's routing status (the `/health` per-replica view).
#[derive(Debug, Clone)]
pub struct ReplicaStatus {
    pub replica: usize,
    pub healthy: bool,
    pub draining: bool,
    pub in_flight: usize,
}

struct RouterReplica {
    handle: EngineHandle,
    in_flight: AtomicUsize,
    draining: AtomicBool,
}

struct RouteState {
    rr_next: usize,
    prefix_owner: HashMap<u64, usize>,
    outstanding: Vec<f64>,
}

/// Cluster keys summed across replica snapshots for the aggregated
/// `GET /metrics` view (counters and capacities only — gauges are
/// reported per replica and as spreads, never summed).
const CLUSTER_SUM_KEYS: &[&str] = &[
    "requests_finished",
    "tokens_generated",
    "prefill_steps",
    "prefill_chunks",
    "decode_steps",
    "preemptions",
    "spec_rounds",
    "spec_drafted",
    "spec_accepted",
    "swap_outs",
    "swap_ins",
    "prefetch_hits",
    "prefetch_misses",
    "tokens_recomputed",
    "recompute_avoided_tokens",
    "cache_blocks_total",
    "cache_blocks_used",
    "cache_prefix_hits",
    "host_pool_blocks",
    "host_blocks_used",
    "swapped_seqs",
];

/// Threaded N-replica front-end: each replica is an [`EngineHandle`]
/// thread; routing reads the replicas' atomically-published snapshots
/// plus the router's own in-flight accounting.  The [`crate::server`]
/// HTTP layer serves through this.
pub struct RouterHandle {
    replicas: Vec<RouterReplica>,
    policy: RouterPolicy,
    tokenizer: Tokenizer,
    block_size: usize,
    affinity_threshold: f64,
    state: Mutex<RouteState>,
}

impl RouterHandle {
    /// Spawn one engine thread per replica.
    pub fn spawn<B: Backend + Send + 'static>(
        engines: Vec<Engine<B>>,
        policy: RouterPolicy,
    ) -> Self {
        assert!(!engines.is_empty(), "router needs at least one replica");
        let geometry = *engines[0].backend.geometry();
        let affinity_threshold = affinity_threshold_for(&engines[0].backend);
        let n = engines.len();
        RouterHandle {
            replicas: engines
                .into_iter()
                .map(|e| RouterReplica {
                    handle: EngineHandle::spawn(e),
                    in_flight: AtomicUsize::new(0),
                    draining: AtomicBool::new(false),
                })
                .collect(),
            policy,
            tokenizer: Tokenizer::new(),
            block_size: geometry.block_size,
            affinity_threshold,
            state: Mutex::new(RouteState {
                rr_next: 0,
                prefix_owner: HashMap::new(),
                outstanding: vec![0.0; n],
            }),
        }
    }

    /// Wrap an already-spawned single engine: the N = 1 special case the
    /// one-replica [`crate::server::Server::bind`] path uses (every
    /// policy is the identity there, so no cost model is consulted).
    pub fn single(handle: EngineHandle) -> Self {
        RouterHandle {
            replicas: vec![RouterReplica {
                handle,
                in_flight: AtomicUsize::new(0),
                draining: AtomicBool::new(false),
            }],
            policy: RouterPolicy::RoundRobin,
            tokenizer: Tokenizer::new(),
            block_size: 16,
            affinity_threshold: 1.0,
            state: Mutex::new(RouteState {
                rr_next: 0,
                prefix_owner: HashMap::new(),
                outstanding: vec![0.0],
            }),
        }
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Take a replica out of rotation (or put it back).  In-flight
    /// requests finish; only new placements are affected.
    pub fn set_draining(&self, replica: usize, draining: bool) -> Result<()> {
        let r = self.replicas.get(replica).ok_or_else(|| {
            anyhow!(
                "no replica {replica} (cluster has {})",
                self.replicas.len()
            )
        })?;
        r.draining.store(draining, Ordering::Relaxed);
        Ok(())
    }

    pub fn status(&self) -> Vec<ReplicaStatus> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaStatus {
                replica: i,
                healthy: r.handle.is_alive(),
                draining: r.draining.load(Ordering::Relaxed),
                in_flight: r.in_flight.load(Ordering::Relaxed),
            })
            .collect()
    }

    fn loads(&self, outstanding: &[f64]) -> Vec<ReplicaLoad> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let snap = r.handle.snapshot();
                ReplicaLoad {
                    // the snapshot's pending lags by up to a step; the
                    // router's own dispatch counter never does
                    queue_depth: r.in_flight.load(Ordering::Relaxed).max(snap.pending),
                    outstanding_tokens: outstanding[i],
                    free_device_blocks: snap.free_device_blocks,
                    total_device_blocks: snap.total_device_blocks,
                    free_host_blocks: snap.free_host_blocks,
                    tokens_per_step: snap.tokens_per_step,
                    gemm_bound: snap.gemm_bound,
                    draining: r.draining.load(Ordering::Relaxed),
                    healthy: r.handle.is_alive(),
                }
            })
            .collect()
    }

    /// Route one request and generate through the chosen replica
    /// (blocking, like [`EngineHandle::generate`]).
    pub fn generate(&self, req: GenRequest) -> Result<GenResult> {
        // round-robin reads neither the cost estimate nor the prefix
        // key, so it skips the router-side tokenization entirely
        let (cost, prefix) = match self.policy {
            RouterPolicy::RoundRobin => (0.0, None),
            _ => {
                let tokens = self.tokenizer.encode(&req.prompt, true, false);
                let prefix = if self.policy == RouterPolicy::PrefixAffinity {
                    leading_prefix_hash(&tokens, self.block_size)
                } else {
                    None
                };
                (
                    request_cost_estimate(tokens.len(), req.max_new_tokens),
                    prefix,
                )
            }
        };
        let choice = {
            let mut guard = self.state.lock().unwrap();
            let st = &mut *guard;
            let loads = self.loads(&st.outstanding);
            let Some(c) = pick_replica(
                self.policy,
                &loads,
                prefix,
                &st.prefix_owner,
                &mut st.rr_next,
                cost,
                self.affinity_threshold,
            ) else {
                bail!("no routable replica (all draining or dead)");
            };
            if let Some(h) = prefix {
                record_prefix_owner(&mut st.prefix_owner, h, c, &loads);
            }
            st.outstanding[c] += cost;
            c
        };
        self.replicas[choice].in_flight.fetch_add(1, Ordering::Relaxed);
        let result = self.replicas[choice].handle.generate(req);
        self.replicas[choice].in_flight.fetch_sub(1, Ordering::Relaxed);
        if let Ok(mut st) = self.state.lock() {
            st.outstanding[choice] = (st.outstanding[choice] - cost).max(0.0);
        }
        result
    }

    /// The `GET /metrics` payload: for N = 1 the single replica's
    /// snapshot verbatim (existing scrapers keep working); for N > 1 a
    /// cluster aggregate of the counter keys plus gauge spreads.  Either
    /// way a `replicas` array carries each replica's full snapshot
    /// stamped with its step sequence number — each snapshot is an
    /// atomically-swapped Arc, so no per-replica view is ever torn.
    pub fn metrics_json(&self) -> String {
        let snaps: Vec<Arc<MetricsSnapshot>> =
            self.replicas.iter().map(|r| r.handle.snapshot()).collect();
        let parsed: Vec<Value> = snaps
            .iter()
            .map(|s| json::parse(&s.json).unwrap_or(Value::Null))
            .collect();
        let mut top = if parsed.len() == 1 {
            match &parsed[0] {
                Value::Object(o) => o.clone(),
                _ => Object::new(),
            }
        } else {
            cluster_aggregate(&parsed)
        };
        top.insert("num_replicas", self.replicas.len());
        top.insert("router_policy", self.policy.name());
        let reps: Vec<Value> = parsed
            .into_iter()
            .zip(snaps.iter())
            .zip(self.status())
            .map(|((v, snap), st)| {
                let mut o = Object::new();
                o.insert("replica", st.replica);
                o.insert("seq", snap.seq as usize);
                o.insert("healthy", st.healthy);
                o.insert("draining", st.draining);
                o.insert("in_flight", st.in_flight);
                o.insert("pending", snap.pending);
                o.insert("metrics", v);
                Value::Object(o)
            })
            .collect();
        top.insert("replicas", Value::Array(reps));
        Value::Object(top).to_string()
    }
}

fn cluster_aggregate(parsed: &[Value]) -> Object {
    let mut o = Object::new();
    for key in CLUSTER_SUM_KEYS {
        let total: f64 = parsed
            .iter()
            .filter_map(|v| v.get(key).and_then(|x| x.as_f64()))
            .sum();
        o.insert(*key, total as usize);
    }
    let gauges = |key: &str| -> Vec<f64> {
        parsed
            .iter()
            .filter_map(|v| v.get(key).and_then(|x| x.as_f64()))
            .collect()
    };
    let occ = gauges("decode_batch_occupancy");
    if !occ.is_empty() {
        o.insert(
            "decode_batch_occupancy_mean",
            occ.iter().sum::<f64>() / occ.len() as f64,
        );
        // how evenly the decode batches fill across replicas — the
        // router's balance report card
        o.insert("replica_occupancy_spread", replica_imbalance(&occ));
    }
    let tps = gauges("tokens_per_step");
    if !tps.is_empty() {
        o.insert(
            "tokens_per_step_mean",
            tps.iter().sum::<f64>() / tps.len() as f64,
        );
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, COOPT};
    use crate::runtime::mock::MockBackend;

    fn loads(n: usize) -> Vec<ReplicaLoad> {
        (0..n).map(|_| ReplicaLoad::idle()).collect()
    }

    fn pick(
        policy: RouterPolicy,
        ls: &[ReplicaLoad],
        prefix: Option<u64>,
        owners: &HashMap<u64, usize>,
        rr: &mut usize,
        cost: f64,
        thr: f64,
    ) -> Option<usize> {
        pick_replica(policy, ls, prefix, owners, rr, cost, thr)
    }

    #[test]
    fn round_robin_cycles_and_skips_drained() {
        let mut ls = loads(3);
        let owners = HashMap::new();
        let mut rr = 0;
        let picks: Vec<usize> = (0..6)
            .map(|_| {
                pick(RouterPolicy::RoundRobin, &ls, None, &owners, &mut rr, 10.0, 1.0).unwrap()
            })
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        ls[1].draining = true;
        let picks: Vec<usize> = (0..4)
            .map(|_| {
                pick(RouterPolicy::RoundRobin, &ls, None, &owners, &mut rr, 10.0, 1.0).unwrap()
            })
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "drained replica skipped");
        ls[0].draining = true;
        ls[2].healthy = false;
        assert_eq!(
            pick(RouterPolicy::RoundRobin, &ls, None, &owners, &mut rr, 10.0, 1.0),
            None,
            "nothing routable"
        );
    }

    #[test]
    fn least_loaded_scores_backlog_speed_and_pressure() {
        let mut ls = loads(3);
        ls[0].outstanding_tokens = 100.0;
        ls[1].outstanding_tokens = 40.0;
        ls[2].outstanding_tokens = 60.0;
        let owners = HashMap::new();
        let mut rr = 0;
        assert_eq!(
            pick(RouterPolicy::LeastLoaded, &ls, None, &owners, &mut rr, 1.0, 1.0),
            Some(1)
        );
        // a speculating replica drains its backlog faster (credit capped
        // at 2x: the gauge is a run-cumulative average)...
        ls[0].tokens_per_step = 3.0;
        assert!((load_score(&ls[0]) - 50.0).abs() < 1e-9, "100 tokens at capped 2x");
        assert!(load_score(&ls[0]) < load_score(&ls[2]));
        ls[0].tokens_per_step = 10.0;
        assert!((load_score(&ls[0]) - 50.0).abs() < 1e-9, "credit stays capped");
        // ...unless it is GEMM-bound (no amortization left)
        ls[0].gemm_bound = true;
        assert!(load_score(&ls[0]) > load_score(&ls[2]));
        // KV pressure inflates the score; host headroom relieves it
        let mut full = ReplicaLoad::idle();
        full.outstanding_tokens = 40.0;
        full.total_device_blocks = 96;
        full.free_device_blocks = 0;
        assert!(load_score(&full) > load_score(&ls[1]));
        full.free_host_blocks = 192;
        assert!((load_score(&full) - load_score(&ls[1])).abs() < 1e-9);
        // ties break to the lowest index
        let even = loads(3);
        assert_eq!(
            pick(RouterPolicy::LeastLoaded, &even, None, &owners, &mut rr, 1.0, 1.0),
            Some(0)
        );
    }

    #[test]
    fn prefix_affinity_prefers_owner_until_imbalance() {
        let mut ls = loads(2);
        let mut owners = HashMap::new();
        owners.insert(7u64, 1usize);
        let mut rr = 0;
        // balanced: honor affinity
        assert_eq!(
            pick(RouterPolicy::PrefixAffinity, &ls, Some(7), &owners, &mut rr, 10.0, 1.0),
            Some(1)
        );
        // unknown prefix: fall through to least-loaded
        ls[0].outstanding_tokens = 50.0;
        assert_eq!(
            pick(RouterPolicy::PrefixAffinity, &ls, Some(9), &owners, &mut rr, 10.0, 1.0),
            Some(1)
        );
        // owner badly behind the rest: the incoming request would push
        // (max-min)/mean past the threshold -> fall back to least-loaded
        ls[0].outstanding_tokens = 0.0;
        ls[1].outstanding_tokens = 300.0;
        assert_eq!(
            pick(RouterPolicy::PrefixAffinity, &ls, Some(7), &owners, &mut rr, 10.0, 1.0),
            Some(0),
            "hot prefix must not wedge its replica"
        );
        // a drained owner also falls back
        ls[1].outstanding_tokens = 0.0;
        ls[1].draining = true;
        assert_eq!(
            pick(RouterPolicy::PrefixAffinity, &ls, Some(7), &owners, &mut rr, 10.0, 1.0),
            Some(0)
        );
        // N = 1 degeneracy: imbalance is always 0, affinity always holds
        let one = loads(1);
        let mut owners1 = HashMap::new();
        owners1.insert(7u64, 0usize);
        for policy in RouterPolicy::ALL {
            assert_eq!(
                pick(policy, &one, Some(7), &owners1, &mut rr, 10.0, 0.25),
                Some(0)
            );
        }
    }

    #[test]
    fn dead_owner_transfers_prefix_ownership() {
        let mut owners = HashMap::new();
        let mut ls = loads(2);
        owners.insert(7u64, 0usize);
        // a live owner keeps its prefix even when another replica served
        // this request (fallback/drain are temporary, its cache is warm)
        record_prefix_owner(&mut owners, 7, 1, &ls);
        assert_eq!(owners[&7], 0);
        // a dead owner's cache is gone: ownership transfers
        ls[0].healthy = false;
        record_prefix_owner(&mut owners, 7, 1, &ls);
        assert_eq!(owners[&7], 1);
        // new prefixes insert normally
        record_prefix_owner(&mut owners, 9, 0, &ls);
        assert_eq!(owners[&9], 0);
    }

    fn mock_engine() -> Engine<MockBackend> {
        Engine::new(
            MockBackend::new().with_opt(COOPT),
            EngineConfig::new("llama-7b-sim", COOPT),
        )
    }

    #[test]
    fn sync_router_routes_runs_and_orders_results() {
        let mut router = Router::new(vec![mock_engine(), mock_engine()], RouterPolicy::RoundRobin);
        assert_eq!(router.num_replicas(), 2);
        let mut picks = Vec::new();
        for i in 0..4 {
            let (rep, _) = router
                .submit(GenRequest::greedy(format!("routed prompt {i}"), 4))
                .unwrap();
            picks.push(rep);
        }
        assert_eq!(picks, vec![0, 1, 0, 1]);
        let results = router.run_to_completion().unwrap();
        assert_eq!(results.len(), 4);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.replica, i % 2, "results in submission order");
            assert_eq!(r.result.generated_tokens, 4);
        }
        // draining replica 0 steers everything to 1
        router.set_draining(0, true);
        let (rep, _) = router
            .submit(GenRequest::greedy("after drain", 2))
            .unwrap();
        assert_eq!(rep, 1);
        router.set_draining(1, true);
        assert!(router.submit(GenRequest::greedy("nowhere", 2)).is_err());
        router.set_draining(1, false);
        router.run_to_completion().unwrap();
    }

    #[test]
    fn sync_router_outputs_match_single_engine() {
        let reqs: Vec<GenRequest> = (0..6)
            .map(|i| GenRequest::greedy(format!("same output prompt {i} {}", "x".repeat(i)), 5))
            .collect();
        let mut single = mock_engine();
        let base = single.generate(reqs.clone()).unwrap();
        for policy in RouterPolicy::ALL {
            let mut router = Router::new(vec![mock_engine(), mock_engine(), mock_engine()], policy);
            for r in &reqs {
                router.submit(r.clone()).unwrap();
            }
            let got = router.run_to_completion().unwrap();
            assert_eq!(base.len(), got.len());
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.tokens, b.result.tokens, "{}", policy.name());
                assert_eq!(a.finish, b.result.finish);
            }
        }
    }

    #[test]
    fn prefix_affinity_colocates_tenants_and_wins_prefix_hits() {
        // two tenants with multi-block shared system prompts, arriving in
        // an uneven order (round-robin's index parity scatters each
        // tenant across both replicas; affinity must not)
        let tenants = [0usize, 0, 1, 0, 1, 1, 0, 1];
        let reqs: Vec<GenRequest> = tenants
            .iter()
            .enumerate()
            .map(|(i, &tenant)| {
                GenRequest::greedy(
                    format!(
                        "tenantsys{tenant} {} tail {i} {}",
                        "s".repeat(30 + tenant),
                        "y".repeat(4 + i)
                    ),
                    3,
                )
            })
            .collect();
        let hits = |policy: RouterPolicy| -> (u64, Vec<usize>) {
            // fixed threshold: with two replicas (max-min)/mean never
            // exceeds 2, so affinity is never abandoned — this test pins
            // the colocation behaviour, not the cost-model constant
            let mut router = Router::new(vec![mock_engine(), mock_engine()], policy)
                .with_affinity_threshold(4.0);
            let mut picks = Vec::new();
            for r in &reqs {
                picks.push(router.submit(r.clone()).unwrap().0);
            }
            router.run_to_completion().unwrap();
            let h = router
                .replicas()
                .iter()
                .map(|e| e.cache_stats().prefix_hits)
                .sum();
            (h, picks)
        };
        let (affinity_hits, affinity_picks) = hits(RouterPolicy::PrefixAffinity);
        let (rr_hits, rr_picks) = hits(RouterPolicy::RoundRobin);
        // affinity keeps each tenant on one replica...
        for (&tenant, &pick) in tenants.iter().zip(&affinity_picks) {
            let first = tenants.iter().position(|&t| t == tenant).unwrap();
            assert_eq!(pick, affinity_picks[first], "tenant {tenant} colocated");
        }
        // ...where round-robin splits both tenants across both replicas
        assert_eq!(rr_picks, vec![0, 1, 0, 1, 0, 1, 0, 1]);
        // and the colocated tenants reuse their shared system-prompt
        // blocks where round-robin rebuilt them
        assert!(
            affinity_hits > rr_hits,
            "affinity {affinity_hits} vs round-robin {rr_hits}"
        );
    }

    #[test]
    fn router_handle_routes_drains_and_aggregates() {
        let router = RouterHandle::spawn(
            vec![mock_engine(), mock_engine()],
            RouterPolicy::RoundRobin,
        );
        assert_eq!(router.num_replicas(), 2);
        assert_eq!(router.policy_name(), "round_robin");
        // one request per replica (round robin, sequential)
        for i in 0..2 {
            let r = router
                .generate(GenRequest::greedy(format!("handle prompt {i}"), 3))
                .unwrap();
            assert_eq!(r.generated_tokens, 3);
        }
        // drain replica 0: the next requests all land on replica 1
        router.set_draining(0, true).unwrap();
        assert!(router.set_draining(5, true).is_err());
        for i in 0..2 {
            router
                .generate(GenRequest::greedy(format!("drained era {i}"), 3))
                .unwrap();
        }
        let st = router.status();
        assert!(st[0].draining && !st[1].draining);
        assert!(st[0].healthy && st[1].healthy);
        assert_eq!(st[0].in_flight + st[1].in_flight, 0);
        // aggregated metrics: replica 0 served 3 tokens, replica 1 nine
        // (snapshots publish after the engine's next step; poll briefly)
        let mut per_replica = (0, 0);
        for _ in 0..200 {
            let v = json::parse(&router.metrics_json()).unwrap();
            assert_eq!(v.req_usize("num_replicas").unwrap(), 2);
            let reps = v.req_array("replicas").unwrap();
            let tok = |i: usize| {
                reps[i]
                    .req("metrics")
                    .and_then(|m| m.req_usize("tokens_generated"))
                    .unwrap_or(0)
            };
            per_replica = (tok(0), tok(1));
            if per_replica.0 + per_replica.1 >= 12 {
                // cluster sum matches the per-replica views
                assert_eq!(
                    v.req_usize("tokens_generated").unwrap(),
                    per_replica.0 + per_replica.1
                );
                assert!(v.req_usize("cache_blocks_total").unwrap() > 0);
                assert!(v.get("replica_occupancy_spread").is_some());
                for r in reps {
                    assert!(r.req_usize("seq").unwrap() > 0);
                    assert!(r.req_bool("healthy").unwrap());
                }
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(per_replica, (3, 9), "drain steered traffic to replica 1");
        // all drained -> no routable replica
        router.set_draining(1, true).unwrap();
        let err = router
            .generate(GenRequest::greedy("nowhere to go", 2))
            .unwrap_err();
        assert!(err.to_string().contains("no routable replica"));
        // undrain restores service
        router.set_draining(0, false).unwrap();
        let r = router
            .generate(GenRequest::greedy("back online", 2))
            .unwrap();
        assert_eq!(r.generated_tokens, 2);
    }

    #[test]
    fn router_handle_single_is_n1_special_case() {
        let handle = EngineHandle::spawn(mock_engine());
        let router = RouterHandle::single(handle);
        assert_eq!(router.num_replicas(), 1);
        let r = router.generate(GenRequest::greedy("solo", 4)).unwrap();
        assert_eq!(r.generated_tokens, 4);
        // N = 1 metrics stay flat (plus the replicas array)
        let mut seen = false;
        for _ in 0..200 {
            let v = json::parse(&router.metrics_json()).unwrap();
            if v.req_usize("tokens_generated").unwrap_or(0) >= 4 {
                assert_eq!(v.req_usize("num_replicas").unwrap(), 1);
                assert_eq!(v.req_array("replicas").unwrap().len(), 1);
                assert!(v.get("swap_outs").is_some(), "flat single-engine fields");
                seen = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(seen, "single-replica metrics never published");
    }

    #[test]
    fn request_cost_estimate_weighs_decode_heavier() {
        assert!(request_cost_estimate(10, 10) > request_cost_estimate(30, 4));
        assert_eq!(request_cost_estimate(0, 0), 0.0);
    }
}
