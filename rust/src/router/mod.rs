//! Multi-replica serving: a load- and prefix-aware router in front of N
//! engines — the first subsystem *above* the engine, and the step from
//! one engine thread toward the million-user north star.
//!
//! Every optimization below this layer (Opt-KV tiering, Opt-Pa chunked
//! prefill, adaptive speculation) is per-engine; the next order of
//! magnitude is horizontal: N replicas, each with its own scheduler, KV
//! cache, and tier manager, behind one front-end.  Where multi-instance
//! throughput is won or lost is *placement* (arXiv:2603.20397,
//! arXiv:2604.05012): cache-oblivious replication scatters reusable
//! prefixes and stacks heavy requests, so the router routes on the
//! per-replica signals the engines already export.
//!
//! Four policies ([`RouterPolicy`]):
//!
//! * `round_robin` — the load-blind baseline;
//! * `least_loaded` — lowest [`load_score`]: estimated outstanding
//!   tokens + queue depth, discounted by the replica's measured service
//!   speed (`tokens_per_step`, `spec_regime` gauges) and inflated by KV
//!   pressure (free device/host blocks from the tier stats);
//! * `prefix_affinity` — hash the prompt's leading full KV block with
//!   the prefix-sharing index's own hash
//!   ([`crate::kvcache::leading_prefix_hash`]) and prefer the replica
//!   that already holds it (its paged cache will serve the shared
//!   system-prompt blocks as prefix hits instead of re-prefilling
//!   them).  When following affinity would push the cross-replica load
//!   imbalance ([`crate::platform::replica_imbalance`]) above the cost
//!   model's threshold
//!   ([`crate::platform::CostModel::affinity_imbalance_threshold`]),
//!   the request falls back to least-loaded — one hot prefix cannot
//!   wedge a replica.  Ownership stays with the original replica (the
//!   fallback copy is a one-off), so affinity re-forms once the skew
//!   drains.
//! * `directory` — prefix affinity driven by the cluster-wide
//!   [`directory::PrefixDirectory`]: replicas publish prefix-index
//!   deltas (commit/evict/tier moves) through the snapshot channel, the
//!   router folds them into one map from *prefix-chain* hashes
//!   ([`crate::kvcache::prefix_chain_hashes`] — every complete leading
//!   block, not just the first) to `(replica, tier)`.  At admission the
//!   router probes for the request's longest registered chain; when the
//!   owner is a different replica and
//!   [`CostModel::prefix_pull_pays`] prices moving those blocks over
//!   the PCIe host tier under re-prefilling them (a device hit pays
//!   two legs, a host hit one), the destination *pulls* the blocks
//!   ([`Engine::export_prefix`] → [`Engine::pull_commit`]) before
//!   prefill starts, so prefill covers only the unmatched tail.  The
//!   directory is eventually consistent: stale entries make a pull
//!   export fewer (or zero) blocks and the destination re-prefills the
//!   difference — exact by construction, never corrupt.
//!
//! Two drivers share the policy code: [`Router`] owns N [`Engine`]s
//! directly and runs them synchronously (benches/tests — fully
//! deterministic), and [`RouterHandle`] owns N
//! [`EngineHandle`] threads for the HTTP server, reading each replica's
//! atomically-published [`MetricsSnapshot`] for live load signals and
//! aggregating `GET /metrics` into cluster + per-replica views.
//! Per-replica drain (`/admin/drain`) takes a replica out of rotation
//! without killing in-flight work; health is the engine thread's
//! liveness.  N = 1 degenerates to the single-engine path.
//!
//! **Disaggregated prefill/decode (PD).**  With
//! [`ReplicaRole`]s assigned (`--replica-roles`), placement becomes
//! phase-aware: a request whose prompt dominates its decode budget
//! starts on a `Prefill` replica when the cost model prices the
//! later KV hand-off (committed-prefix blocks over the PCIe host
//! tier) under re-prefilling it ([`handoff_pays`]) — otherwise it
//! falls back to the `Mixed` pool.  At prefill completion the
//! prefill engine parks the sequence
//! ([`Engine::take_handoff_ready`]); the router re-admits it on the
//! least-loaded decode-capable replica
//! ([`Engine::migrate_in_seq`]), waiter and all.  The sync driver
//! interleaves replica stepping with hand-off dispatch; the threaded
//! driver runs a dedicated dispatcher thread draining the cluster's
//! hand-off bus ([`crate::server::HandoffEnvelope`]).  An optional
//! autoscaler ([`RouterHandle::autoscale_tick`]) re-roles replicas
//! and rotates them in and out of the drain set from the cluster
//! queue-depth and occupancy-spread gauges.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::config::{ForecastConfig, OptConfig, ReplicaRole, ReqClass, RouterPolicy, SloConfig};
use crate::coordinator::{Engine, GenRequest, GenResult};
use crate::kvcache::{leading_prefix_hash, prefix_chain_hashes, SeqId};
use crate::obs::forecast::{ForecastPlane, ForecastStamp};
use crate::obs::LatencyHist;
use crate::platform::{replica_imbalance, CostModel};
use crate::runtime::Backend;
use crate::server::{EngineHandle, HandoffEnvelope, MetricsSnapshot};
use crate::tokenizer::Tokenizer;
use crate::util::json::{self, Object, Value};

pub mod directory;

use directory::{PrefixDirectory, Tier, DIRECTORY_CAP};

/// Longest prefix chain the router hashes per request: 32 blocks covers
/// system prompts far past the pull break-even while keeping admission
/// hashing O(1)-ish on pathological prompts.
const CHAIN_CAP: usize = 32;

// ---------------------------------------------------------------------------
// policy core (shared by the sync and threaded drivers)
// ---------------------------------------------------------------------------

/// A replica's load signals at routing time, assembled from the router's
/// own accounting (queue depth, outstanding-token estimates) and the
/// engine's exported gauges (`/metrics` tier stats, `tokens_per_step`,
/// `spec_regime`).
#[derive(Debug, Clone)]
pub struct ReplicaLoad {
    /// requests routed here and not yet finished
    pub queue_depth: usize,
    /// estimated tokens still to serve ([`request_cost_estimate`] sums)
    pub outstanding_tokens: f64,
    pub free_device_blocks: usize,
    pub total_device_blocks: usize,
    pub free_host_blocks: usize,
    /// tokens committed per decode/verify round (0 while idle)
    pub tokens_per_step: f64,
    /// the replica's last decode batch was GEMM-bound (no speculation
    /// credit: extra load will not be amortized away)
    pub gemm_bound: bool,
    pub draining: bool,
    pub healthy: bool,
}

impl ReplicaLoad {
    /// An idle, healthy replica (unit-test scaffolding).
    pub fn idle() -> Self {
        ReplicaLoad {
            queue_depth: 0,
            outstanding_tokens: 0.0,
            free_device_blocks: 0,
            total_device_blocks: 0,
            free_host_blocks: 0,
            tokens_per_step: 0.0,
            gemm_bound: false,
            draining: false,
            healthy: true,
        }
    }
}

/// Estimated serving cost of a request, in decode-token equivalents.
/// Decode dominates: each generated token costs roughly one shared
/// weight-stream round divided by the batch width, while a prefill token
/// amortizes the same stream across the whole window — the 5x factor is
/// that ratio at the default geometry's operating point.
pub fn request_cost_estimate(prompt_tokens: usize, max_new_tokens: usize) -> f64 {
    prompt_tokens as f64 + 5.0 * max_new_tokens as f64
}

/// [`request_cost_estimate`] with an optional per-tenant p90 output
/// length from the forecast plane: `max_new` is a *limit*, not a
/// prediction, and most requests stop at EOS far short of it — when the
/// tenant's length estimator is in its calibration band, the p90 caps
/// the decode term.  `None` (estimator cold, out of band, or
/// forecasting off) reproduces the unhinted estimate exactly.
pub fn request_cost_estimate_hinted(
    prompt_tokens: usize,
    max_new_tokens: usize,
    len_p90: Option<f64>,
) -> f64 {
    match len_p90 {
        Some(p90) => {
            prompt_tokens as f64 + 5.0 * (max_new_tokens as f64).min(p90.max(1.0))
        }
        None => request_cost_estimate(prompt_tokens, max_new_tokens),
    }
}

/// The least-loaded policy's score (lower = preferred).  Backlog in
/// token-equivalents, discounted by measured service speed, inflated by
/// KV pressure: a nearly-full device pool will preempt or swap on
/// admission, and host-tier headroom only half-relieves that (the blocks
/// still round-trip over PCIe).
pub fn load_score(l: &ReplicaLoad) -> f64 {
    let backlog = l.outstanding_tokens + 4.0 * l.queue_depth as f64;
    // service-speed discount: a replica whose verify rounds commit s
    // tokens/round drains its backlog s× faster.  tokens_per_step is a
    // windowed EWMA of recent rounds (not the run-cumulative average),
    // so a since-demoted replica's score tracks its true current rate
    // and the credit needs no stale-signal cap
    let speed = if l.gemm_bound {
        1.0
    } else {
        l.tokens_per_step.max(1.0)
    };
    let pressure = if l.total_device_blocks > 0 {
        let free = l.free_device_blocks as f64 + 0.5 * l.free_host_blocks as f64;
        (1.0 - (free / l.total_device_blocks as f64).min(1.0)).max(0.0)
    } else {
        0.0
    };
    backlog / speed * (1.0 + pressure)
}

// ---------------------------------------------------------------------------
// SLO admission control (shared by the sync and threaded drivers)
// ---------------------------------------------------------------------------

/// Queue-wait projection: estimated ms of queue-wait per token-equivalent
/// of the best routable replica's [`load_score`].  The sim's default
/// geometry drains roughly half a token-equivalent per wall ms at the
/// ShareGPT operating point; the constant errs high so admission sheds
/// *before* the interactive TTFT budget is spent, not at it.
pub const SLO_MS_PER_TOKEN: f64 = 2.0;

/// Projected queue-wait for a newly admitted request, in milliseconds:
/// the lowest routable [`load_score`] (the replica the request would
/// land on) read through the backlog drain rate, floored by the
/// cluster's *observed* queue-wait p95 (the PR 7 `queue_wall`
/// histogram) — the score projects forward, the histogram remembers
/// what admission optimism cost the last time.  No routable replica
/// projects an infinite wait.
pub fn projected_wait_ms(loads: &[ReplicaLoad], observed_queue_p95_s: f64) -> f64 {
    projected_wait_ms_with(loads, observed_queue_p95_s, None)
}

/// [`projected_wait_ms`] with an optional *learned* drain rate from the
/// queue-wait forecaster (ms of wait per unit of load score).  `None`
/// (forecaster cold, out of band, or forecasting off) falls back to the
/// [`SLO_MS_PER_TOKEN`] constant — bit-identical to the reactive
/// projection.  The observed-p95 floor applies either way: the
/// forecaster replaces the constant, not the memory of past queueing.
pub fn projected_wait_ms_with(
    loads: &[ReplicaLoad],
    observed_queue_p95_s: f64,
    drain_ms_per_load: Option<f64>,
) -> f64 {
    let best = loads
        .iter()
        .filter(|l| l.healthy && !l.draining)
        .map(load_score)
        .fold(f64::INFINITY, f64::min);
    if best.is_finite() {
        let ms_per = drain_ms_per_load.unwrap_or(SLO_MS_PER_TOKEN);
        (best * ms_per).max(observed_queue_p95_s * 1e3)
    } else {
        f64::INFINITY
    }
}

/// Why admission refused a request, and how long the client should back
/// off before retrying (the 429's `Retry-After`).
#[derive(Debug, Clone)]
pub struct ShedDecision {
    pub reason: &'static str,
    pub retry_after_ms: u64,
}

/// The admission controller: decide whether to shed one request, given
/// the router's per-class and per-tenant books.  Pure — both drivers
/// route their state through here so the shed rules cannot drift.
///
/// Batch work is shed when (i) the bounded batch queue is full, (ii) the
/// projected queue-wait would blow the *interactive* TTFT budget
/// (admitting more batch now is what makes interactive miss later), or
/// (iii) its tenant already holds more than its share of the
/// outstanding prefill tokens while other tenants have work in flight.
/// Interactive work is shed only as a last resort: the projected wait
/// already blows its own budget *and* there is no queued batch work
/// left to displace — so by construction no interactive request is ever
/// shed while the batch queue is nonempty.
pub fn admission_decision(
    slo: &SloConfig,
    class: &ReqClass,
    prompt_tokens: usize,
    batch_queued: usize,
    projected_wait_ms: f64,
    tenant_outstanding: f64,
    cluster_outstanding: f64,
) -> Option<ShedDecision> {
    if !slo.admission {
        return None;
    }
    let budget_ms = slo.interactive_ttft_ms as f64;
    if class.priority.is_interactive() {
        if batch_queued == 0 && projected_wait_ms > budget_ms {
            return Some(ShedDecision {
                reason: "projected wait over TTFT budget with no batch to displace",
                retry_after_ms: slo.interactive_ttft_ms,
            });
        }
        return None;
    }
    if batch_queued >= slo.max_batch_queue {
        return Some(ShedDecision {
            reason: "batch queue full",
            retry_after_ms: 2 * slo.interactive_ttft_ms,
        });
    }
    if projected_wait_ms > budget_ms {
        return Some(ShedDecision {
            reason: "projected wait would blow interactive TTFT budget",
            retry_after_ms: 2 * slo.interactive_ttft_ms,
        });
    }
    if class.tenant.is_some() {
        let cost = prompt_tokens as f64;
        let total = cluster_outstanding + cost;
        // the cap only bites while *other* tenants hold outstanding
        // work: a sole tenant saturating an idle cluster is utilization,
        // not unfairness
        if cluster_outstanding > tenant_outstanding
            && total > 0.0
            && (tenant_outstanding + cost) / total > slo.tenant_share
        {
            return Some(ShedDecision {
                reason: "tenant over outstanding-prefill share",
                retry_after_ms: slo.interactive_ttft_ms,
            });
        }
    }
    None
}

/// The admission knobs under a scored burst: while the arrival-burst
/// detector is firing *and* in band, the bounded batch queue shrinks by
/// the tighten factor so batch work sheds earlier into the arrival wave
/// (the projected-wait multiplier alone cannot act until the queue has
/// already built).  `tighten <= 1.0` — no burst, detector out of band,
/// or forecasting off — returns the knobs unchanged, so the reactive
/// path is bit-identical.
pub fn tightened_slo(slo: &SloConfig, tighten: f64) -> SloConfig {
    if tighten <= 1.0 {
        return *slo;
    }
    SloConfig {
        max_batch_queue: ((slo.max_batch_queue as f64 / tighten).ceil() as usize).max(1),
        ..*slo
    }
}

/// Marker every shed error starts with; the HTTP layer string-matches it
/// (the vendored error type has no downcast) to map sheds to 429 +
/// `Retry-After` instead of 500.
pub const SHED_MARKER: &str = "request shed";

/// Build a shed error whose message carries the class and back-off in a
/// `key=value` form the HTTP layer can parse back out for the response
/// body: `request shed (<reason>); class=<c> retry_after_ms=<n>`.
fn shed_error(class: &ReqClass, shed: &ShedDecision) -> anyhow::Error {
    anyhow!(
        "{SHED_MARKER} ({}); class={} retry_after_ms={}",
        shed.reason,
        class.priority.name(),
        shed.retry_after_ms
    )
}

/// Does this error mean the serving replica itself failed under the
/// request (thread dead, or a step fault that killed everything in
/// flight) — as opposed to a routing or admission refusal?  Replica
/// failures are the retryable class: the same request on a surviving
/// replica is expected to succeed.
fn is_replica_failure(e: &anyhow::Error) -> bool {
    let s = e.to_string();
    s.contains("engine thread gone")
        || s.contains("engine dropped the request")
        || s.contains("engine error")
}

fn least_loaded_of(eligible: &[usize], loads: &[ReplicaLoad]) -> usize {
    let mut best = eligible[0];
    let mut best_score = load_score(&loads[best]);
    for &i in &eligible[1..] {
        let s = load_score(&loads[i]);
        if s < best_score {
            best = i;
            best_score = s;
        }
    }
    best
}

/// Shared by both drivers so the bench/test [`Router`] and the serving
/// [`RouterHandle`] always derive the affinity fallback threshold the
/// same way (same ShareGPT ctx-scale operating point as the engine's
/// own cost model).
fn affinity_threshold_for<B: Backend>(backend: &B) -> f64 {
    CostModel::for_preset(backend.preset(), backend.geometry().block_size)
        .with_ctx_scale(8.0)
        .affinity_imbalance_threshold(backend.opt())
}

/// Pick the replica for one request.  `owner` is the prompt's resolved
/// affinity target — the replica the prefix-owner bookkeeping (sync
/// driver) or cluster directory (threaded driver) says already holds
/// the prompt's leading KV — and `incoming_cost` its
/// [`request_cost_estimate`]; `rr_next` is the round-robin cursor.
/// Returns `None` when no replica is routable (all draining/dead).
pub fn pick_replica(
    policy: RouterPolicy,
    loads: &[ReplicaLoad],
    owner: Option<usize>,
    rr_next: &mut usize,
    incoming_cost: f64,
    affinity_threshold: f64,
) -> Option<usize> {
    let eligible: Vec<usize> = (0..loads.len())
        .filter(|&i| loads[i].healthy && !loads[i].draining)
        .collect();
    if eligible.is_empty() {
        return None;
    }
    match policy {
        RouterPolicy::RoundRobin => {
            for _ in 0..loads.len() {
                let i = *rr_next % loads.len();
                *rr_next = rr_next.wrapping_add(1);
                if loads[i].healthy && !loads[i].draining {
                    return Some(i);
                }
            }
            Some(eligible[0])
        }
        RouterPolicy::LeastLoaded => Some(least_loaded_of(&eligible, loads)),
        RouterPolicy::PrefixAffinity | RouterPolicy::Directory => {
            if let Some(owner) = owner {
                if owner < loads.len() && loads[owner].healthy && !loads[owner].draining {
                    // would honoring affinity skew the cluster past
                    // the cost model's break-even?  Project the
                    // owner's score with the incoming request's
                    // tokens added to its backlog — through the same
                    // speed/pressure model as everyone else's score,
                    // so a fast (speculating) owner is not penalized
                    // by raw token units
                    let mut projected = loads[owner].clone();
                    projected.outstanding_tokens += incoming_cost;
                    let backlog: Vec<f64> = eligible
                        .iter()
                        .map(|&i| {
                            if i == owner {
                                load_score(&projected)
                            } else {
                                load_score(&loads[i])
                            }
                        })
                        .collect();
                    if replica_imbalance(&backlog) <= affinity_threshold {
                        return Some(owner);
                    }
                }
            }
            Some(least_loaded_of(&eligible, loads))
        }
    }
}

/// Should this request start on a dedicated prefill replica and hand
/// off at prefill completion?  Yes only when the prompt dominates the
/// decode budget (there is real prefill work to specialize on) AND the
/// cost model prices moving the committed prefix — its KV blocks
/// through the PCIe host tier — under re-prefilling it on the decode
/// side.  Otherwise the request is better served by a mixed placement
/// and the router routes it through the ordinary decode-capable pool.
/// `pricing` is `None` when no cost model is available (N = 1 wrapper,
/// tests), which prices every prefill-heavy hand-off as paying.
pub fn handoff_pays(
    pricing: Option<&(CostModel, OptConfig)>,
    block_size: usize,
    prompt_tokens: usize,
    max_new_tokens: usize,
) -> bool {
    if prompt_tokens < 4 * max_new_tokens.max(1) {
        return false;
    }
    match pricing {
        // +1 block: the sampled first decode token travels in the
        // hand-off envelope but its KV lands in a possibly-fresh tail
        // block on the destination
        Some((cm, opt)) => cm.swap_beats_recompute(
            prompt_tokens.div_ceil(block_size.max(1)) + 1,
            prompt_tokens,
            opt,
        ),
        None => true,
    }
}

/// Role-aware wrapper around [`pick_replica`]: restrict placement to
/// the replicas whose [`ReplicaRole`] fits the request's phase, falling
/// back to the remaining roles when the preferred pool has nothing
/// routable — roles are a preference, availability is a guarantee.
/// Requests bound for a prefill replica (`to_prefill`, per
/// [`handoff_pays`]) prefer the dedicated `Prefill` pool; everything
/// else prefers the decode-capable (`Decode`/`Mixed`) pool.
#[allow(clippy::too_many_arguments)]
pub fn pick_replica_pd(
    policy: RouterPolicy,
    loads: &[ReplicaLoad],
    roles: &[ReplicaRole],
    to_prefill: bool,
    owner: Option<usize>,
    rr_next: &mut usize,
    incoming_cost: f64,
    affinity_threshold: f64,
) -> Option<usize> {
    let tiers: &[&[ReplicaRole]] = if to_prefill {
        &[
            &[ReplicaRole::Prefill],
            &[ReplicaRole::Decode, ReplicaRole::Mixed],
        ]
    } else {
        &[
            &[ReplicaRole::Decode, ReplicaRole::Mixed],
            &[ReplicaRole::Prefill],
        ]
    };
    for tier in tiers {
        // mask out-of-tier replicas as draining in a scratch copy so
        // the policy core (including the round-robin cursor and the
        // affinity fallback) sees them exactly like drained ones
        let mut masked = loads.to_vec();
        let mut any = false;
        for (l, r) in masked.iter_mut().zip(roles) {
            if !tier.contains(r) {
                l.draining = true;
            } else if l.healthy && !l.draining {
                any = true;
            }
        }
        if !any {
            continue;
        }
        if let Some(c) = pick_replica(
            policy,
            &masked,
            owner,
            rr_next,
            incoming_cost,
            affinity_threshold,
        ) {
            return Some(c);
        }
    }
    None
}

// ---------------------------------------------------------------------------
// synchronous driver (benches/tests)
// ---------------------------------------------------------------------------

/// One routed request's outcome.
#[derive(Debug, Clone)]
pub struct RoutedResult {
    pub replica: usize,
    pub result: GenResult,
}

/// What one admitted request owes the admission books: released when
/// its result comes back (success, cancellation, or failure alike).
#[derive(Debug, Clone)]
struct AdmitDebit {
    batch: bool,
    tenant: Option<String>,
    prompt_tokens: f64,
    /// router-plane length stamp (p50, p90) in force at admission —
    /// resolved against the actual generated length at settle
    len_pred: Option<(f64, f64)>,
    /// router-plane wait stamp: (predicted ms, load score it was quoted
    /// at) — resolved against the actual queue wait at settle
    wait_pred: Option<(f64, f64)>,
}

/// Synchronous N-replica cluster: owns the engines, routes at submit
/// time, runs each replica to completion.  Fully deterministic — the
/// bench/test driver (the HTTP path uses [`RouterHandle`]).
pub struct Router<B: Backend> {
    replicas: Vec<Engine<B>>,
    policy: RouterPolicy,
    tokenizer: Tokenizer,
    block_size: usize,
    affinity_threshold: f64,
    /// PD role per replica (mirrors each engine's own `cfg.role`)
    roles: Vec<ReplicaRole>,
    /// hand-off pricing inputs; `None` prices every prefill-heavy
    /// hand-off as paying (see [`handoff_pays`])
    pricing: Option<(CostModel, OptConfig)>,
    rr_next: usize,
    /// cluster prefix directory: affinity bookkeeping for the
    /// `prefix_affinity` policy (leading block only, registered at
    /// routing time) and the full chain map for `directory` (delta-fed
    /// from the replicas' prefix indexes, drives cross-replica pulls)
    directory: PrefixDirectory,
    outstanding: Vec<f64>,
    draining: Vec<bool>,
    /// SLO admission knobs ([`Router::with_slo`]); default off
    slo: SloConfig,
    /// requests refused by the admission controller
    shed_requests: u64,
    /// admitted-but-unfinished batch requests (the bounded batch queue)
    batch_queued: usize,
    /// outstanding prefill tokens per tenant, and their cluster total
    tenant_tokens: HashMap<String, f64>,
    tenant_total: f64,
    /// per-admission debits, keyed like [`Router::routed`] entries
    admitted: HashMap<(usize, SeqId), AdmitDebit>,
    /// (replica, seq id) per submission, in submission order; hand-off
    /// dispatch remaps an entry to its destination replica + new id
    routed: Vec<(usize, SeqId)>,
    /// results collected by [`Router::step_all`] before the closing
    /// [`Router::run_to_completion`] (open-loop driving)
    completed: HashMap<(usize, SeqId), GenResult>,
    /// router-level predictive plane: arrival/burst tracking, the
    /// queue-wait forecaster, and per-tenant length hints for the cost
    /// estimate ([`Router::with_forecast`]; default off = reactive)
    forecast: ForecastPlane,
}

impl<B: Backend> Router<B> {
    pub fn new(replicas: Vec<Engine<B>>, policy: RouterPolicy) -> Self {
        assert!(!replicas.is_empty(), "router needs at least one replica");
        let geometry = *replicas[0].backend.geometry();
        let affinity_threshold = affinity_threshold_for(&replicas[0].backend);
        let pricing = Some((
            CostModel::for_preset(replicas[0].backend.preset(), geometry.block_size)
                .with_ctx_scale(8.0),
            *replicas[0].backend.opt(),
        ));
        let n = replicas.len();
        let roles = replicas.iter().map(|e| e.role()).collect();
        Router {
            replicas,
            policy,
            tokenizer: Tokenizer::new(),
            block_size: geometry.block_size,
            affinity_threshold,
            roles,
            pricing,
            rr_next: 0,
            directory: PrefixDirectory::new(DIRECTORY_CAP),
            outstanding: vec![0.0; n],
            draining: vec![false; n],
            slo: SloConfig::default(),
            shed_requests: 0,
            batch_queued: 0,
            tenant_tokens: HashMap::new(),
            tenant_total: 0.0,
            admitted: HashMap::new(),
            routed: Vec::new(),
            completed: HashMap::new(),
            forecast: ForecastPlane::new(ForecastConfig::default()),
        }
    }

    /// Override the prefix-affinity fallback threshold (tests).
    pub fn with_affinity_threshold(mut self, t: f64) -> Self {
        self.affinity_threshold = t;
        self
    }

    /// Set the SLO admission knobs (benches/tests; the serving path
    /// takes them from the engine config via [`RouterHandle::with_slo`]).
    pub fn with_slo(mut self, slo: SloConfig) -> Self {
        self.slo = slo;
        self
    }

    /// Enable the router-level predictive plane (benches/tests; the
    /// serving path takes it from the engine config via
    /// [`RouterHandle::with_forecast`]).
    pub fn with_forecast(mut self, fc: ForecastConfig) -> Self {
        self.forecast = ForecastPlane::new(fc);
        self
    }

    /// The router-level predictive plane (calibration reads).
    pub fn forecast(&self) -> &ForecastPlane {
        &self.forecast
    }

    /// Mutable plane access — property tests poison estimators through
    /// this to prove out-of-band coverage falls back to reactive control.
    pub fn forecast_mut(&mut self) -> &mut ForecastPlane {
        &mut self.forecast
    }

    /// Requests refused by the admission controller so far.
    pub fn shed_requests(&self) -> u64 {
        self.shed_requests
    }

    /// Admitted-but-unfinished batch requests (the bounded batch queue's
    /// current depth).
    pub fn batch_queue_depth(&self) -> usize {
        self.batch_queued
    }

    /// Assign PD roles, one per replica: sets each engine's own role
    /// (so prefill replicas park finished prompts) and the router's
    /// placement table.
    pub fn with_roles(mut self, roles: Vec<ReplicaRole>) -> Self {
        assert_eq!(roles.len(), self.replicas.len(), "one role per replica");
        for (e, &r) in self.replicas.iter_mut().zip(&roles) {
            e.set_role(r);
        }
        self.roles = roles;
        self
    }

    /// Drop the cost-model gate on hand-off placement: every
    /// prefill-heavy request routes through a prefill replica (tests —
    /// keeps PD behaviour independent of the cost-model constants).
    pub fn with_unpriced_handoff(mut self) -> Self {
        self.pricing = None;
        self
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    pub fn replicas(&self) -> &[Engine<B>] {
        &self.replicas
    }

    /// Mutable view, e.g. for percentile reads (sorting is lazy) after
    /// a run has completed.
    pub fn replicas_mut(&mut self) -> &mut [Engine<B>] {
        &mut self.replicas
    }

    pub fn set_draining(&mut self, replica: usize, draining: bool) {
        self.draining[replica] = draining;
    }

    /// Live load view of every replica (engine state + router estimates).
    pub fn loads(&self) -> Vec<ReplicaLoad> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let s = e.load_signals();
                ReplicaLoad {
                    queue_depth: s.pending,
                    outstanding_tokens: self.outstanding[i],
                    free_device_blocks: s.free_device_blocks,
                    total_device_blocks: s.total_device_blocks,
                    free_host_blocks: s.free_host_blocks,
                    tokens_per_step: s.tokens_per_step,
                    gemm_bound: s.gemm_bound,
                    draining: self.draining[i],
                    healthy: true,
                }
            })
            .collect()
    }

    /// The cluster prefix directory (bench/test observability: hit-tier
    /// counters, per-entry accounting).
    pub fn directory(&self) -> &PrefixDirectory {
        &self.directory
    }

    /// Mutable directory access (tests inject stale entries to exercise
    /// the fallback path).
    pub fn directory_mut(&mut self) -> &mut PrefixDirectory {
        &mut self.directory
    }

    /// Drain every replica's published prefix-index deltas into the
    /// directory (the sync driver's stand-in for the snapshot channel).
    /// Deltas lost to the replica-side ring cap only make the directory
    /// *staler*, never wrong — a stale pull under-exports and the
    /// destination re-prefills the difference.
    fn sync_directory(&mut self) {
        for i in 0..self.replicas.len() {
            for d in self.replicas[i].take_prefix_deltas() {
                self.directory.apply(i, d);
            }
        }
    }

    /// The cluster's observed queue-wait p95 (merged across replicas),
    /// the admission controller's memory of past queueing.
    fn observed_queue_p95_s(&self) -> f64 {
        let mut merged = LatencyHist::new();
        for e in &self.replicas {
            merged.merge(&e.metrics.hist_queue_wall);
        }
        if merged.count() > 0 {
            merged.p95()
        } else {
            0.0
        }
    }

    /// Route and submit one request; returns (replica, sequence id).
    /// With [`SloConfig::admission`] on, the request first passes the
    /// admission controller and may be shed (`Err` starting with
    /// [`SHED_MARKER`]) instead of routed.
    pub fn submit(&mut self, req: GenRequest) -> Result<(usize, SeqId)> {
        if self.policy == RouterPolicy::Directory {
            self.sync_directory();
        }
        self.forecast.observe_arrival(req.class.tenant.as_deref());
        let pd_active = self.roles.iter().any(|&r| r != ReplicaRole::Mixed);
        // round-robin reads neither the cost estimate nor the prefix
        // key, so it skips the router-side tokenization entirely — but
        // PD placement needs the prompt length, and admission the
        // tenant's prefill tokens, so either forces it on
        let (cost, chain, prompt_tokens) = match self.policy {
            RouterPolicy::RoundRobin if !pd_active && !self.slo.admission => {
                (0.0, Vec::new(), 0)
            }
            _ => {
                let tokens = self.tokenizer.encode(&req.prompt, true, false);
                let chain = match self.policy {
                    // affinity keys on the leading block only (PR 5
                    // behaviour); the directory keys on the full chain
                    RouterPolicy::PrefixAffinity => {
                        leading_prefix_hash(&tokens, self.block_size)
                            .into_iter()
                            .collect()
                    }
                    RouterPolicy::Directory => {
                        prefix_chain_hashes(&tokens, self.block_size, CHAIN_CAP)
                    }
                    _ => Vec::new(),
                };
                (
                    // an in-band per-tenant p90 caps the decode term of
                    // the cost estimate; None reproduces the `5x max_new`
                    // guess exactly
                    request_cost_estimate_hinted(
                        tokens.len(),
                        req.max_new_tokens,
                        self.forecast.len_hint_p90(req.class.tenant.as_deref()),
                    ),
                    chain,
                    tokens.len(),
                )
            }
        };
        let loads = self.loads();
        // band-independent stamps: every prediction is scored at settle
        // whether or not admission consumed it (self-scoring contract)
        let len_pred = self.forecast.len_quantiles(req.class.tenant.as_deref());
        let best_score = loads
            .iter()
            .filter(|l| l.healthy && !l.draining)
            .map(load_score)
            .fold(f64::INFINITY, f64::min);
        let wait_pred = if self.forecast.enabled() && best_score.is_finite() {
            // the reactive quote bootstraps the forecaster's first sample
            let quote = self
                .forecast
                .wait_quote_ms(best_score)
                .unwrap_or(best_score * SLO_MS_PER_TOKEN);
            Some((quote, best_score))
        } else {
            None
        };
        if self.slo.admission {
            let tenant_out = req
                .class
                .tenant
                .as_deref()
                .and_then(|t| self.tenant_tokens.get(t))
                .copied()
                .unwrap_or(0.0);
            // the learned drain rate replaces the SLO_MS_PER_TOKEN
            // constant while in band, and a scored burst pre-tightens
            // admission ahead of the arrival wave (wait multiplied,
            // batch-queue bound divided); every lever is 1:1 with the
            // reactive path when cold or out of band
            let tighten = self.forecast.admission_tighten();
            let wait = projected_wait_ms_with(
                &loads,
                self.observed_queue_p95_s(),
                self.forecast.wait_ms_per_load(),
            ) * tighten;
            if let Some(shed) = admission_decision(
                &tightened_slo(&self.slo, tighten),
                &req.class,
                prompt_tokens,
                self.batch_queued,
                wait,
                tenant_out,
                self.tenant_total,
            ) {
                self.shed_requests += 1;
                return Err(shed_error(&req.class, &shed));
            }
        }
        // resolve the affinity owner: deepest registered chain entry for
        // `directory` (with hit-tier accounting), leading block for
        // `prefix_affinity`
        let probe = match self.policy {
            RouterPolicy::Directory => self.directory.probe_longest(&chain),
            RouterPolicy::PrefixAffinity => chain
                .first()
                .and_then(|&h| self.directory.owner_of(h))
                .map(|r| (1, r, Tier::Device)),
            _ => None,
        };
        let owner = probe
            .map(|(_, r, _)| r)
            .filter(|&r| r < loads.len());
        let choice = if pd_active {
            let to_prefill = handoff_pays(
                self.pricing.as_ref(),
                self.block_size,
                prompt_tokens,
                req.max_new_tokens,
            );
            pick_replica_pd(
                self.policy,
                &loads,
                &self.roles,
                to_prefill,
                owner,
                &mut self.rr_next,
                cost,
                self.affinity_threshold,
            )
        } else {
            pick_replica(
                self.policy,
                &loads,
                owner,
                &mut self.rr_next,
                cost,
                self.affinity_threshold,
            )
        }
        .ok_or_else(|| anyhow!("no routable replica (all draining)"))?;
        if let Some(&h) = chain.first() {
            let alive: Vec<bool> = loads.iter().map(|l| l.healthy).collect();
            self.directory.register(h, choice, &alive);
        }
        // cross-replica prefix pull: the owner holds a deeper warm chain
        // than the chosen destination and the cost model prices moving
        // it over the host tier under re-prefilling it — pull before
        // submit so prefill covers only the unmatched tail
        if self.policy == RouterPolicy::Directory {
            if let Some((depth, owner, tier)) = probe {
                let pays = match &self.pricing {
                    Some((cm, opt)) => cm.prefix_pull_pays(
                        depth,
                        depth * self.block_size,
                        tier == Tier::Host,
                        opt,
                    ),
                    None => true,
                };
                if owner != choice && owner < self.replicas.len() && pays {
                    let pull = self.replicas[owner].export_prefix(&chain[..depth]);
                    self.replicas[choice].pull_commit(pull)?;
                }
            }
        }
        let debit = AdmitDebit {
            batch: !req.class.priority.is_interactive(),
            tenant: req.class.tenant.clone(),
            prompt_tokens: prompt_tokens as f64,
            len_pred,
            wait_pred,
        };
        let id = self.replicas[choice].submit(req)?;
        // carry the router-plane wait prediction onto the request's
        // trace so predicted-vs-actual lands in the flight recorder
        // (length stamps are the engine plane's own, made at submit)
        if let Some((quote, _)) = wait_pred {
            self.replicas[choice].stamp_forecast(
                id,
                ForecastStamp {
                    wait_ms: Some(quote),
                    ..ForecastStamp::default()
                },
            );
        }
        self.outstanding[choice] += cost;
        if debit.batch {
            self.batch_queued += 1;
        }
        if let Some(t) = &debit.tenant {
            *self.tenant_tokens.entry(t.clone()).or_insert(0.0) += debit.prompt_tokens;
            self.tenant_total += debit.prompt_tokens;
        }
        self.admitted.insert((choice, id), debit);
        self.routed.push((choice, id));
        Ok((choice, id))
    }

    /// Release one finished request's admission debits (its batch-queue
    /// slot and tenant prefill tokens) — called wherever a result comes
    /// back, so cancellations and failures release exactly like
    /// successes.
    fn settle(&mut self, key: (usize, SeqId), r: &GenResult) {
        let Some(d) = self.admitted.remove(&key) else { return };
        // score the admission-time stamps against the outcome before
        // releasing the books (self-scoring: consumed or not)
        if self.forecast.enabled() {
            let tenant = d.tenant.as_deref();
            let actual_len = r.generated_tokens as u32;
            match d.len_pred {
                Some((p50, p90)) => {
                    self.forecast.resolve_len(tenant, p50, p90, actual_len)
                }
                // unstamped finishes still teach the window (warm-up)
                None => self.forecast.observe_len(tenant, actual_len),
            }
            if let Some((pred_ms, load)) = d.wait_pred {
                self.forecast
                    .resolve_wait(pred_ms, load, r.phases.queue_s * 1e3);
            }
        }
        if d.batch {
            self.batch_queued = self.batch_queued.saturating_sub(1);
        }
        if let Some(t) = &d.tenant {
            if let Some(v) = self.tenant_tokens.get_mut(t) {
                *v = (*v - d.prompt_tokens).max(0.0);
                if *v <= 0.0 {
                    self.tenant_tokens.remove(t);
                }
            }
            self.tenant_total = (self.tenant_total - d.prompt_tokens).max(0.0);
        }
    }

    /// Step every replica once (and dispatch any parked hand-offs),
    /// buffering finished results for the closing
    /// [`Router::run_to_completion`].  This is the open-loop driver for
    /// benches and property tests: interleaving submissions with
    /// stepping keeps earlier requests' prefix blocks *live* in their
    /// owners' caches at later requests' routing time — the state the
    /// directory probes (and cross-replica pulls) exist for.  Prefix
    /// blocks die with their last reader here, so an all-upfront
    /// submission would route everything against a cold directory.
    pub fn step_all(&mut self) -> Result<()> {
        for i in 0..self.replicas.len() {
            // parked sequences wait on dispatch, not stepping
            if self.replicas[i].num_pending() > self.replicas[i].num_migrating() {
                for r in self.replicas[i].step()? {
                    self.settle((i, r.id), &r);
                    self.completed.insert((i, r.id), r);
                }
            }
        }
        self.dispatch_handoffs()?;
        self.tick_forecast();
        Ok(())
    }

    /// Advance the router-level plane one step: sample cluster-aggregate
    /// signals and feed the burst detector the arrivals accumulated
    /// since the last [`Router::step_all`] round.  No-op with
    /// forecasting off.
    fn tick_forecast(&mut self) {
        if !self.forecast.enabled() {
            return;
        }
        let mut pending = 0usize;
        let mut free = 0usize;
        let mut prefill = 0u64;
        let mut decode = 0u64;
        for e in &self.replicas {
            let s = e.load_signals();
            pending += s.pending;
            free += s.free_device_blocks;
            prefill += e.metrics.prefill_tokens_committed;
            decode += e.metrics.decode_tokens_committed;
        }
        self.forecast
            .tick(pending, self.admitted.len(), prefill, decode, free);
    }

    /// Collect parked sequences from prefill-role replicas and re-admit
    /// each on the least-loaded decode-capable replica.  A sequence with
    /// no routable destination at all is aborted back to local decode;
    /// one whose destinations are merely batch-full right now is
    /// deferred to a later round, so the hand-off lands on the KV path
    /// once a decode slot frees instead of degrading to re-prefill.
    /// Returns whether any hand-off was resolved (moved or aborted).
    fn dispatch_handoffs(&mut self) -> Result<bool> {
        let mut moved = false;
        for i in 0..self.replicas.len() {
            for id in self.replicas[i].take_handoff_ready() {
                let loads = self.loads();
                let routable = |j: &usize| {
                    *j != i && self.roles[*j].accepts_decode() && !self.draining[*j]
                };
                if !(0..self.replicas.len()).any(|j| routable(&j)) {
                    // back to local decode: still progress
                    moved |= self.replicas[i].abort_handoff(id);
                    continue;
                }
                let dest = (0..self.replicas.len())
                    .filter(routable)
                    .filter(|&j| self.replicas[j].has_batch_slot())
                    .min_by(|&a, &b| load_score(&loads[a]).total_cmp(&load_score(&loads[b])));
                let Some(j) = dest else {
                    // all destinations batch-full: retry next round
                    self.replicas[i].defer_handoff(id);
                    continue;
                };
                let h = self.replicas[i].make_handoff(id)?;
                // the remaining work (its decode tokens) moves with it
                let rest = 5.0 * h.max_new.saturating_sub(h.tokens.len() - h.prompt_len) as f64;
                let new_id = self.replicas[j].migrate_in_seq(h)?;
                self.outstanding[i] = (self.outstanding[i] - rest).max(0.0);
                self.outstanding[j] += rest;
                for slot in self.routed.iter_mut() {
                    if *slot == (i, id) {
                        *slot = (j, new_id);
                    }
                }
                // the admission debit follows the sequence to its
                // destination so settle() finds it under the new key
                if let Some(d) = self.admitted.remove(&(i, id)) {
                    self.admitted.insert((j, new_id), d);
                }
                moved = true;
            }
        }
        Ok(moved)
    }

    /// Drive every replica to completion; results come back in
    /// submission order.  Without PD roles the replicas are independent
    /// and run in sequence (leaving each one's simulated-clock metrics
    /// untouched); with roles assigned they are stepped round-robin so
    /// hand-offs dispatch between rounds, exactly like the serving
    /// path's dispatcher thread.
    pub fn run_to_completion(&mut self) -> Result<Vec<RoutedResult>> {
        let mut by_key: HashMap<(usize, SeqId), GenResult> =
            std::mem::take(&mut self.completed);
        let pd_active = self.roles.iter().any(|&r| r != ReplicaRole::Mixed);
        if !pd_active {
            for i in 0..self.replicas.len() {
                for r in self.replicas[i].run_to_completion()? {
                    self.settle((i, r.id), &r);
                    by_key.insert((i, r.id), r);
                }
                self.outstanding[i] = 0.0;
            }
        } else {
            for e in self.replicas.iter_mut() {
                e.metrics.start_run();
            }
            loop {
                let mut progressed = false;
                for i in 0..self.replicas.len() {
                    // parked sequences wait on dispatch, not stepping
                    if self.replicas[i].num_pending() > self.replicas[i].num_migrating() {
                        for r in self.replicas[i].step()? {
                            self.settle((i, r.id), &r);
                            by_key.insert((i, r.id), r);
                        }
                        progressed = true;
                    }
                }
                progressed |= self.dispatch_handoffs()?;
                if self.replicas.iter().all(|e| e.num_pending() == 0) {
                    break;
                }
                if !progressed {
                    bail!("router wedged: pending work but no replica can progress");
                }
            }
            for e in self.replicas.iter_mut() {
                e.metrics.finish_run();
            }
            for o in self.outstanding.iter_mut() {
                *o = 0.0;
            }
        }
        if self.policy == RouterPolicy::Directory {
            // fold the run's prefix-index churn into the directory now,
            // so between-wave readers (benches, props) see fresh state
            // instead of waiting for the next submission to drain it
            self.sync_directory();
        }
        std::mem::take(&mut self.routed)
            .into_iter()
            .map(|(replica, id)| {
                by_key
                    .remove(&(replica, id))
                    .map(|result| RoutedResult { replica, result })
                    .ok_or_else(|| anyhow!("replica {replica} lost sequence {id}"))
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// threaded driver (HTTP serving)
// ---------------------------------------------------------------------------

/// A replica's routing status (the `/health` per-replica view).
#[derive(Debug, Clone)]
pub struct ReplicaStatus {
    pub replica: usize,
    pub healthy: bool,
    pub draining: bool,
    pub in_flight: usize,
    pub role: ReplicaRole,
}

/// [`ReplicaRole`] packed into an atomic for the lock-free role table
/// (the autoscaler writes it while the routing path reads it).
fn role_code(r: ReplicaRole) -> u8 {
    match r {
        ReplicaRole::Prefill => 0,
        ReplicaRole::Decode => 1,
        ReplicaRole::Mixed => 2,
    }
}

fn role_from_code(c: u8) -> ReplicaRole {
    match c {
        0 => ReplicaRole::Prefill,
        1 => ReplicaRole::Decode,
        _ => ReplicaRole::Mixed,
    }
}

struct RouterReplica {
    handle: EngineHandle,
    in_flight: AtomicUsize,
    draining: AtomicBool,
}

struct RouteState {
    rr_next: usize,
    /// cluster prefix directory (see [`directory`]): affinity
    /// bookkeeping for `prefix_affinity`, full chain map + pull driver
    /// for `directory`
    directory: PrefixDirectory,
    /// highest snapshot `seq` whose prefix deltas were drained, per
    /// replica — each delta is published in exactly one snapshot, so
    /// the guard prevents double-applying a snapshot read twice while a
    /// skipped snapshot merely loses its deltas (stale-safe)
    last_delta_seq: Vec<u64>,
    outstanding: Vec<f64>,
    /// admitted-but-unfinished batch requests (the bounded batch queue)
    batch_queued: usize,
    /// outstanding prefill tokens per tenant, and their cluster total
    tenant_tokens: HashMap<String, f64>,
    tenant_total: f64,
    /// the router's own predictive plane (default-off; see
    /// [`RouterHandle::with_forecast`]) — ticked off the replicas'
    /// snapshot seq stream, so its step clock advances with cluster
    /// progress rather than with request arrivals
    forecast: ForecastPlane,
    /// highest snapshot `seq` the forecast plane has ticked on (each
    /// published engine step advances the plane at most once)
    forecast_last_seq: u64,
}

/// Cluster keys summed across replica snapshots for the aggregated
/// `GET /metrics` view (counters and capacities only — gauges are
/// reported per replica and as spreads, never summed).
const CLUSTER_SUM_KEYS: &[&str] = &[
    "requests_finished",
    "tokens_generated",
    "prefill_steps",
    "prefill_chunks",
    "decode_steps",
    "preemptions",
    "spec_rounds",
    "spec_drafted",
    "spec_accepted",
    "swap_outs",
    "swap_ins",
    "prefetch_hits",
    "prefetch_misses",
    "tokens_recomputed",
    "recompute_avoided_tokens",
    "cache_blocks_total",
    "cache_blocks_used",
    "cache_prefix_hits",
    "host_pool_blocks",
    "host_blocks_used",
    "host_blocks_peak",
    "swapped_seqs",
    "migrations_out",
    "migrations_in",
    "migrated_blocks_out",
    "migrated_blocks_in",
    "migration_bytes",
    "migrations_token_fallback",
    "prefix_pulls",
    "prefix_pull_blocks",
    "prefix_pull_bytes",
    "prefix_pull_blocks_out",
    "prefix_pull_stale",
    "proactive_swap_outs",
    "deadline_cancellations",
];

/// Threaded N-replica front-end: each replica is an [`EngineHandle`]
/// thread; routing reads the replicas' atomically-published snapshots
/// plus the router's own in-flight accounting.  The [`crate::server`]
/// HTTP layer serves through this.
pub struct RouterHandle {
    replicas: Arc<Vec<RouterReplica>>,
    /// lock-free PD role table ([`role_code`]); the engines hold their
    /// own copy, updated via [`EngineHandle::set_role`] messages
    roles: Arc<Vec<AtomicU8>>,
    policy: RouterPolicy,
    tokenizer: Tokenizer,
    block_size: usize,
    affinity_threshold: f64,
    /// hand-off pricing inputs; `None` (N = 1 wrapper) prices every
    /// prefill-heavy hand-off as paying
    pricing: Option<(CostModel, OptConfig)>,
    /// SLO admission knobs ([`RouterHandle::with_slo`]); default off
    slo: SloConfig,
    /// requests refused by the admission controller
    shed_requests: AtomicU64,
    /// failed requests re-routed once to a surviving replica
    router_retries: AtomicU64,
    state: Mutex<RouteState>,
}

impl RouterHandle {
    /// Spawn one engine thread per replica, plus the hand-off
    /// dispatcher draining the cluster's hand-off bus: a prefill-role
    /// engine ships each sequence it parks at prefill completion (with
    /// its waiting client) to the bus, and the dispatcher re-admits it
    /// on the least-loaded decode-capable replica.
    pub fn spawn<B: Backend + Send + 'static>(
        engines: Vec<Engine<B>>,
        policy: RouterPolicy,
    ) -> Self {
        assert!(!engines.is_empty(), "router needs at least one replica");
        let geometry = *engines[0].backend.geometry();
        let affinity_threshold = affinity_threshold_for(&engines[0].backend);
        let pricing = Some((
            CostModel::for_preset(engines[0].backend.preset(), geometry.block_size)
                .with_ctx_scale(8.0),
            *engines[0].backend.opt(),
        ));
        let n = engines.len();
        let roles: Arc<Vec<AtomicU8>> = Arc::new(
            engines
                .iter()
                .map(|e| AtomicU8::new(role_code(e.role())))
                .collect(),
        );
        let (handoff_tx, handoff_rx) = std::sync::mpsc::channel();
        let replicas: Arc<Vec<RouterReplica>> = Arc::new(
            engines
                .into_iter()
                .enumerate()
                .map(|(i, e)| RouterReplica {
                    handle: EngineHandle::spawn_routed(e, i, handoff_tx.clone()),
                    in_flight: AtomicUsize::new(0),
                    draining: AtomicBool::new(false),
                })
                .collect(),
        );
        // the dispatcher exits when every engine thread (sender) is gone
        drop(handoff_tx);
        spawn_handoff_dispatcher(Arc::clone(&replicas), Arc::clone(&roles), handoff_rx);
        RouterHandle {
            replicas,
            roles,
            policy,
            tokenizer: Tokenizer::new(),
            block_size: geometry.block_size,
            affinity_threshold,
            pricing,
            slo: SloConfig::default(),
            shed_requests: AtomicU64::new(0),
            router_retries: AtomicU64::new(0),
            state: Mutex::new(RouteState {
                rr_next: 0,
                directory: PrefixDirectory::new(DIRECTORY_CAP),
                last_delta_seq: vec![0; n],
                outstanding: vec![0.0; n],
                batch_queued: 0,
                tenant_tokens: HashMap::new(),
                tenant_total: 0.0,
                forecast: ForecastPlane::new(ForecastConfig::default()),
                forecast_last_seq: 0,
            }),
        }
    }

    /// Wrap an already-spawned single engine: the N = 1 special case the
    /// one-replica [`crate::server::Server::bind`] path uses (every
    /// policy is the identity there, so no cost model is consulted).
    pub fn single(handle: EngineHandle) -> Self {
        RouterHandle {
            replicas: Arc::new(vec![RouterReplica {
                handle,
                in_flight: AtomicUsize::new(0),
                draining: AtomicBool::new(false),
            }]),
            roles: Arc::new(vec![AtomicU8::new(role_code(ReplicaRole::Mixed))]),
            policy: RouterPolicy::RoundRobin,
            tokenizer: Tokenizer::new(),
            block_size: 16,
            affinity_threshold: 1.0,
            pricing: None,
            slo: SloConfig::default(),
            shed_requests: AtomicU64::new(0),
            router_retries: AtomicU64::new(0),
            state: Mutex::new(RouteState {
                rr_next: 0,
                directory: PrefixDirectory::new(DIRECTORY_CAP),
                last_delta_seq: vec![0],
                outstanding: vec![0.0],
                batch_queued: 0,
                tenant_tokens: HashMap::new(),
                tenant_total: 0.0,
                forecast: ForecastPlane::new(ForecastConfig::default()),
                forecast_last_seq: 0,
            }),
        }
    }

    /// Set the SLO admission knobs (the serve path passes the engine
    /// config's [`SloConfig`] through; default leaves admission off).
    pub fn with_slo(mut self, slo: SloConfig) -> Self {
        self.slo = slo;
        self
    }

    /// Give the router its own predictive plane (the serve path passes
    /// the engine config's [`ForecastConfig`] through; default off).
    pub fn with_forecast(mut self, fc: ForecastConfig) -> Self {
        let st = self.state.get_mut().unwrap_or_else(|p| p.into_inner());
        st.forecast = ForecastPlane::new(fc);
        self
    }

    /// Requests refused by the admission controller so far.
    pub fn shed_requests(&self) -> u64 {
        self.shed_requests.load(Ordering::Relaxed)
    }

    /// Failed requests re-routed once to a surviving replica.
    pub fn router_retries(&self) -> u64 {
        self.router_retries.load(Ordering::Relaxed)
    }

    /// Drop the cost-model gate on hand-off placement: every
    /// prefill-heavy request starts on a prefill replica regardless of
    /// what the PCIe-vs-re-prefill pricing says.  Deterministic PD
    /// activation for tests and benches.
    pub fn with_unpriced_handoff(mut self) -> Self {
        self.pricing = None;
        self
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// A replica's current PD role (the router's placement table).
    pub fn role(&self, replica: usize) -> ReplicaRole {
        role_from_code(self.roles[replica].load(Ordering::Relaxed))
    }

    fn roles_vec(&self) -> Vec<ReplicaRole> {
        self.roles
            .iter()
            .map(|r| role_from_code(r.load(Ordering::Relaxed)))
            .collect()
    }

    /// Re-role a replica (`/admin/role`, autoscaler): the placement
    /// table updates immediately; the engine thread applies the role
    /// before its next step, so an in-progress park still completes.
    pub fn set_role(&self, replica: usize, role: ReplicaRole) -> Result<()> {
        let r = self.replicas.get(replica).ok_or_else(|| {
            anyhow!(
                "no replica {replica} (cluster has {})",
                self.replicas.len()
            )
        })?;
        self.roles[replica].store(role_code(role), Ordering::Relaxed);
        r.handle.set_role(role)
    }

    /// Take a replica out of rotation (or put it back).  In-flight
    /// requests finish; only new placements are affected.
    pub fn set_draining(&self, replica: usize, draining: bool) -> Result<()> {
        let r = self.replicas.get(replica).ok_or_else(|| {
            anyhow!(
                "no replica {replica} (cluster has {})",
                self.replicas.len()
            )
        })?;
        r.draining.store(draining, Ordering::Relaxed);
        Ok(())
    }

    pub fn status(&self) -> Vec<ReplicaStatus> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaStatus {
                replica: i,
                healthy: r.handle.is_alive(),
                draining: r.draining.load(Ordering::Relaxed),
                in_flight: r.in_flight.load(Ordering::Relaxed),
                role: self.role(i),
            })
            .collect()
    }

    /// The router's outstanding-token estimates, one per replica.  They
    /// must drain back to zero as requests finish — success or failure —
    /// or least-loaded placement is permanently biased (tests).
    pub fn outstanding_estimates(&self) -> Vec<f64> {
        self.state
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .outstanding
            .clone()
    }

    fn loads(&self, outstanding: &[f64]) -> Vec<ReplicaLoad> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let snap = r.handle.snapshot();
                ReplicaLoad {
                    // the snapshot's pending lags by up to a step; the
                    // router's own dispatch counter never does
                    queue_depth: r.in_flight.load(Ordering::Relaxed).max(snap.pending),
                    outstanding_tokens: outstanding[i],
                    free_device_blocks: snap.free_device_blocks,
                    total_device_blocks: snap.total_device_blocks,
                    free_host_blocks: snap.free_host_blocks,
                    tokens_per_step: snap.tokens_per_step,
                    gemm_bound: snap.gemm_bound,
                    draining: r.draining.load(Ordering::Relaxed),
                    healthy: r.handle.is_alive(),
                }
            })
            .collect()
    }

    /// Advance the router plane's step clock off the replicas' snapshot
    /// stream: tick once per newly-published max engine step, feeding
    /// cluster-aggregate signals, so the signal ring and burst windows
    /// move with cluster progress rather than with request arrivals.
    fn tick_forecast_locked(&self, st: &mut RouteState) {
        if !st.forecast.enabled() {
            return;
        }
        let mut max_seq = 0u64;
        let mut pending = 0usize;
        let mut free = 0usize;
        let mut prefill = 0u64;
        let mut decode = 0u64;
        let mut running = 0usize;
        for r in self.replicas.iter() {
            let snap = r.handle.snapshot();
            max_seq = max_seq.max(snap.seq);
            pending += snap.pending;
            free += snap.free_device_blocks;
            prefill += snap.prefill_tokens_committed;
            decode += snap.decode_tokens_committed;
            running += r.in_flight.load(Ordering::Relaxed);
        }
        if max_seq > st.forecast_last_seq {
            st.forecast_last_seq = max_seq;
            st.forecast.tick(pending, running, prefill, decode, free);
        }
    }

    /// The cluster's observed queue-wait p95 (merged across replica
    /// snapshots) — the admission controller's memory of past queueing.
    fn observed_queue_p95_s(&self) -> f64 {
        let mut merged = LatencyHist::new();
        for r in self.replicas.iter() {
            if let Some(h) = json::parse(&r.handle.snapshot().json)
                .ok()
                .as_ref()
                .and_then(|v| v.get("hist"))
                .and_then(|h| h.get("queue_wall"))
                .and_then(LatencyHist::from_json)
            {
                merged.merge(&h);
            }
        }
        if merged.count() > 0 {
            merged.p95()
        } else {
            0.0
        }
    }

    /// Is any replica other than `failed` alive and in rotation?  Gates
    /// the one-shot retry: with nowhere else to go the client gets the
    /// original engine error, not a useless re-route failure.
    fn another_routable(&self, failed: usize) -> bool {
        self.replicas.iter().enumerate().any(|(j, r)| {
            j != failed && r.handle.is_alive() && !r.draining.load(Ordering::Relaxed)
        })
    }

    /// Route one request and generate through the chosen replica
    /// (blocking, like [`EngineHandle::generate`]).  With PD roles
    /// assigned, a prefill-heavy request whose hand-off pays starts on
    /// a prefill replica; the reply then comes from whichever replica
    /// the sequence migrated to.  With [`SloConfig::admission`] on the
    /// request first passes the admission controller and may be shed
    /// (`Err` starting with [`SHED_MARKER`]); a request whose replica
    /// fails under it is re-routed once to a surviving replica.
    pub fn generate(&self, req: GenRequest) -> Result<GenResult> {
        let roles = self.roles_vec();
        let pd_active = roles.iter().any(|&r| r != ReplicaRole::Mixed);
        // round-robin reads neither the cost estimate nor the prefix
        // key, so it skips the router-side tokenization entirely — but
        // PD placement needs the prompt length, and admission the
        // tenant's prefill tokens, so either forces it on
        let (mut cost, chain, prompt_tokens) = match self.policy {
            RouterPolicy::RoundRobin if !pd_active && !self.slo.admission => {
                (0.0, Vec::new(), 0)
            }
            _ => {
                let tokens = self.tokenizer.encode(&req.prompt, true, false);
                let chain = match self.policy {
                    RouterPolicy::PrefixAffinity => {
                        leading_prefix_hash(&tokens, self.block_size)
                            .into_iter()
                            .collect()
                    }
                    RouterPolicy::Directory => {
                        prefix_chain_hashes(&tokens, self.block_size, CHAIN_CAP)
                    }
                    _ => Vec::new(),
                };
                (
                    request_cost_estimate(tokens.len(), req.max_new_tokens),
                    chain,
                    tokens.len(),
                )
            }
        };
        let observed_queue_p95_s = if self.slo.admission {
            self.observed_queue_p95_s()
        } else {
            0.0
        };
        // router-plane predictions made on the first routing attempt,
        // taken and resolved once against the final result at settle
        let mut len_pred: Option<(f64, f64)> = None;
        let mut wait_pred: Option<(f64, f64)> = None;
        // `exclude` is the replica that already failed this request:
        // `None` on the first attempt, `Some` on the single retry
        let mut exclude: Option<usize> = None;
        loop {
            let (choice, pull_plan) = {
                // recover a poisoned lock: the routing state is plain
                // bookkeeping (cursor, directory, token estimates), valid
                // whatever a panicking thread was doing.  Propagating the
                // poison would wedge every subsequent request permanently.
                let mut guard = self.state.lock().unwrap_or_else(|p| p.into_inner());
                let st = &mut *guard;
                self.tick_forecast_locked(st);
                if exclude.is_none() {
                    // arrivals are observed before any shed decision so
                    // turned-away traffic still feeds the burst detector
                    st.forecast.observe_arrival(req.class.tenant.as_deref());
                    if cost > 0.0 {
                        // refine the admission/placement cost with the
                        // tenant's learned p90 output length (in-band
                        // hint only; None reproduces the static guess)
                        cost = request_cost_estimate_hinted(
                            prompt_tokens,
                            req.max_new_tokens,
                            st.forecast.len_hint_p90(req.class.tenant.as_deref()),
                        );
                    }
                    len_pred = st.forecast.len_quantiles(req.class.tenant.as_deref());
                }
                if self.policy == RouterPolicy::Directory {
                    // fold each replica's newly-published prefix deltas into
                    // the directory (eventual consistency over the snapshot
                    // channel; a skipped snapshot's deltas are lost, which
                    // only makes the directory staler, never wrong)
                    for (i, r) in self.replicas.iter().enumerate() {
                        let snap = r.handle.snapshot();
                        if snap.seq > st.last_delta_seq[i] {
                            for d in &snap.prefix_deltas {
                                st.directory.apply(i, *d);
                            }
                            st.last_delta_seq[i] = snap.seq;
                        }
                    }
                }
                let mut loads = self.loads(&st.outstanding);
                if let Some(x) = exclude {
                    // the replica that just failed this request is no
                    // candidate for its retry
                    loads[x].healthy = false;
                }
                if self.slo.admission && exclude.is_none() {
                    let tenant_out = req
                        .class
                        .tenant
                        .as_deref()
                        .and_then(|t| st.tenant_tokens.get(t))
                        .copied()
                        .unwrap_or(0.0);
                    // the wait forecast (when calibrated) replaces the
                    // static drain-rate constant, and an active burst
                    // pre-tightens admission ahead of the queue growth
                    // (wait multiplied, batch-queue bound divided)
                    let tighten = st.forecast.admission_tighten();
                    if let Some(shed) = admission_decision(
                        &tightened_slo(&self.slo, tighten),
                        &req.class,
                        prompt_tokens,
                        st.batch_queued,
                        projected_wait_ms_with(
                            &loads,
                            observed_queue_p95_s,
                            st.forecast.wait_ms_per_load(),
                        ) * tighten,
                        tenant_out,
                        st.tenant_total,
                    ) {
                        self.shed_requests.fetch_add(1, Ordering::Relaxed);
                        return Err(shed_error(&req.class, &shed));
                    }
                }
                if exclude.is_none() && st.forecast.enabled() {
                    // quote the queue wait this request is being admitted
                    // into (reactive drain model until the forecaster has
                    // its first resolved sample) and score it at settle
                    let best = loads
                        .iter()
                        .filter(|l| l.healthy && !l.draining)
                        .map(load_score)
                        .fold(f64::INFINITY, f64::min);
                    if best.is_finite() {
                        let quote = st
                            .forecast
                            .wait_quote_ms(best)
                            .unwrap_or(best * SLO_MS_PER_TOKEN);
                        wait_pred = Some((quote, best));
                    }
                }
                let probe = match self.policy {
                    RouterPolicy::Directory => st.directory.probe_longest(&chain),
                    RouterPolicy::PrefixAffinity => chain
                        .first()
                        .and_then(|&h| st.directory.owner_of(h))
                        .map(|r| (1, r, Tier::Device)),
                    _ => None,
                };
                let owner = probe
                    .map(|(_, r, _)| r)
                    .filter(|&r| r < loads.len());
                let picked = if pd_active {
                    let to_prefill = handoff_pays(
                        self.pricing.as_ref(),
                        self.block_size,
                        prompt_tokens,
                        req.max_new_tokens,
                    );
                    pick_replica_pd(
                        self.policy,
                        &loads,
                        &roles,
                        to_prefill,
                        owner,
                        &mut st.rr_next,
                        cost,
                        self.affinity_threshold,
                    )
                } else {
                    pick_replica(
                        self.policy,
                        &loads,
                        owner,
                        &mut st.rr_next,
                        cost,
                        self.affinity_threshold,
                    )
                };
                let Some(c) = picked else {
                    bail!("no routable replica (all draining or dead)");
                };
                if let Some(&h) = chain.first() {
                    let alive: Vec<bool> = loads.iter().map(|l| l.healthy).collect();
                    st.directory.register(h, c, &alive);
                }
                // plan a cross-replica pull while holding the lock, execute
                // it after release: the export/commit round-trips block on
                // the engine threads and must not serialize all routing
                let pull_plan = match (self.policy, probe) {
                    (RouterPolicy::Directory, Some((depth, owner, tier)))
                        if owner != c && owner < self.replicas.len() =>
                    {
                        let pays = match &self.pricing {
                            Some((cm, opt)) => cm.prefix_pull_pays(
                                depth,
                                depth * self.block_size,
                                tier == Tier::Host,
                                opt,
                            ),
                            None => true,
                        };
                        pays.then_some((depth, owner))
                    }
                    _ => None,
                };
                st.outstanding[c] += cost;
                if !req.class.priority.is_interactive() {
                    st.batch_queued += 1;
                }
                if let Some(t) = &req.class.tenant {
                    *st.tenant_tokens.entry(t.clone()).or_insert(0.0) +=
                        prompt_tokens as f64;
                    st.tenant_total += prompt_tokens as f64;
                }
                (c, pull_plan)
            };
            // cross-replica prefix pull: move the owner's warm chain through
            // the host-tier envelope before prefill starts.  Best-effort —
            // any failure (dead owner, nothing exportable) falls back to
            // re-prefilling the whole prompt, exact by construction.
            if let Some((depth, owner)) = pull_plan {
                if let Ok(pull) = self.replicas[owner].handle.export_prefix(chain[..depth].to_vec())
                {
                    let _ = self.replicas[choice].handle.pull_commit(pull);
                }
            }
            self.replicas[choice].in_flight.fetch_add(1, Ordering::Relaxed);
            let result = self.replicas[choice].handle.generate(req.clone());
            self.replicas[choice].in_flight.fetch_sub(1, Ordering::Relaxed);
            // same poison recovery as the routing path above: the two must
            // agree, or one panicking thread leaks its outstanding-token
            // estimate forever and biases least_loaded away from the replica
            let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
            st.outstanding[choice] = (st.outstanding[choice] - cost).max(0.0);
            if !req.class.priority.is_interactive() {
                st.batch_queued = st.batch_queued.saturating_sub(1);
            }
            if let Some(t) = &req.class.tenant {
                let tok = prompt_tokens as f64;
                if let Some(v) = st.tenant_tokens.get_mut(t) {
                    *v = (*v - tok).max(0.0);
                    if *v <= 0.0 {
                        st.tenant_tokens.remove(t);
                    }
                }
                st.tenant_total = (st.tenant_total - tok).max(0.0);
            }
            // score the router plane's predictions against the final
            // outcome (the take()s make each resolve at most once)
            if let Ok(r) = &result {
                match len_pred.take() {
                    Some((p50, p90)) => st.forecast.resolve_len(
                        req.class.tenant.as_deref(),
                        p50,
                        p90,
                        r.generated_tokens as u32,
                    ),
                    None => st.forecast.observe_len(
                        req.class.tenant.as_deref(),
                        r.generated_tokens as u32,
                    ),
                }
                if let Some((pred_ms, load)) = wait_pred.take() {
                    st.forecast.resolve_wait(pred_ms, load, r.phases.queue_s * 1e3);
                }
            }
            drop(st);
            match result {
                // the serving replica failed under the request and a
                // surviving replica can take it: re-route exactly once
                Err(e)
                    if exclude.is_none()
                        && is_replica_failure(&e)
                        && self.another_routable(choice) =>
                {
                    self.router_retries.fetch_add(1, Ordering::Relaxed);
                    crate::log_info!(
                        "router: replica {choice} failed a request ({e}); retrying once"
                    );
                    exclude = Some(choice);
                }
                other => return other,
            }
        }
    }

    /// The `GET /metrics` payload: for N = 1 the single replica's
    /// snapshot verbatim (existing scrapers keep working); for N > 1 a
    /// cluster aggregate of the counter keys plus gauge spreads.  Either
    /// way a `replicas` array carries each replica's full snapshot
    /// stamped with its step sequence number — each snapshot is an
    /// atomically-swapped Arc, so no per-replica view is ever torn.
    pub fn metrics_json(&self) -> String {
        let snaps: Vec<Arc<MetricsSnapshot>> =
            self.replicas.iter().map(|r| r.handle.snapshot()).collect();
        let parsed: Vec<Value> = snaps
            .iter()
            .map(|s| json::parse(&s.json).unwrap_or(Value::Null))
            .collect();
        let mut top = if parsed.len() == 1 {
            match &parsed[0] {
                Value::Object(o) => o.clone(),
                _ => Object::new(),
            }
        } else {
            cluster_aggregate(&parsed)
        };
        top.insert("num_replicas", self.replicas.len());
        top.insert("router_policy", self.policy.name());
        // router-level overload counters (these live above any replica)
        top.insert("shed_requests", self.shed_requests() as usize);
        top.insert("router_retries", self.router_retries() as usize);
        {
            let st = self.state.lock().unwrap_or_else(|p| p.into_inner());
            top.insert("batch_queue_depth", st.batch_queued);
            // the router plane's calibration gauges, nested so they can
            // never collide with the flat per-replica forecast keys that
            // the N = 1 path hoists to top level
            let mut fo = Object::new();
            st.forecast.metrics_json(&mut fo);
            if !fo.is_empty() {
                top.insert("router_forecast", Value::Object(fo));
            }
        }
        let role_names: Vec<Value> = self
            .roles_vec()
            .into_iter()
            .map(|r| r.name().into())
            .collect();
        top.insert("replica_roles", Value::Array(role_names));
        let reps: Vec<Value> = parsed
            .into_iter()
            .zip(snaps.iter())
            .zip(self.status())
            .map(|((v, snap), st)| {
                let h = &self.replicas[st.replica].handle;
                let mut o = Object::new();
                o.insert("replica", st.replica);
                o.insert("seq", snap.seq as usize);
                // signal freshness: how many engine steps this snapshot
                // lags the replica's live step counter, and how long the
                // replica has been up — scrapers can spot a wedged
                // publisher without diffing seq themselves
                o.insert(
                    "snapshot_age_steps",
                    crate::server::snapshot_age_steps(h.current_step(), snap.seq) as usize,
                );
                o.insert("uptime_s", h.uptime_s());
                o.insert("healthy", st.healthy);
                o.insert("draining", st.draining);
                o.insert("in_flight", st.in_flight);
                o.insert("role", st.role.name());
                o.insert("pending", snap.pending);
                o.insert("metrics", v);
                Value::Object(o)
            })
            .collect();
        top.insert("replicas", Value::Array(reps));
        Value::Object(top).to_string()
    }

    /// The `GET /admin/trace` payload: each replica's flight-recorder
    /// ring of recent finished-request timelines, optionally filtered by
    /// engine request id or client correlation id.  A migrated request
    /// appears once, under the replica that finished it (its trace
    /// travels with the hand-off).  A dead replica contributes an empty
    /// ring rather than failing the whole dump.
    pub fn trace_json(&self, id: Option<u64>, corr: Option<&str>) -> String {
        let reps: Vec<Value> = self
            .replicas
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let mut o = Object::new();
                o.insert("replica", i);
                o.insert(
                    "requests",
                    r.handle
                        .trace_json(id, corr)
                        .unwrap_or_else(|_| Value::Array(Vec::new())),
                );
                Value::Object(o)
            })
            .collect();
        let mut top = Object::new();
        top.insert("replicas", Value::Array(reps));
        Value::Object(top).to_string()
    }

    /// The `GET /admin/forecast` payload: the router's own predictive
    /// plane plus each replica's signal ring + estimator states (dumped
    /// through the engine threads, so every replica view is a consistent
    /// post-step one; a dead replica contributes `null`).
    pub fn forecast_json(&self) -> String {
        let router_plane = self
            .state
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .forecast
            .to_json();
        let reps: Vec<Value> = self
            .replicas
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let mut o = Object::new();
                o.insert("replica", i);
                o.insert(
                    "forecast",
                    r.handle.forecast_json().unwrap_or(Value::Null),
                );
                Value::Object(o)
            })
            .collect();
        let mut top = Object::new();
        top.insert("router", router_plane);
        top.insert("replicas", Value::Array(reps));
        Value::Object(top).to_string()
    }

    /// One autoscaling control step over the cluster's queue-depth and
    /// occupancy-spread signals; returns what it did (`"scale_up"`,
    /// `"scale_down"`, `"rerole"`, `"noop"`) for the serve loop's log
    /// and the tests.  Capacity rotates through the existing
    /// `/admin/drain` mechanism, so scaling down never kills in-flight
    /// work, and a drained replica is re-admitted (scaled up) the
    /// moment the backlog crosses the high-water mark.  Re-roling
    /// pushes the idlest replica toward the busiest one's
    /// specialization when the spread says one phase pool is
    /// saturated, but never strands a phase: the active set always
    /// keeps at least one prefill-capable and one decode-capable
    /// replica.
    pub fn autoscale_tick(&self) -> &'static str {
        let n = self.replicas.len();
        if n < 2 {
            return "noop";
        }
        let depths: Vec<f64> = self
            .replicas
            .iter()
            .map(|r| {
                let pending = r.handle.snapshot().pending;
                r.in_flight.load(Ordering::Relaxed).max(pending) as f64
            })
            .collect();
        let live = |i: usize| self.replicas[i].handle.is_alive();
        let draining = |i: usize| self.replicas[i].draining.load(Ordering::Relaxed);
        let active: Vec<usize> = (0..n).filter(|&i| live(i) && !draining(i)).collect();
        let parked: Vec<usize> = (0..n).filter(|&i| live(i) && draining(i)).collect();
        let total: f64 = active.iter().map(|&i| depths[i]).sum();
        // scale up: backlog past the active pool's high-water mark and
        // drained capacity is available
        if !parked.is_empty() && total >= AUTOSCALE_HIGH_DEPTH * active.len().max(1) as f64 {
            let _ = self.set_draining(parked[0], false);
            return "scale_up";
        }
        // scale down: cluster nearly idle — rotate out the
        // highest-index idle replica whose absence keeps both phases
        // covered
        if active.len() > 1 && total <= AUTOSCALE_LOW_DEPTH {
            let survives = |without: usize| {
                let rest: Vec<ReplicaRole> = active
                    .iter()
                    .filter(|&&i| i != without)
                    .map(|&i| self.role(i))
                    .collect();
                rest.iter().any(|r| r.accepts_prefill()) && rest.iter().any(|r| r.accepts_decode())
            };
            if let Some(&victim) = active
                .iter()
                .rev()
                .find(|&&i| depths[i] == 0.0 && survives(i))
            {
                let _ = self.set_draining(victim, true);
                return "scale_down";
            }
        }
        // re-role: one phase pool saturated while another replica
        // idles — give the idlest replica the busiest one's role
        if active.len() >= 2 {
            let spread: Vec<f64> = active.iter().map(|&i| depths[i]).collect();
            if replica_imbalance(&spread) > AUTOSCALE_REROLE_SPREAD {
                let busiest = *active
                    .iter()
                    .max_by(|&&a, &&b| depths[a].total_cmp(&depths[b]))
                    .unwrap();
                let idlest = *active
                    .iter()
                    .min_by(|&&a, &&b| depths[a].total_cmp(&depths[b]))
                    .unwrap();
                let want = self.role(busiest);
                let covered = {
                    let rest: Vec<ReplicaRole> = active
                        .iter()
                        .map(|&i| if i == idlest { want } else { self.role(i) })
                        .collect();
                    rest.iter().any(|r| r.accepts_prefill())
                        && rest.iter().any(|r| r.accepts_decode())
                };
                if busiest != idlest
                    && want != ReplicaRole::Mixed
                    && self.role(idlest) != want
                    && covered
                {
                    let _ = self.set_role(idlest, want);
                    return "rerole";
                }
            }
        }
        "noop"
    }
}

/// Autoscaler watermarks: scale up when the active pool's total queue
/// depth exceeds this many requests per active replica, scale down when
/// the whole cluster's depth falls to the low mark, and consider
/// re-roling when the queue-depth spread ([`replica_imbalance`]) says
/// one phase pool is saturated while another idles.
const AUTOSCALE_HIGH_DEPTH: f64 = 4.0;
const AUTOSCALE_LOW_DEPTH: f64 = 1.0;
const AUTOSCALE_REROLE_SPREAD: f64 = 1.0;

/// Run [`RouterHandle::autoscale_tick`] on a background thread every
/// `interval` (`--pd-autoscale`).  Holds only a weak reference, so the
/// thread winds down when the router is dropped.
pub fn start_autoscaler(router: &Arc<RouterHandle>, interval: std::time::Duration) {
    let weak = Arc::downgrade(router);
    std::thread::Builder::new()
        .name("coopt-autoscale".into())
        .spawn(move || loop {
            std::thread::sleep(interval);
            let Some(r) = weak.upgrade() else { return };
            let action = r.autoscale_tick();
            if action != "noop" {
                crate::log_info!("autoscaler: {action}");
            }
        })
        .expect("spawn autoscaler thread");
}

/// Hand-off deferrals: how many dispatcher rounds an envelope waits for
/// a destination batch slot before it is force-placed anyway (the
/// destination engine then parks it until a slot frees, so even a
/// force-placed hand-off stays on the KV path).
const MAX_DEFER_ATTEMPTS: u32 = 200;
/// Dispatcher poll interval while envelopes are deferred.
const DEFER_RETRY: Duration = Duration::from_millis(5);

/// The hand-off dispatcher: one thread draining the cluster's hand-off
/// bus.  Each envelope goes to the least-loaded decode-capable replica
/// *with a free batch slot*; when every candidate is batch-full the
/// envelope is deferred and retried (mirroring the sync driver's
/// `defer_handoff`) instead of burning the hand-off on a token
/// fallback.  The source replica is the fallback (a migrated-in
/// sequence is decode-ready and never re-parks, so sending it home is
/// always safe).  Runs until every engine thread (every bus sender) is
/// gone and the deferred queue has drained.
fn spawn_handoff_dispatcher(
    replicas: Arc<Vec<RouterReplica>>,
    roles: Arc<Vec<AtomicU8>>,
    rx: std::sync::mpsc::Receiver<HandoffEnvelope>,
) {
    std::thread::Builder::new()
        .name("coopt-handoff".into())
        .spawn(move || {
            let mut deferred: VecDeque<(HandoffEnvelope, u32)> = VecDeque::new();
            loop {
                let timeout = if deferred.is_empty() {
                    // nothing waiting: block until traffic (long timeout
                    // only so sender-drop is noticed promptly)
                    Duration::from_millis(100)
                } else {
                    DEFER_RETRY
                };
                match rx.recv_timeout(timeout) {
                    Ok(env) => {
                        if let Some(env) = dispatch_one_handoff(&replicas, &roles, env, false) {
                            deferred.push_back((env, 1));
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {
                        // engines gone: force-place whatever is left so
                        // no waiter is stranded, then exit
                        for (env, _) in deferred.drain(..) {
                            dispatch_one_handoff(&replicas, &roles, env, true);
                        }
                        return;
                    }
                }
                // retry the deferred queue; an envelope past its
                // deferral budget is force-placed
                for (env, attempts) in std::mem::take(&mut deferred) {
                    let force = attempts >= MAX_DEFER_ATTEMPTS;
                    if let Some(env) = dispatch_one_handoff(&replicas, &roles, env, force) {
                        deferred.push_back((env, attempts + 1));
                    }
                }
            }
        })
        .expect("spawn hand-off dispatcher");
}

/// Place one hand-off envelope.  Returns `Some(env)` when every
/// routable destination is batch-full and the envelope should be
/// retried later (`force` disables deferral and places it anyway).
fn dispatch_one_handoff(
    replicas: &[RouterReplica],
    roles: &[AtomicU8],
    env: HandoffEnvelope,
    force: bool,
) -> Option<HandoffEnvelope> {
    let depth = |j: usize| {
        let pending = replicas[j].handle.snapshot().pending;
        replicas[j].in_flight.load(Ordering::Relaxed).max(pending)
    };
    let candidates: Vec<usize> = (0..replicas.len())
        .filter(|&j| {
            j != env.from
                && role_from_code(roles[j].load(Ordering::Relaxed)).accepts_decode()
                && replicas[j].handle.is_alive()
                && !replicas[j].draining.load(Ordering::Relaxed)
        })
        .collect();
    // prefer destinations whose latest snapshot shows a free batch slot.
    // The snapshot can lag a step, so this is load balancing, not a
    // guarantee — the destination engine parks a KV hand-off that lands
    // while its batch is full and admits it once a slot frees, so the
    // race can delay a hand-off but never downgrade it to re-prefill.
    let with_slot: Vec<usize> = candidates
        .iter()
        .copied()
        .filter(|&j| replicas[j].handle.snapshot().batch_slots_free > 0)
        .collect();
    if !force && !candidates.is_empty() && with_slot.is_empty() {
        return Some(env);
    }
    let pool = if with_slot.is_empty() {
        &candidates
    } else {
        &with_slot
    };
    let dest = pool.iter().copied().min_by_key(|&j| depth(j)).unwrap_or(env.from);
    let HandoffEnvelope {
        from,
        handoff,
        reply,
    } = env;
    if let Err((h, r)) = replicas[dest].handle.migrate_in(handoff, reply) {
        // destination thread died between the health check and the
        // send: fall back to the source, then fail the waiter
        let failed = if dest != from {
            replicas[from].handle.migrate_in(h, r)
        } else {
            Err((h, r))
        };
        if let Err((h, r)) = failed {
            // both replicas are gone under this sequence; failing the
            // waiter can itself fail (client hung up) — either way the
            // loss is a structured stderr event, never a silent drop
            if r.send(Err(anyhow!("engine error: hand-off destination lost")))
                .is_err()
            {
                crate::obs::log_json_event(
                    crate::util::logging::Level::Warn,
                    "handoff_reply_send_failed",
                    &[
                        ("request_id", (h.trace.id as usize).into()),
                        ("from", from.into()),
                        ("dest", dest.into()),
                    ],
                );
            }
        }
    }
    None
}

fn cluster_aggregate(parsed: &[Value]) -> Object {
    let mut o = Object::new();
    for key in CLUSTER_SUM_KEYS {
        let total: f64 = parsed
            .iter()
            .filter_map(|v| v.get(key).and_then(|x| x.as_f64()))
            .sum();
        o.insert(*key, total as usize);
    }
    let gauges = |key: &str| -> Vec<f64> {
        parsed
            .iter()
            .filter_map(|v| v.get(key).and_then(|x| x.as_f64()))
            .collect()
    };
    let occ = gauges("decode_batch_occupancy");
    if !occ.is_empty() {
        o.insert(
            "decode_batch_occupancy_mean",
            occ.iter().sum::<f64>() / occ.len() as f64,
        );
        // how evenly the decode batches fill across replicas — the
        // router's balance report card
        o.insert("replica_occupancy_spread", replica_imbalance(&occ));
    }
    let tps = gauges("tokens_per_step");
    if !tps.is_empty() {
        o.insert(
            "tokens_per_step_mean",
            tps.iter().sum::<f64>() / tps.len() as f64,
        );
    }
    // wall-phase totals sum like counters (seconds spent are additive)
    for key in [
        "phase_queue_s",
        "phase_prefill_s",
        "phase_decode_s",
        "phase_swap_blocked_s",
        "phase_migration_s",
        "phase_spec_overhead_sim_s",
    ] {
        let total: f64 = parsed
            .iter()
            .filter_map(|v| v.get(key).and_then(|x| x.as_f64()))
            .sum();
        o.insert(key, total);
    }
    // exact cluster percentiles: merge the per-replica log-bucketed
    // histograms elementwise (identical canonical bounds everywhere),
    // then read percentiles off the merged distribution — never average
    // per-replica percentiles, which has no statistical meaning
    let mut hists = Object::new();
    for key in ["ttft_wall", "e2e_wall", "itl_sim", "queue_wall"] {
        let mut merged = LatencyHist::new();
        for v in parsed {
            if let Some(h) = v
                .get("hist")
                .and_then(|h| h.get(key))
                .and_then(LatencyHist::from_json)
            {
                merged.merge(&h);
            }
        }
        if merged.count() > 0 {
            o.insert(format!("{key}_p50_s"), merged.p50());
            o.insert(format!("{key}_p95_s"), merged.p95());
            o.insert(format!("{key}_p99_s"), merged.p99());
            o.insert(format!("{key}_mean_s"), merged.mean());
        }
        hists.insert(key, merged.to_json());
    }
    o.insert("hist", Value::Object(hists));
    // same merge per priority class: exact cluster-wide per-class
    // percentiles (`interactive_ttft_wall_p99_s`, ...) plus the nested
    // histograms the Prometheus exposition labels `class="..."`
    let mut by_class = Object::new();
    for class in ["interactive", "batch"] {
        let mut ch = Object::new();
        for key in ["ttft_wall", "e2e_wall", "itl_sim", "queue_wall"] {
            let mut merged = LatencyHist::new();
            for v in parsed {
                if let Some(h) = v
                    .get("hist_class")
                    .and_then(|c| c.get(class))
                    .and_then(|c| c.get(key))
                    .and_then(LatencyHist::from_json)
                {
                    merged.merge(&h);
                }
            }
            if merged.count() > 0 {
                o.insert(format!("{class}_{key}_p50_s"), merged.p50());
                o.insert(format!("{class}_{key}_p95_s"), merged.p95());
                o.insert(format!("{class}_{key}_p99_s"), merged.p99());
            }
            ch.insert(key, merged.to_json());
        }
        by_class.insert(class, Value::Object(ch));
    }
    o.insert("hist_class", Value::Object(by_class));
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, SwapPolicy, COOPT};
    use crate::runtime::mock::MockBackend;

    fn loads(n: usize) -> Vec<ReplicaLoad> {
        (0..n).map(|_| ReplicaLoad::idle()).collect()
    }

    fn pick(
        policy: RouterPolicy,
        ls: &[ReplicaLoad],
        owner: Option<usize>,
        rr: &mut usize,
        cost: f64,
        thr: f64,
    ) -> Option<usize> {
        pick_replica(policy, ls, owner, rr, cost, thr)
    }

    #[test]
    fn round_robin_cycles_and_skips_drained() {
        let mut ls = loads(3);
        let mut rr = 0;
        let picks: Vec<usize> = (0..6)
            .map(|_| pick(RouterPolicy::RoundRobin, &ls, None, &mut rr, 10.0, 1.0).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        ls[1].draining = true;
        let picks: Vec<usize> = (0..4)
            .map(|_| pick(RouterPolicy::RoundRobin, &ls, None, &mut rr, 10.0, 1.0).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "drained replica skipped");
        ls[0].draining = true;
        ls[2].healthy = false;
        assert_eq!(
            pick(RouterPolicy::RoundRobin, &ls, None, &mut rr, 10.0, 1.0),
            None,
            "nothing routable"
        );
    }

    #[test]
    fn least_loaded_scores_backlog_speed_and_pressure() {
        let mut ls = loads(3);
        ls[0].outstanding_tokens = 100.0;
        ls[1].outstanding_tokens = 40.0;
        ls[2].outstanding_tokens = 60.0;
        let mut rr = 0;
        assert_eq!(
            pick(RouterPolicy::LeastLoaded, &ls, None, &mut rr, 1.0, 1.0),
            Some(1)
        );
        // a speculating replica drains its backlog faster — the gauge is
        // a windowed EWMA of recent rounds, so the full measured rate is
        // credited (no stale-signal cap)...
        ls[0].tokens_per_step = 3.0;
        assert!(
            (load_score(&ls[0]) - 100.0 / 3.0).abs() < 1e-9,
            "100 tokens at a 3x recent rate"
        );
        assert!(load_score(&ls[0]) < load_score(&ls[2]));
        ls[0].tokens_per_step = 10.0;
        assert!((load_score(&ls[0]) - 10.0).abs() < 1e-9, "full 10x credit");
        // ...unless it is GEMM-bound (no amortization left)
        ls[0].gemm_bound = true;
        assert!(load_score(&ls[0]) > load_score(&ls[2]));
        // KV pressure inflates the score; host headroom relieves it
        let mut full = ReplicaLoad::idle();
        full.outstanding_tokens = 40.0;
        full.total_device_blocks = 96;
        full.free_device_blocks = 0;
        assert!(load_score(&full) > load_score(&ls[1]));
        full.free_host_blocks = 192;
        assert!((load_score(&full) - load_score(&ls[1])).abs() < 1e-9);
        // ties break to the lowest index
        let even = loads(3);
        assert_eq!(
            pick(RouterPolicy::LeastLoaded, &even, None, &mut rr, 1.0, 1.0),
            Some(0)
        );
    }

    #[test]
    fn prefix_affinity_prefers_owner_until_imbalance() {
        let mut ls = loads(2);
        let mut rr = 0;
        // balanced: honor affinity (resolved owner = replica 1)
        assert_eq!(
            pick(RouterPolicy::PrefixAffinity, &ls, Some(1), &mut rr, 10.0, 1.0),
            Some(1)
        );
        // unknown prefix (no resolved owner): fall through to least-loaded
        ls[0].outstanding_tokens = 50.0;
        assert_eq!(
            pick(RouterPolicy::PrefixAffinity, &ls, None, &mut rr, 10.0, 1.0),
            Some(1)
        );
        // owner badly behind the rest: the incoming request would push
        // (max-min)/mean past the threshold -> fall back to least-loaded
        ls[0].outstanding_tokens = 0.0;
        ls[1].outstanding_tokens = 300.0;
        assert_eq!(
            pick(RouterPolicy::PrefixAffinity, &ls, Some(1), &mut rr, 10.0, 1.0),
            Some(0),
            "hot prefix must not wedge its replica"
        );
        // a drained owner also falls back
        ls[1].outstanding_tokens = 0.0;
        ls[1].draining = true;
        assert_eq!(
            pick(RouterPolicy::PrefixAffinity, &ls, Some(1), &mut rr, 10.0, 1.0),
            Some(0)
        );
        // the directory policy shares the same affinity/fallback arm
        assert_eq!(
            pick(RouterPolicy::Directory, &ls, Some(1), &mut rr, 10.0, 1.0),
            Some(0),
            "directory falls back off a drained owner too"
        );
        // N = 1 degeneracy: imbalance is always 0, affinity always holds
        let one = loads(1);
        for policy in RouterPolicy::ALL {
            assert_eq!(pick(policy, &one, Some(0), &mut rr, 10.0, 0.25), Some(0));
        }
    }

    fn mock_engine() -> Engine<MockBackend> {
        Engine::new(
            MockBackend::new().with_opt(COOPT),
            EngineConfig::new("llama-7b-sim", COOPT),
        )
    }

    #[test]
    fn sync_router_routes_runs_and_orders_results() {
        let mut router = Router::new(vec![mock_engine(), mock_engine()], RouterPolicy::RoundRobin);
        assert_eq!(router.num_replicas(), 2);
        let mut picks = Vec::new();
        for i in 0..4 {
            let (rep, _) = router
                .submit(GenRequest::greedy(format!("routed prompt {i}"), 4))
                .unwrap();
            picks.push(rep);
        }
        assert_eq!(picks, vec![0, 1, 0, 1]);
        let results = router.run_to_completion().unwrap();
        assert_eq!(results.len(), 4);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.replica, i % 2, "results in submission order");
            assert_eq!(r.result.generated_tokens, 4);
        }
        // draining replica 0 steers everything to 1
        router.set_draining(0, true);
        let (rep, _) = router
            .submit(GenRequest::greedy("after drain", 2))
            .unwrap();
        assert_eq!(rep, 1);
        router.set_draining(1, true);
        assert!(router.submit(GenRequest::greedy("nowhere", 2)).is_err());
        router.set_draining(1, false);
        router.run_to_completion().unwrap();
    }

    #[test]
    fn sync_router_outputs_match_single_engine() {
        let reqs: Vec<GenRequest> = (0..6)
            .map(|i| GenRequest::greedy(format!("same output prompt {i} {}", "x".repeat(i)), 5))
            .collect();
        let mut single = mock_engine();
        let base = single.generate(reqs.clone()).unwrap();
        for policy in RouterPolicy::ALL {
            let mut router = Router::new(vec![mock_engine(), mock_engine(), mock_engine()], policy);
            for r in &reqs {
                router.submit(r.clone()).unwrap();
            }
            let got = router.run_to_completion().unwrap();
            assert_eq!(base.len(), got.len());
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.tokens, b.result.tokens, "{}", policy.name());
                assert_eq!(a.finish, b.result.finish);
            }
        }
    }

    #[test]
    fn prefix_affinity_colocates_tenants_and_wins_prefix_hits() {
        // two tenants with multi-block shared system prompts, arriving in
        // an uneven order (round-robin's index parity scatters each
        // tenant across both replicas; affinity must not)
        let tenants = [0usize, 0, 1, 0, 1, 1, 0, 1];
        let reqs: Vec<GenRequest> = tenants
            .iter()
            .enumerate()
            .map(|(i, &tenant)| {
                GenRequest::greedy(
                    format!(
                        "tenantsys{tenant} {} tail {i} {}",
                        "s".repeat(30 + tenant),
                        "y".repeat(4 + i)
                    ),
                    3,
                )
            })
            .collect();
        let hits = |policy: RouterPolicy| -> (u64, Vec<usize>) {
            // fixed threshold: with two replicas (max-min)/mean never
            // exceeds 2, so affinity is never abandoned — this test pins
            // the colocation behaviour, not the cost-model constant
            let mut router = Router::new(vec![mock_engine(), mock_engine()], policy)
                .with_affinity_threshold(4.0);
            let mut picks = Vec::new();
            for r in &reqs {
                picks.push(router.submit(r.clone()).unwrap().0);
            }
            router.run_to_completion().unwrap();
            let h = router
                .replicas()
                .iter()
                .map(|e| e.cache_stats().prefix_hits)
                .sum();
            (h, picks)
        };
        let (affinity_hits, affinity_picks) = hits(RouterPolicy::PrefixAffinity);
        let (rr_hits, rr_picks) = hits(RouterPolicy::RoundRobin);
        // affinity keeps each tenant on one replica...
        for (&tenant, &pick) in tenants.iter().zip(&affinity_picks) {
            let first = tenants.iter().position(|&t| t == tenant).unwrap();
            assert_eq!(pick, affinity_picks[first], "tenant {tenant} colocated");
        }
        // ...where round-robin splits both tenants across both replicas
        assert_eq!(rr_picks, vec![0, 1, 0, 1, 0, 1, 0, 1]);
        // and the colocated tenants reuse their shared system-prompt
        // blocks where round-robin rebuilt them
        assert!(
            affinity_hits > rr_hits,
            "affinity {affinity_hits} vs round-robin {rr_hits}"
        );
    }

    #[test]
    fn router_handle_routes_drains_and_aggregates() {
        let router = RouterHandle::spawn(
            vec![mock_engine(), mock_engine()],
            RouterPolicy::RoundRobin,
        );
        assert_eq!(router.num_replicas(), 2);
        assert_eq!(router.policy_name(), "round_robin");
        // one request per replica (round robin, sequential)
        for i in 0..2 {
            let r = router
                .generate(GenRequest::greedy(format!("handle prompt {i}"), 3))
                .unwrap();
            assert_eq!(r.generated_tokens, 3);
        }
        // drain replica 0: the next requests all land on replica 1
        router.set_draining(0, true).unwrap();
        assert!(router.set_draining(5, true).is_err());
        for i in 0..2 {
            router
                .generate(GenRequest::greedy(format!("drained era {i}"), 3))
                .unwrap();
        }
        let st = router.status();
        assert!(st[0].draining && !st[1].draining);
        assert!(st[0].healthy && st[1].healthy);
        assert_eq!(st[0].in_flight + st[1].in_flight, 0);
        // aggregated metrics: replica 0 served 3 tokens, replica 1 nine
        // (snapshots publish after the engine's next step; poll briefly)
        let mut per_replica = (0, 0);
        for _ in 0..200 {
            let v = json::parse(&router.metrics_json()).unwrap();
            assert_eq!(v.req_usize("num_replicas").unwrap(), 2);
            let reps = v.req_array("replicas").unwrap();
            let tok = |i: usize| {
                reps[i]
                    .req("metrics")
                    .and_then(|m| m.req_usize("tokens_generated"))
                    .unwrap_or(0)
            };
            per_replica = (tok(0), tok(1));
            if per_replica.0 + per_replica.1 >= 12 {
                // cluster sum matches the per-replica views
                assert_eq!(
                    v.req_usize("tokens_generated").unwrap(),
                    per_replica.0 + per_replica.1
                );
                assert!(v.req_usize("cache_blocks_total").unwrap() > 0);
                assert!(v.get("replica_occupancy_spread").is_some());
                // per-class latency hists merge into the cluster view
                assert!(v
                    .get("hist_class")
                    .and_then(|c| c.get("interactive"))
                    .is_some());
                for r in reps {
                    assert!(r.req_usize("seq").unwrap() > 0);
                    assert!(r.req_bool("healthy").unwrap());
                }
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(per_replica, (3, 9), "drain steered traffic to replica 1");
        // all drained -> no routable replica
        router.set_draining(1, true).unwrap();
        let err = router
            .generate(GenRequest::greedy("nowhere to go", 2))
            .unwrap_err();
        assert!(err.to_string().contains("no routable replica"));
        // undrain restores service
        router.set_draining(0, false).unwrap();
        let r = router
            .generate(GenRequest::greedy("back online", 2))
            .unwrap();
        assert_eq!(r.generated_tokens, 2);
    }

    #[test]
    fn router_handle_single_is_n1_special_case() {
        let handle = EngineHandle::spawn(mock_engine());
        let router = RouterHandle::single(handle);
        assert_eq!(router.num_replicas(), 1);
        let r = router.generate(GenRequest::greedy("solo", 4)).unwrap();
        assert_eq!(r.generated_tokens, 4);
        // N = 1 metrics stay flat (plus the replicas array)
        let mut seen = false;
        for _ in 0..200 {
            let v = json::parse(&router.metrics_json()).unwrap();
            if v.req_usize("tokens_generated").unwrap_or(0) >= 4 {
                assert_eq!(v.req_usize("num_replicas").unwrap(), 1);
                assert_eq!(v.req_array("replicas").unwrap().len(), 1);
                assert!(v.get("swap_outs").is_some(), "flat single-engine fields");
                seen = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(seen, "single-replica metrics never published");
    }

    #[test]
    fn request_cost_estimate_weighs_decode_heavier() {
        assert!(request_cost_estimate(10, 10) > request_cost_estimate(30, 4));
        assert_eq!(request_cost_estimate(0, 0), 0.0);
    }

    // ---- SLO admission control --------------------------------------------

    #[test]
    fn admission_sheds_batch_before_interactive() {
        let slo = SloConfig {
            admission: true,
            interactive_ttft_ms: 100,
            ..SloConfig::default()
        };
        let b = ReqClass::batch();
        let i = ReqClass::interactive();
        // admission off: never sheds, whatever the books say
        let off = SloConfig::default();
        assert!(admission_decision(&off, &b, 50, 999, 1e9, 0.0, 0.0).is_none());
        // bounded batch queue
        let full = admission_decision(&slo, &b, 50, slo.max_batch_queue, 0.0, 0.0, 0.0).unwrap();
        assert_eq!(full.reason, "batch queue full");
        assert!(full.retry_after_ms > 0, "sheds carry a client back-off");
        // projected wait over the interactive budget sheds batch...
        assert!(admission_decision(&slo, &b, 50, 0, 101.0, 0.0, 0.0).is_some());
        assert!(admission_decision(&slo, &b, 50, 0, 99.0, 0.0, 0.0).is_none());
        // ...but interactive is admitted while any batch is queued,
        // however bad the wait — the shed-ordering invariant
        assert!(admission_decision(&slo, &i, 50, 1, 1e12, 0.0, 0.0).is_none());
        // interactive sheds only with no batch left to displace AND the
        // budget already blown
        assert!(admission_decision(&slo, &i, 50, 0, 101.0, 0.0, 0.0).is_some());
        assert!(admission_decision(&slo, &i, 50, 0, 99.0, 0.0, 0.0).is_none());
        // tenant cap: a batch tenant over its outstanding-prefill share
        // sheds only while other tenants hold work
        let bt = ReqClass::batch().with_tenant("t0");
        assert!(admission_decision(&slo, &bt, 100, 0, 0.0, 90.0, 100.0).is_some());
        assert!(
            admission_decision(&slo, &bt, 100, 0, 0.0, 90.0, 90.0).is_none(),
            "a sole tenant saturating an idle cluster is utilization"
        );
        assert!(admission_decision(&slo, &bt, 20, 0, 0.0, 10.0, 100.0).is_none());
        // untenanted batch skips the cap entirely
        assert!(admission_decision(&slo, &b, 100, 0, 0.0, 90.0, 100.0).is_none());
    }

    #[test]
    fn projected_wait_scales_with_best_score_and_observed_tail() {
        let mut ls = loads(2);
        ls[0].outstanding_tokens = 400.0;
        ls[1].outstanding_tokens = 100.0;
        // the request lands on the best replica, so its score drives
        assert!((projected_wait_ms(&ls, 0.0) - 100.0 * SLO_MS_PER_TOKEN).abs() < 1e-9);
        // the observed queue-wait p95 floors the projection
        assert!((projected_wait_ms(&ls, 0.5) - 500.0).abs() < 1e-9);
        ls[0].draining = true;
        ls[1].healthy = false;
        assert_eq!(projected_wait_ms(&ls, 0.0), f64::INFINITY);
    }

    #[test]
    fn sync_router_sheds_batch_and_releases_books() {
        let slo = SloConfig {
            admission: true,
            max_batch_queue: 1,
            ..SloConfig::default()
        };
        let mut router = Router::new(vec![mock_engine(), mock_engine()], RouterPolicy::LeastLoaded)
            .with_slo(slo);
        let breq = |i: usize| {
            GenRequest::greedy(format!("batch work {i}"), 3)
                .with_class(ReqClass::batch().with_tenant("acme"))
        };
        // the first batch request takes the single bounded-queue slot
        router.submit(breq(0)).unwrap();
        assert_eq!(router.batch_queue_depth(), 1);
        // the second is shed with the parseable 429 convention
        let err = router.submit(breq(1)).unwrap_err().to_string();
        assert!(err.starts_with(SHED_MARKER), "{err}");
        assert!(
            err.contains("class=batch") && err.contains("retry_after_ms="),
            "{err}"
        );
        assert_eq!(router.shed_requests(), 1);
        // interactive is never bounded by the batch queue
        router
            .submit(GenRequest::greedy("interactive user", 3))
            .unwrap();
        let results = router.run_to_completion().unwrap();
        assert_eq!(results.len(), 2);
        // completion releases the batch slot and the tenant's tokens
        assert_eq!(router.batch_queue_depth(), 0);
        assert!(router.tenant_total.abs() < 1e-9);
        assert!(router.tenant_tokens.is_empty());
        router.submit(breq(2)).unwrap();
        router.run_to_completion().unwrap();
    }

    #[test]
    fn router_handle_sheds_batch_and_serves_interactive() {
        let slo = SloConfig {
            admission: true,
            max_batch_queue: 0,
            ..SloConfig::default()
        };
        let router = RouterHandle::spawn(
            vec![mock_engine(), mock_engine()],
            RouterPolicy::LeastLoaded,
        )
        .with_slo(slo);
        let err = router
            .generate(GenRequest::greedy("bulk job", 3).with_class(ReqClass::batch()))
            .unwrap_err()
            .to_string();
        assert!(err.starts_with(SHED_MARKER), "{err}");
        assert!(err.contains("retry_after_ms="), "{err}");
        assert_eq!(router.shed_requests(), 1);
        // interactive traffic still serves on the idle cluster
        let r = router.generate(GenRequest::greedy("chat turn", 3)).unwrap();
        assert_eq!(r.generated_tokens, 3);
        // the shed left no residue in the books, and the counters reach
        // the cluster metrics view
        for o in router.outstanding_estimates() {
            assert!(o.abs() < 1e-9);
        }
        let v = json::parse(&router.metrics_json()).unwrap();
        assert_eq!(v.req_usize("shed_requests").unwrap(), 1);
        assert_eq!(v.req_usize("router_retries").unwrap(), 0);
        assert_eq!(v.req_usize("batch_queue_depth").unwrap(), 0);
    }

    #[test]
    fn replica_fault_retries_once_to_surviving_replica() {
        let mk = |fail| {
            Engine::new(
                FlakyDecode { inner: MockBackend::new().with_opt(COOPT), fail },
                EngineConfig::new("llama-7b-sim", COOPT),
            )
        };
        // the flaky replica sits at index 0 so the idle-cluster
        // tie-break routes the first request straight into the fault
        let router = RouterHandle::spawn(vec![mk(true), mk(false)], RouterPolicy::LeastLoaded);
        let r = router
            .generate(GenRequest::greedy("survives the fault", 3))
            .unwrap();
        assert_eq!(r.generated_tokens, 3, "client sees success, not the fault");
        assert_eq!(router.router_retries(), 1);
        // books balanced across both attempts
        let st = router.status();
        assert_eq!(st[0].in_flight + st[1].in_flight, 0);
        for o in router.outstanding_estimates() {
            assert!(o.abs() < 1e-9, "outstanding estimate leaked: {o}");
        }
    }

    // ---- disaggregated prefill/decode -------------------------------------

    #[test]
    fn pd_placement_masks_roles_and_falls_back() {
        let roles = [ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Mixed];
        let mut ls = loads(3);
        let mut rr = 0;
        let mut pd = |ls: &[ReplicaLoad], to_prefill: bool| {
            pick_replica_pd(
                RouterPolicy::LeastLoaded,
                ls,
                &roles,
                to_prefill,
                None,
                &mut rr,
                10.0,
                1.0,
            )
        };
        // prefill-bound requests land on the prefill pool, everything
        // else on the decode-capable pool
        assert_eq!(pd(&ls, true), Some(0));
        assert_eq!(pd(&ls, false), Some(1));
        // preferred pool drained: fall back to the other tier rather
        // than refusing the request
        ls[0].draining = true;
        assert_eq!(pd(&ls, true), Some(1));
        ls[0].draining = false;
        ls[1].draining = true;
        ls[2].healthy = false;
        assert_eq!(pd(&ls, false), Some(0), "prefill replica is the fallback");
        ls[0].draining = true;
        assert_eq!(pd(&ls, true), None, "nothing routable");
    }

    #[test]
    fn handoff_pays_gates_on_prefill_dominance_then_price() {
        let be = MockBackend::new().with_opt(COOPT);
        let pricing = (
            CostModel::for_preset(be.preset(), 16).with_ctx_scale(8.0),
            *be.opt(),
        );
        // decode-heavy requests never start on a prefill replica, with
        // or without a cost model
        assert!(!handoff_pays(Some(&pricing), 16, 10, 10));
        assert!(!handoff_pays(None, 16, 10, 10));
        // prefill-heavy and unpriced: always pays
        assert!(handoff_pays(None, 16, 96, 4));
        // priced: exactly the cost model's swap-vs-recompute relation
        // (+1 block for the sampled tail's landing block)
        let expect = pricing.0.swap_beats_recompute(96usize.div_ceil(16) + 1, 96, &pricing.1);
        assert_eq!(handoff_pays(Some(&pricing), 16, 96, 4), expect);
    }

    fn pd_engine(role: ReplicaRole) -> Engine<MockBackend> {
        Engine::new(
            MockBackend::new().with_opt(COOPT),
            EngineConfig::new("llama-7b-sim", COOPT)
                .with_host_pool(64)
                .with_swap_policy(SwapPolicy::Always)
                .with_role(role),
        )
    }

    fn pd_reqs(n: usize, pad: usize, max_new: usize) -> Vec<GenRequest> {
        (0..n)
            .map(|i| GenRequest::greedy(format!("pd handoff {i} {}", "p".repeat(pad + i)), max_new))
            .collect()
    }

    #[test]
    fn pd_split_cluster_matches_single_engine_and_hands_off() {
        let reqs = pd_reqs(6, 40, 4);
        let mut single = mock_engine();
        let base = single.generate(reqs.clone()).unwrap();
        let mut router = Router::new(
            vec![
                pd_engine(ReplicaRole::Prefill),
                pd_engine(ReplicaRole::Decode),
                pd_engine(ReplicaRole::Mixed),
            ],
            RouterPolicy::LeastLoaded,
        )
        .with_unpriced_handoff();
        let mut picks = Vec::new();
        for r in &reqs {
            picks.push(router.submit(r.clone()).unwrap().0);
        }
        assert!(
            picks.iter().all(|&p| p == 0),
            "prefill-heavy requests start on the prefill replica: {picks:?}"
        );
        let got = router.run_to_completion().unwrap();
        assert_eq!(base.len(), got.len());
        for (a, b) in base.iter().zip(&got) {
            assert_eq!(a.tokens, b.result.tokens, "hand-off is token-identical");
            assert_eq!(a.finish, b.result.finish);
            assert_ne!(b.replica, 0, "decode finished away from the prefill replica");
        }
        let pre = &router.replicas()[0];
        assert_eq!(pre.metrics.migrations_out, 6, "every sequence left via the host tier");
        assert_eq!(pre.metrics.decode_steps, 0, "the prefill replica never decodes");
        assert!(pre.metrics.migration_bytes > 0);
        let landed: u64 = router.replicas().iter().map(|e| e.metrics.migrations_in).sum();
        assert_eq!(landed, 6);
        for e in router.replicas() {
            assert_eq!(e.tier_stats().host_used_blocks, 0, "staging slots all released");
            assert_eq!(e.cache_stats().blocks_used, 0);
        }
    }

    #[test]
    fn pd_without_destination_aborts_to_local_decode() {
        let reqs = pd_reqs(2, 40, 3);
        let mut single = mock_engine();
        let base = single.generate(reqs.clone()).unwrap();
        let mut router = Router::new(
            vec![pd_engine(ReplicaRole::Prefill), pd_engine(ReplicaRole::Prefill)],
            RouterPolicy::LeastLoaded,
        )
        .with_unpriced_handoff();
        for r in &reqs {
            router.submit(r.clone()).unwrap();
        }
        let got = router.run_to_completion().unwrap();
        for (a, b) in base.iter().zip(&got) {
            assert_eq!(a.tokens, b.result.tokens, "aborted hand-off still decodes locally");
        }
        let moved: u64 = router.replicas().iter().map(|e| e.metrics.migrations_out).sum();
        assert_eq!(moved, 0, "no decode-capable destination: nothing migrates");
    }

    #[test]
    fn poisoned_route_state_recovers_on_both_paths() {
        let router = RouterHandle::spawn(
            vec![mock_engine(), mock_engine()],
            RouterPolicy::LeastLoaded,
        );
        // a panic while holding the routing lock poisons it for good
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = router.state.lock().unwrap();
            panic!("poison the route state");
        }));
        assert!(router.state.lock().is_err(), "mutex is poisoned");
        // both lock sites (placement and the completion-side estimate
        // decrement) must shrug it off: requests keep routing and the
        // estimates keep draining — a wedged router here was the bug
        for i in 0..4 {
            let r = router
                .generate(GenRequest::greedy(format!("after poison {i}"), 3))
                .unwrap();
            assert_eq!(r.generated_tokens, 3);
        }
        for o in router.outstanding_estimates() {
            assert!(o.abs() < 1e-9, "outstanding estimate leaked: {o}");
        }
    }

    struct FlakyDecode {
        inner: MockBackend,
        fail: bool,
    }
    impl Backend for FlakyDecode {
        fn preset(&self) -> &crate::config::ModelPreset {
            self.inner.preset()
        }
        fn geometry(&self) -> &crate::config::CacheGeometry {
            self.inner.geometry()
        }
        fn opt(&self) -> &OptConfig {
            self.inner.opt()
        }
        fn prefill(&mut self, t: &[i32], l: i32, s: &[i32]) -> Result<Vec<f32>> {
            self.inner.prefill(t, l, s)
        }
        fn decode(
            &mut self,
            t: &[i32],
            p: &[i32],
            b: &[i32],
            c: &[i32],
            s: &[i32],
        ) -> Result<Vec<f32>> {
            if self.fail {
                bail!("simulated accelerator fault");
            }
            self.inner.decode(t, p, b, c, s)
        }
        fn reset_cache(&mut self) -> Result<()> {
            self.inner.reset_cache()
        }
        fn take_exec_time(&mut self) -> std::time::Duration {
            self.inner.take_exec_time()
        }
    }

    #[test]
    fn replica_death_mid_request_rebalances_accounting() {
        let mk = |fail| {
            Engine::new(
                FlakyDecode { inner: MockBackend::new().with_opt(COOPT), fail },
                EngineConfig::new("llama-7b-sim", COOPT),
            )
        };
        let router = RouterHandle::spawn(vec![mk(false), mk(true)], RouterPolicy::LeastLoaded);
        let r = router
            .generate(GenRequest::greedy("healthy replica", 3))
            .unwrap();
        assert_eq!(r.generated_tokens, 3);
        // force the next request onto the faulty replica, which dies
        // mid-request (prefill lands, the first decode step faults)
        router.set_draining(0, true).unwrap();
        let err = router
            .generate(GenRequest::greedy("doomed request", 3))
            .unwrap_err();
        assert!(err.to_string().contains("engine error"), "{err}");
        assert_eq!(
            router.router_retries(),
            0,
            "no surviving routable replica: the original error comes back"
        );
        // the failure leaves no residue in the router's books: the
        // in-flight gauges and outstanding estimates return to balance,
        // so least-loaded placement is never permanently biased
        let st = router.status();
        assert_eq!(st[0].in_flight + st[1].in_flight, 0);
        for o in router.outstanding_estimates() {
            assert!(o.abs() < 1e-9, "outstanding estimate leaked: {o}");
        }
        assert!(st[1].healthy, "the engine thread survives a step fault");
        router.set_draining(0, false).unwrap();
        let r = router
            .generate(GenRequest::greedy("back to balance", 2))
            .unwrap();
        assert_eq!(r.generated_tokens, 2);
    }

    #[test]
    fn router_handle_hands_off_through_the_dispatcher() {
        let router = RouterHandle::spawn(
            vec![pd_engine(ReplicaRole::Prefill), pd_engine(ReplicaRole::Decode)],
            RouterPolicy::LeastLoaded,
        )
        .with_unpriced_handoff();
        assert_eq!(router.role(0), ReplicaRole::Prefill);
        let reqs = pd_reqs(3, 40, 4);
        let mut single = mock_engine();
        let base = single.generate(reqs.clone()).unwrap();
        for (req, b) in reqs.iter().zip(&base) {
            let got = router.generate(req.clone()).unwrap();
            assert_eq!(got.tokens, b.tokens, "threaded hand-off is token-identical");
        }
        // the migration counters reach the aggregated cluster view
        // (snapshots publish after each engine's next step; poll)
        let mut seen = false;
        for _ in 0..400 {
            let v = json::parse(&router.metrics_json()).unwrap();
            if v.req_usize("migrations_out").unwrap_or(0) >= 3
                && v.req_usize("migrations_in").unwrap_or(0) >= 3
            {
                assert_eq!(v.req_array("replica_roles").unwrap().len(), 2);
                seen = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(seen, "migration counters never reached the cluster metrics");
        // books balanced after the hand-offs
        for o in router.outstanding_estimates() {
            assert!(o.abs() < 1e-9);
        }
        let st = router.status();
        assert_eq!(st[0].in_flight + st[1].in_flight, 0);
    }

    #[test]
    fn autoscaler_rotates_reroles_and_keeps_coverage() {
        let router = RouterHandle::spawn(
            vec![mock_engine(), mock_engine(), mock_engine()],
            RouterPolicy::LeastLoaded,
        );
        // idle cluster: drain one replica per tick down to a floor of one
        assert_eq!(router.autoscale_tick(), "scale_down");
        assert_eq!(router.autoscale_tick(), "scale_down");
        assert_eq!(router.autoscale_tick(), "noop", "never drains the last replica");
        assert_eq!(router.status().iter().filter(|s| s.draining).count(), 2);
        // queue-depth surge past the high-water mark: re-admit capacity
        router.replicas[0].in_flight.store(8, Ordering::Relaxed);
        assert_eq!(router.autoscale_tick(), "scale_up");
        assert_eq!(router.status().iter().filter(|s| s.draining).count(), 1);
        // still saturated: the next tick re-admits the rest
        router.set_role(0, ReplicaRole::Prefill).unwrap();
        assert_eq!(router.autoscale_tick(), "scale_up");
        // the backlog sits entirely on the prefill replica while the
        // others idle: the idlest replica adopts its specialization
        assert_eq!(router.autoscale_tick(), "rerole");
        assert_eq!(router.role(1), ReplicaRole::Prefill);
        // and then holds: the idlest replica already specializes, so
        // another tick must not churn roles
        assert_eq!(router.autoscale_tick(), "noop");
    }

    // ---- cluster-wide prefix reuse ----------------------------------------

    fn pull_engine() -> Engine<MockBackend> {
        Engine::new(
            MockBackend::new().with_opt(COOPT),
            EngineConfig::new("llama-7b-sim", COOPT).with_host_pool(64),
        )
    }

    #[test]
    fn directory_pull_moves_warm_prefix_and_stays_exact() {
        // 4 repeats ≈ 85 tokens with BOS: five full 16-token blocks of
        // shared prefix, comfortably inside the mock's max_seq of 128
        let sys = "shared system prompt ".repeat(4);
        let reqs: Vec<GenRequest> = (0..2)
            .map(|i| GenRequest::greedy(format!("{sys}tenant {i}"), 6))
            .collect();
        let mut single = pull_engine();
        let base = single.generate(reqs.clone()).unwrap();
        let mut router =
            Router::new(vec![pull_engine(), pull_engine()], RouterPolicy::Directory)
                .with_unpriced_handoff();
        // request 0 lands and prefills; a couple of steps leave it
        // mid-decode with its prefix chain committed and *live*
        let (owner, _) = router.submit(reqs[0].clone()).unwrap();
        router.step_all().unwrap();
        router.step_all().unwrap();
        // drain the owner: request 1 must route elsewhere, and the
        // directory pulls the warm chain across before its prefill
        router.set_draining(owner, true);
        let (dest, _) = router.submit(reqs[1].clone()).unwrap();
        assert_ne!(dest, owner, "drained owner cannot take the request");
        router.set_draining(owner, false);
        let got = router.run_to_completion().unwrap();
        for (a, b) in base.iter().zip(&got) {
            assert_eq!(a.tokens, b.result.tokens, "pulled prefix is token-identical");
        }
        let dm = &router.replicas()[dest].metrics;
        assert!(dm.prefix_pulls >= 1, "destination committed a pull");
        assert!(dm.prefix_pull_blocks > 0, "blocks actually moved");
        assert!(
            router.replicas()[owner].metrics.prefix_pull_blocks_out > 0,
            "owner exported blocks"
        );
        assert!(
            router.directory().device_hits + router.directory().host_hits > 0,
            "the probe hit the registered chain"
        );
    }

    #[test]
    fn directory_stale_entry_falls_back_to_prefill_exactly() {
        let sys = "stale directory prompt ".repeat(4);
        let reqs: Vec<GenRequest> = (0..2)
            .map(|i| GenRequest::greedy(format!("{sys}tenant {i}"), 5))
            .collect();
        let mut single = pull_engine();
        let base = single.generate(reqs.clone()).unwrap();
        let mut router =
            Router::new(vec![pull_engine(), pull_engine()], RouterPolicy::Directory)
                .with_unpriced_handoff();
        // inject bogus registrations for request 1's whole chain: the
        // directory claims replica 0 holds blocks it never prefilled
        let tokens = Tokenizer::new().encode(&reqs[1].prompt, true, false);
        let chain = prefix_chain_hashes(&tokens, 16, CHAIN_CAP);
        assert!(chain.len() >= 2, "prompt must span several blocks");
        for &h in &chain {
            router.directory_mut().register(h, 0, &[true, true]);
        }
        // steer the request off the fake owner so a pull is attempted
        router.set_draining(0, true);
        router.submit(reqs[1].clone()).unwrap();
        router.set_draining(0, false);
        router.submit(reqs[0].clone()).unwrap();
        let got = router.run_to_completion().unwrap();
        assert_eq!(got[0].result.tokens, base[1].tokens, "stale pull stays exact");
        assert_eq!(got[1].result.tokens, base[0].tokens);
        // the stale export shipped nothing; the destination re-prefilled
        let pulled: u64 = router
            .replicas()
            .iter()
            .map(|e| e.metrics.prefix_pull_blocks)
            .sum();
        assert_eq!(pulled, 0, "nothing was resident to move");
        let stale: u64 = router
            .replicas()
            .iter()
            .map(|e| e.metrics.prefix_pull_stale)
            .sum();
        assert!(stale >= 1, "the shortfall is accounted");
    }

    #[test]
    fn dispatcher_defers_handoffs_instead_of_token_fallback() {
        // PR 6 carry-over: the threaded dispatcher used to place
        // hand-offs on batch-full decode replicas, burning the staged KV
        // on a token fallback.  With one decode slot, concurrent
        // hand-offs must now queue for the slot.
        let mut decode_cfg = EngineConfig::new("llama-7b-sim", COOPT)
            .with_host_pool(64)
            .with_swap_policy(SwapPolicy::Always)
            .with_role(ReplicaRole::Decode);
        decode_cfg.max_batch = 1;
        let decode = Engine::new(MockBackend::new().with_opt(COOPT), decode_cfg);
        let router = RouterHandle::spawn(
            vec![pd_engine(ReplicaRole::Prefill), decode],
            RouterPolicy::LeastLoaded,
        )
        .with_unpriced_handoff();
        let reqs = pd_reqs(3, 40, 4);
        let mut single = mock_engine();
        let base = single.generate(reqs.clone()).unwrap();
        let results: Vec<GenResult> = std::thread::scope(|s| {
            let handles: Vec<_> = reqs
                .iter()
                .map(|r| {
                    let router = &router;
                    let r = r.clone();
                    s.spawn(move || router.generate(r).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // concurrent completion order is arbitrary: compare as multisets
        let mut want: Vec<_> = base.iter().map(|b| b.tokens.clone()).collect();
        let mut got: Vec<_> = results.iter().map(|r| r.tokens.clone()).collect();
        want.sort();
        got.sort();
        assert_eq!(want, got, "deferred hand-offs stay token-identical");
        let mut landed = false;
        for _ in 0..400 {
            let v = json::parse(&router.metrics_json()).unwrap();
            if v.req_usize("migrations_in").unwrap_or(0) >= 3 {
                assert_eq!(
                    v.req_usize("migrations_token_fallback").unwrap_or(0),
                    0,
                    "a full batch must defer the hand-off, not burn it"
                );
                landed = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(landed, "hand-offs never landed on the decode replica");
    }
}
