//! ARC-sim accuracy harness — reproduces Tables 1 and 2 (paper §4.3.2).
//!
//! Protocol (single-token MCQ, Eq. 13): for each question the engine
//! scores the prompt `"... \nAnswer: "` and the choice letter with the
//! highest next-token log-prob is the prediction.  The same questions run
//! under `original` and `coopt` (and any other config) so the tables'
//! claim — FP8-KV + GQA + Opt-Pa preserve accuracy — is measured on real
//! logits from the serving stack.

use anyhow::Result;

use crate::coordinator::Engine;
use crate::runtime::Backend;
use crate::sampling::mcq_scores;
use crate::tokenizer::Tokenizer;
use crate::workload::McqSet;

#[derive(Debug, Clone)]
pub struct EvalResult {
    pub split: String,
    pub total: usize,
    pub correct: usize,
    /// per-question predicted choice index
    pub predictions: Vec<usize>,
}

impl EvalResult {
    /// Eq. 13: accuracy = N_correct / N_total * 100%.
    pub fn accuracy_pct(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64 * 100.0
        }
    }
}

/// Run the MCQ set through the engine's scoring path.
pub fn evaluate<B: Backend>(engine: &mut Engine<B>, set: &McqSet) -> Result<EvalResult> {
    let tok = Tokenizer::new();
    let choice_ids: Vec<u32> = set.letters.iter().map(|&c| c as u32).collect();
    let mut correct = 0;
    let mut predictions = Vec::with_capacity(set.questions.len());
    for q in &set.questions {
        // trained format: "<prompt> A" — score the token after "Answer: "
        let ids = tok.encode(&format!("{} ", q.prompt), true, false);
        let logits = engine.score_tokens(&ids)?;
        let (best, _) = mcq_scores(&logits, &choice_ids);
        predictions.push(best);
        if best == q.answer {
            correct += 1;
        }
    }
    Ok(EvalResult {
        split: set.split.clone(),
        total: set.questions.len(),
        correct,
        predictions,
    })
}

/// Agreement rate between two prediction vectors (how often two configs
/// pick the same answer — a stricter preservation measure than accuracy).
pub fn agreement(a: &EvalResult, b: &EvalResult) -> f64 {
    let n = a.predictions.len().min(b.predictions.len());
    if n == 0 {
        return 1.0;
    }
    let same = a
        .predictions
        .iter()
        .zip(&b.predictions)
        .filter(|(x, y)| x == y)
        .count();
    same as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, COOPT};
    use crate::runtime::mock::MockBackend;
    use crate::workload::McqQuestion;

    fn tiny_set() -> McqSet {
        McqSet {
            split: "easy".into(),
            letters: vec!['A', 'B', 'C', 'D'],
            questions: (0..5)
                .map(|i| McqQuestion {
                    prompt: format!("Q: {i}+0=? A) {i} B) 9 C) 8 D) 7\nAnswer:"),
                    choices: vec![format!("{i}"), "9".into(), "8".into(), "7".into()],
                    answer: 0,
                })
                .collect(),
        }
    }

    #[test]
    fn harness_runs_and_scores() {
        let be = MockBackend::new();
        let mut e = Engine::new(be, EngineConfig::new("llama-7b-sim", COOPT));
        let set = tiny_set();
        let r = evaluate(&mut e, &set).unwrap();
        assert_eq!(r.total, 5);
        assert_eq!(r.predictions.len(), 5);
        assert!(r.accuracy_pct() <= 100.0);
        // engine leaks no blocks across 5 scoring prefills
        assert_eq!(e.cache_stats().blocks_used, 0);
    }

    #[test]
    fn eval_is_deterministic() {
        let set = tiny_set();
        let run = || {
            let be = MockBackend::new();
            let mut e = Engine::new(be, EngineConfig::new("llama-7b-sim", COOPT));
            evaluate(&mut e, &set).unwrap().predictions
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn agreement_bounds() {
        let a = EvalResult {
            split: "x".into(),
            total: 4,
            correct: 2,
            predictions: vec![0, 1, 2, 3],
        };
        let b = EvalResult {
            split: "x".into(),
            total: 4,
            correct: 2,
            predictions: vec![0, 1, 0, 0],
        };
        assert_eq!(agreement(&a, &a), 1.0);
        assert_eq!(agreement(&a, &b), 0.5);
    }
}
